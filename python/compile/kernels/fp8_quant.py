"""Pallas kernels for blockwise FP8 quantization and W8A8 matmul (L1).

These are the paper's compute hot spots re-thought for the TPU model
(DESIGN.md §2 Hardware adaptation):

* ``blockwise_quant``      — per (BM x BN) block amax -> scale -> saturating
                             E4M3 round-trip. The weight-sync phase's kernel.
* ``act_quant``            — dynamic per (1 x BK) tile activation quant.
* ``w8a8_matmul``          — blockwise-scaled FP8 GEMM: grid over
                             (M/BM, N/BN, K/BK); weight tiles are fake-quant
                             E4M3 with one scale per (BK x BN) block,
                             activation rows are quantized per (1 x BK) tile
                             in-kernel, MXU accumulates in f32 with scale
                             folding — the DeepGEMM analogue.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); they lower into the same HLO as the surrounding jax model so
the AOT artifacts contain them. Correctness oracle: ``ref.py`` (pytest).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fp8_numerics import fp8_max, _FMT

INTERPRET = True  # CPU path; real TPU would flip this off


def _qdq_in_kernel(x, fmt: str):
    """Saturating FP8 round-trip usable inside a pallas kernel body."""
    f = _FMT[fmt]
    clipped = jnp.clip(x, -f["max"], f["max"])
    return clipped.astype(f["dtype"]).astype(x.dtype)


def _mk_scale(amax, fmt: str, pow2_scale: bool):
    scale = jnp.maximum(amax, 1e-12) / fp8_max(fmt)
    if pow2_scale:
        scale = 2.0 ** jnp.ceil(jnp.log2(scale))
    return scale


# ---------------------------------------------------------------------------
# blockwise weight quantization
# ---------------------------------------------------------------------------


def _blockwise_quant_kernel(w_ref, out_ref, scale_ref, *, fmt, pow2_scale):
    blk = w_ref[...]
    scale = _mk_scale(jnp.max(jnp.abs(blk)), fmt, pow2_scale)
    out_ref[...] = _qdq_in_kernel(blk / scale, fmt) * scale
    scale_ref[0, 0] = scale


def blockwise_quant(
    w: jnp.ndarray,
    block: Tuple[int, int] = (128, 128),
    fmt: str = "e4m3",
    pow2_scale: bool = False,
):
    """Fake-quant ``w`` blockwise; returns (dequantized w, per-block scales).

    Shapes must be multiples of ``block`` (aot pads its weights to the
    block grid; tests sweep both aligned shapes and the jnp-ref padding
    path in fp8_numerics).
    """
    m, n = w.shape
    bm, bn = block
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, block)
    grid = (m // bm, n // bn)
    kernel = functools.partial(
        _blockwise_quant_kernel, fmt=fmt, pow2_scale=pow2_scale
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), w.dtype),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=INTERPRET,
    )(w)


# ---------------------------------------------------------------------------
# dynamic activation quantization
# ---------------------------------------------------------------------------


def _act_quant_kernel(x_ref, out_ref, *, fmt, tile, pow2_scale):
    row = x_ref[...]  # (BR, K)
    k = row.shape[-1]
    tiles = row.reshape(row.shape[0], k // tile, tile)
    amax = jnp.max(jnp.abs(tiles), axis=-1, keepdims=True)
    scale = _mk_scale(amax, fmt, pow2_scale)
    q = _qdq_in_kernel(tiles / scale, fmt) * scale
    out_ref[...] = q.reshape(row.shape)


def act_quant(
    x: jnp.ndarray,
    tile: int = 128,
    fmt: str = "e4m3",
    block_rows: int = 8,
    pow2_scale: bool = False,
):
    """Per-(1 x tile) dynamic fake-quant of a 2-D activation matrix."""
    r, k = x.shape
    tile = min(tile, k)
    assert k % tile == 0, (k, tile)
    br = min(block_rows, r)
    while r % br:
        br -= 1
    kernel = functools.partial(
        _act_quant_kernel, fmt=fmt, tile=tile, pow2_scale=pow2_scale
    )
    return pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, k), x.dtype),
        interpret=INTERPRET,
    )(x)


# ---------------------------------------------------------------------------
# W8A8 blockwise matmul
# ---------------------------------------------------------------------------


def _w8a8_matmul_kernel(x_ref, w_ref, o_ref, *, fmt, act_tile, nk, pow2_scale):
    """One (BM x BN) output tile, accumulating over the K grid axis.

    x tile: (BM, BK) activations — quantized per (1 x act_tile) here.
    w tile: (BK, BN) weights — ONE scale for the whole block (the paper's
            128x128 weight-block granularity).
    The output ref doubles as the f32 accumulator across the K axis (the
    grid's last dimension is sequential, the TPU "arbitrary" dimension).
    """
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = w_ref[...]
    # weight block quant (static per block; idempotent for pre-quantized w)
    wscale = _mk_scale(jnp.max(jnp.abs(w)), fmt, pow2_scale)
    wq = _qdq_in_kernel(w / wscale, fmt)
    # activation tile quant (dynamic)
    bm, bk = x.shape
    tiles = x.reshape(bm, bk // act_tile, act_tile)
    ascale = _mk_scale(
        jnp.max(jnp.abs(tiles), axis=-1, keepdims=True), fmt, pow2_scale
    )
    xq = _qdq_in_kernel(tiles / ascale, fmt)
    xdq = (xq * ascale).reshape(bm, bk)
    # MXU matmul with scale folding: (xq*ascale) @ wq * wscale
    o_ref[...] += jnp.dot(xdq, wq, preferred_element_type=jnp.float32) * wscale


def w8a8_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    block: Tuple[int, int, int] = (8, 128, 128),
    act_tile: int = 128,
    fmt: str = "e4m3",
    pow2_scale: bool = False,
):
    """Blockwise-scaled W8A8 GEMM: ``x @ w`` with FP8 fake-quant operands."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bk, bn = block
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    act_tile = min(act_tile, bk)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (x.shape, w.shape, block)
    assert bk % act_tile == 0
    nk = k // bk
    kernel = functools.partial(
        _w8a8_matmul_kernel, fmt=fmt, act_tile=act_tile, nk=nk,
        pow2_scale=pow2_scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, w)


__all__ = ["blockwise_quant", "act_quant", "w8a8_matmul"]
