"""Pallas blocked attention with optional FP8 KV-cache dequantization (L1).

The paper's §2.3 quantizes the KV cache to E4M3 with per-step-recalibrated
QKV scales. On GPU this lives inside the paged-attention kernel (dequant in
shared memory); the TPU-style port streams K/V blocks HBM->VMEM via
BlockSpec and dequantizes in-register before the blocked
softmax-attention (online/flash-style accumulation across KV blocks).

Variants (selected by flags, one kernel body):
  * plain (BF16 path) — f32 K/V straight through.
  * fp8_kv            — K/V arrive FP8-quantized against the per-step
    recalibrated per-tensor scales (k_scale, v_scale operands); the kernel
    dequantizes in-register. ("KV cache FP8 only")
  * fp8_attn          — additionally rounds Q and the attention
    probabilities through E4M3 ("Full FP8" = linear + KV + attention).

The first-query position is a runtime operand (``qpos``), so one compiled
module serves every decode step — no per-position recompiles.

Perf (§Perf iteration 1): heads are processed in blocks of
``head_block`` per grid step. On a real TPU head_block=1 maps one head
per core pass; under interpret=True the grid is a sequential loop, so
batching all heads into one block cut decode step time ~2x (see
EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fp8_numerics import _FMT

INTERPRET = True
NEG_INF = -1e30


def _qdq(x, fmt="e4m3"):
    f = _FMT[fmt]
    return jnp.clip(x, -f["max"], f["max"]).astype(f["dtype"]).astype(x.dtype)


def _attn_kernel(
    q_ref, k_ref, v_ref, kscale_ref, vscale_ref, qpos_ref,
    out_ref, m_ref, l_ref, acc_ref,
    *, nkv, kv_block, causal, fp8_kv, fp8_attn,
):
    """One (head-block, q-block) output tile, streaming over KV blocks
    (grid axis 2, sequential) with online-softmax state carried in
    m/l/acc output refs. All refs carry a leading head-block axis."""
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]  # (HB, TQ, D)
    k = k_ref[...]  # (HB, TK, D)
    v = v_ref[...]  # (HB, TK, D)

    if fp8_kv:
        ks = kscale_ref[0, 0]
        vs = vscale_ref[0, 0]
        k = _qdq(k / ks) * ks
        v = _qdq(v / vs) * vs
    if fp8_attn:
        q = _qdq(q)

    d = q.shape[-1]
    s = jnp.einsum(
        "hqd,hkd->hqk", q, k, preferred_element_type=jnp.float32
    ) * (1.0 / jnp.sqrt(jnp.float32(d)))

    hb, tq, tk = s.shape
    if causal:
        qp = qpos_ref[...][:, :, None] + jax.lax.broadcasted_iota(
            jnp.int32, (hb, tq, tk), 1
        )
        kp = kv_idx * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (hb, tq, tk), 2
        )
        s = jnp.where(kp <= qp, s, NEG_INF)

    m_prev = m_ref[...]      # (HB, TQ, 1)
    l_prev = l_ref[...]      # (HB, TQ, 1)
    acc_prev = acc_ref[...]  # (HB, TQ, D)

    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    if fp8_attn:
        p = _qdq(p)  # attention-probability quantization ("Full FP8")
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_prev * alpha + jnp.einsum(
        "hqk,hkd->hqd", p, v, preferred_element_type=jnp.float32
    )

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(kv_idx == nkv - 1)
    def _final():
        out_ref[...] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def blocked_attention(
    q: jnp.ndarray,          # (H, TQ, D)
    k: jnp.ndarray,          # (H, TK, D)
    v: jnp.ndarray,          # (H, TK, D)
    k_scale: jnp.ndarray,    # (1, 1) per-step recalibrated scale
    v_scale: jnp.ndarray,    # (1, 1)
    qpos: jnp.ndarray,       # (H, 1) int32 — per-head first-query position
                             # (heads may fold a batch axis in decode, where
                             # each sequence sits at a different position)
    *,
    causal: bool = True,
    kv_block: int = 128,
    head_block: int = 0,     # 0 = all heads in one block (CPU-interpret
                             # sweet spot); TPU would use 1..8
    fp8_kv: bool = False,
    fp8_attn: bool = False,
):
    """Blocked (flash-style) multi-head attention; returns (H, TQ, D) f32."""
    h, tq, d = q.shape
    _, tk, _ = k.shape
    kv_block = min(kv_block, tk)
    assert tk % kv_block == 0, (tk, kv_block)
    nkv = tk // kv_block
    hb = h if head_block == 0 else min(head_block, h)
    assert h % hb == 0, (h, hb)
    kernel = functools.partial(
        _attn_kernel,
        nkv=nkv, kv_block=kv_block, causal=causal,
        fp8_kv=fp8_kv, fp8_attn=fp8_attn,
    )
    out, _m, _l, _acc = pl.pallas_call(
        kernel,
        grid=(h // hb, 1, nkv),
        in_specs=[
            pl.BlockSpec((hb, tq, d), lambda hh, qq, kk: (hh, 0, 0)),
            pl.BlockSpec((hb, kv_block, d), lambda hh, qq, kk: (hh, kk, 0)),
            pl.BlockSpec((hb, kv_block, d), lambda hh, qq, kk: (hh, kk, 0)),
            pl.BlockSpec((1, 1), lambda hh, qq, kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda hh, qq, kk: (0, 0)),
            pl.BlockSpec((hb, 1), lambda hh, qq, kk: (hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((hb, tq, d), lambda hh, qq, kk: (hh, 0, 0)),
            pl.BlockSpec((hb, tq, 1), lambda hh, qq, kk: (hh, 0, 0)),
            pl.BlockSpec((hb, tq, 1), lambda hh, qq, kk: (hh, 0, 0)),
            pl.BlockSpec((hb, tq, d), lambda hh, qq, kk: (hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, tq, d), jnp.float32),
            jax.ShapeDtypeStruct((h, tq, 1), jnp.float32),
            jax.ShapeDtypeStruct((h, tq, 1), jnp.float32),
            jax.ShapeDtypeStruct((h, tq, d), jnp.float32),
        ],
        interpret=INTERPRET,
    )(q, k, v, k_scale, v_scale, qpos)
    return out


__all__ = ["blocked_attention"]
