"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``ref_*`` computes the same math as its kernel with no pallas, no
blocking and no online accumulation, so pytest can ``assert_allclose``
kernel-vs-ref across shape/dtype sweeps (hypothesis drives the sweeps in
python/tests/test_kernels.py).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from ..fp8_numerics import (
    make_scale,
    qdq_native,
    quant_act_tilewise,
    quant_weight_blockwise,
)


def ref_blockwise_quant(
    w: jnp.ndarray,
    block: Tuple[int, int] = (128, 128),
    fmt: str = "e4m3",
    pow2_scale: bool = False,
):
    """Oracle for kernels.fp8_quant.blockwise_quant (values + scales)."""
    bm = min(block[0], w.shape[0])
    bn = min(block[1], w.shape[1])
    scale_fmt = "ue8m0" if pow2_scale else "fp32"
    deq = quant_weight_blockwise(w, (bm, bn), fmt, scale_fmt, native=True)
    m, n = w.shape
    blocks = w.reshape(m // bm, bm, n // bn, bn)
    amax = jnp.max(jnp.abs(blocks), axis=(1, 3))
    scales = make_scale(amax, fmt, scale_fmt)
    return deq, scales


def ref_act_quant(
    x: jnp.ndarray, tile: int = 128, fmt: str = "e4m3",
    pow2_scale: bool = False,
):
    """Oracle for kernels.fp8_quant.act_quant."""
    tile = min(tile, x.shape[-1])
    scale_fmt = "ue8m0" if pow2_scale else "fp32"
    return quant_act_tilewise(x, tile, fmt, scale_fmt, native=True)


def ref_w8a8_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    block: Tuple[int, int, int] = (8, 128, 128),
    act_tile: int = 128,
    fmt: str = "e4m3",
    pow2_scale: bool = False,
):
    """Oracle for kernels.fp8_quant.w8a8_matmul.

    Quantizes w per (BK x BN) block and x per (1 x act_tile) tile exactly
    as the kernel does, then one dense f32 matmul.
    """
    m, k = x.shape
    _, n = w.shape
    _, bk, bn = block
    bk, bn = min(bk, k), min(bn, n)
    act_tile = min(act_tile, bk)
    scale_fmt = "ue8m0" if pow2_scale else "fp32"
    wq = quant_weight_blockwise(w, (bk, bn), fmt, scale_fmt, native=True)
    xq = quant_act_tilewise(x, act_tile, fmt, scale_fmt, native=True)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def ref_attention(
    q: jnp.ndarray,        # (H, TQ, D)
    k: jnp.ndarray,        # (H, TK, D)
    v: jnp.ndarray,        # (H, TK, D)
    k_scale: jnp.ndarray,  # (1, 1)
    v_scale: jnp.ndarray,  # (1, 1)
    qpos: jnp.ndarray,     # (H, 1) int32 per-head first-query position
    *,
    causal: bool = True,
    fp8_kv: bool = False,
    fp8_attn: bool = False,
):
    """Oracle for kernels.attention.blocked_attention (dense softmax)."""
    if fp8_kv:
        ks = k_scale[0, 0]
        vs = v_scale[0, 0]
        k = qdq_native(k / ks) * ks
        v = qdq_native(v / vs) * vs
    if fp8_attn:
        q = qdq_native(q)
    d = q.shape[-1]
    s = jnp.einsum("hqd,hkd->hqk", q, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(d))
    if causal:
        tq, tk = s.shape[1], s.shape[2]
        qp = qpos[:, 0][:, None, None] + jnp.arange(tq)[None, :, None]
        kp = jnp.arange(tk)[None, None, :]
        s = jnp.where(kp <= qp, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    if fp8_attn:
        p = qdq_native(p)
    return jnp.einsum("hqk,hkd->hqd", p, v) / jnp.maximum(
        jnp.sum(p, axis=-1, keepdims=True), 1e-30
    )


__all__ = [
    "ref_blockwise_quant",
    "ref_act_quant",
    "ref_w8a8_matmul",
    "ref_attention",
]
