# L1: Pallas kernels for the paper's compute hot-spots.
from .fp8_quant import act_quant, blockwise_quant, w8a8_matmul  # noqa: F401
from .attention import blocked_attention  # noqa: F401
