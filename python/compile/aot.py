"""AOT compiler: lower every model entrypoint to HLO text + manifest.json.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
runtime (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (``python -m compile.aot --out ../artifacts``):
  artifacts/<entry>.hlo.txt      one per entrypoint x precision variant
  artifacts/manifest.json        the Rust<->Python ABI: model configs,
                                 param specs, entrypoint signatures
  artifacts/params_<arch>.bin    deterministic initial weights (f32 LE,
                                 param_spec order) so Rust and tests start
                                 from identical policies

Incremental: ``--only <substring>`` restricts which entrypoints are
re-lowered; the Makefile treats the whole directory as one target.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# ---------------------------------------------------------------------------
# Experiment-scale constants (the Rust side reads these from the manifest)
# ---------------------------------------------------------------------------

B_ROLLOUT = 32     # decode micro-batch rows in the engine
PROMPT_LEN = 16    # padded prompt length for prefill
B_TRAIN = 64       # (prompt x sample) rows per DAPO update
T_TRAIN = 64       # padded full-sequence length for training (== max_seq)

DENSE = M.ModelConfig(
    vocab=32, d_model=128, n_layers=4, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=256, moe=False, max_seq=64,
)
MOE = M.ModelConfig(
    vocab=32, d_model=128, n_layers=2, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=256, moe=True, n_experts=8, top_k=2, d_expert=128,
    max_seq=64,
)
ARCHS = {"dense": DENSE, "moe": MOE}

ROLLOUT_BY_ARCH = {
    "dense": ["bf16", "fp8lin", "kvfp8", "fullfp8", "fp8lin_ue8m0"],
    "moe": ["bf16", "fp8lin", "fp8lin_rfp8", "fp8lin_rfp32",
            "fp8lin_ue8m0", "fullfp8"],
}
TRAIN_BY_ARCH = {
    "dense": ["bf16", "fp8hybrid", "fp8e4m3"],
    "moe": ["bf16", "fp8hybrid", "fp8e4m3", "fp8hybrid_ue8m0"],
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dt(dtype) -> str:
    return {"float32": "f32", "int32": "s32"}[np.dtype(dtype).name]


def _sig(specs):
    return [
        {"shape": list(s.shape), "dtype": _dt(s.dtype)} for s in specs
    ]


def _param_specs(cfg):
    return [_spec(shape) for _, shape in M.param_spec(cfg)]


def build_entrypoints(arch: str, cfg: M.ModelConfig):
    """Yield (name, fn, extra_input_specs, n_param_blocks) tuples.

    Every entrypoint takes the flat param list first (possibly repeated
    for optimizer state), then the extras listed here.
    """
    pspecs = _param_specs(cfg)
    npar = len(pspecs)
    kv_shape = (cfg.n_layers, B_ROLLOUT, cfg.n_kv_heads, cfg.max_seq,
                cfg.d_head)

    entries = []
    for vname in ROLLOUT_BY_ARCH[arch]:
        rv = M.ROLLOUT_VARIANTS[vname]
        entries.append((
            f"{arch}_prefill_{vname}",
            M.make_prefill(cfg, rv, B_ROLLOUT, PROMPT_LEN),
            pspecs + [
                _spec((B_ROLLOUT, PROMPT_LEN), jnp.int32),
                _spec((1, 1)), _spec((1, 1)),
            ],
            dict(kind="prefill", arch=arch, variant=vname),
        ))
        entries.append((
            f"{arch}_decode_{vname}",
            M.make_decode(cfg, rv, B_ROLLOUT),
            pspecs + [
                _spec(kv_shape), _spec(kv_shape),
                _spec((B_ROLLOUT, 1), jnp.int32),
                _spec((B_ROLLOUT, 1), jnp.int32),
                _spec((1, 1)), _spec((1, 1)),
            ],
            dict(kind="decode", arch=arch, variant=vname),
        ))
    for vname in TRAIN_BY_ARCH[arch]:
        tv = M.TRAIN_VARIANTS[vname]
        entries.append((
            f"{arch}_train_{vname}",
            M.make_train_step(cfg, tv, B_TRAIN, T_TRAIN),
            pspecs * 3 + [
                _spec((1, 1)),                                  # step
                _spec((B_TRAIN, T_TRAIN), jnp.int32),           # tokens
                _spec((B_TRAIN, T_TRAIN - 1)),                  # mask
                _spec((B_TRAIN, T_TRAIN - 1)),                  # adv
                _spec((B_TRAIN, T_TRAIN - 1)),                  # rollout_logp
                _spec((1, 4)),                                  # hp
            ],
            dict(kind="train", arch=arch, variant=vname),
        ))
    entries.append((
        f"{arch}_logprobs_bf16",
        M.make_logprobs(cfg, M.TRAIN_VARIANTS["bf16"], B_TRAIN, T_TRAIN),
        pspecs + [_spec((B_TRAIN, T_TRAIN), jnp.int32)],
        dict(kind="logprobs", arch=arch, variant="bf16"),
    ))
    entries.append((
        f"{arch}_calibrate",
        M.make_calibrate(cfg, B_TRAIN, T_TRAIN),
        pspecs + [_spec((B_TRAIN, T_TRAIN), jnp.int32)],
        dict(kind="calibrate", arch=arch, variant="bf16"),
    ))
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default="", help="substring filter")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "version": 1,
        "constants": {
            "b_rollout": B_ROLLOUT,
            "prompt_len": PROMPT_LEN,
            "b_train": B_TRAIN,
            "t_train": T_TRAIN,
            "metric_names": M.METRIC_NAMES,
        },
        "models": {},
        "entrypoints": [],
    }

    for arch, cfg in ARCHS.items():
        manifest["models"][arch] = {
            "config": dataclasses.asdict(cfg),
            "params": [
                {"name": n, "shape": list(s)} for n, s in M.param_spec(cfg)
            ],
        }
        # deterministic initial weights
        params = M.init_params(cfg, jax.random.PRNGKey(42))
        flat = M.flatten_params(cfg, params)
        bin_path = os.path.join(args.out, f"params_{arch}.bin")
        with open(bin_path, "wb") as f:
            for a in flat:
                f.write(np.asarray(a, dtype="<f4").tobytes())

        for name, fn, specs, meta in build_entrypoints(arch, cfg):
            out_path = os.path.join(args.out, f"{name}.hlo.txt")
            entry = dict(
                name=name,
                file=f"{name}.hlo.txt",
                inputs=_sig(specs),
                **meta,
            )
            manifest["entrypoints"].append(entry)
            if args.only and args.only not in name:
                continue
            t0 = time.time()
            # keep_unused: entrypoints like `calibrate` ignore some params
            # (lm_head, ln_f); the Rust ABI passes the full flat list, so
            # unused parameters must survive lowering
            lowered = jax.jit(fn, keep_unused=True).lower(*specs)
            text = to_hlo_text(lowered)
            with open(out_path, "w") as f:
                f.write(text)
            print(
                f"[aot] {name}: {len(text) / 1e6:.2f} MB "
                f"({time.time() - t0:.1f}s)"
            )

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest with {len(manifest['entrypoints'])} entrypoints")


if __name__ == "__main__":
    main()
