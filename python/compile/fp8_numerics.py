"""FP8 numerics for the FP8-RL stack (L2 helpers, build-time only).

Implements the paper's quantization primitives in jnp:

* E4M3 / E5M2 quantize-dequantize ("fake quant") — both a *native* path
  (``jnp.float8_e4m3fn`` casts, which XLA lowers to ``f8e4m3fn`` converts
  the old runtime executes fine) and a *pure-f32 emulation* path used as a
  cross-checked oracle. The native cast maps overflow to NaN, while FP8
  hardware (and the paper's stack) saturates, so every native cast is
  preceded by an explicit clip to the format's max finite value.
* Blockwise weight quantization with 128x128 blocks (paper 2.1.1 /
  DeepSeek-V3 recipe) and per-(1x128)-tile dynamic activation
  quantization.
* Scale formats: FP32 (arbitrary) vs UE8M0 (power-of-2) per the Fig 12
  ablation.

Everything here is shape-polymorphic jnp so it can be traced into the AOT
artifacts; nothing imports torch or runs at serving time.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Format constants (Micikevicius et al., FP8 Formats for Deep Learning)
# ---------------------------------------------------------------------------

E4M3_MAX = 448.0  # S.1111.110 -> 448; 1111.111 is NaN in the *fn* variant
E5M2_MAX = 57344.0  # S.11110.11 -> 57344; 11111.xx are inf/NaN
E4M3_MIN_NORMAL = 2.0 ** -6
E5M2_MIN_NORMAL = 2.0 ** -14
E4M3_MIN_SUBNORMAL = 2.0 ** -9  # 2^-6 * 2^-3
E5M2_MIN_SUBNORMAL = 2.0 ** -16

_FMT = {
    "e4m3": dict(max=E4M3_MAX, mant=3, min_exp=-6, dtype=jnp.float8_e4m3fn),
    "e5m2": dict(max=E5M2_MAX, mant=2, min_exp=-14, dtype=jnp.float8_e5m2),
}


def fp8_max(fmt: str) -> float:
    """Largest finite magnitude representable in ``fmt``."""
    return _FMT[fmt]["max"]


# ---------------------------------------------------------------------------
# Quantize-dequantize (fake quant)
# ---------------------------------------------------------------------------


def qdq_native(x: jnp.ndarray, fmt: str = "e4m3") -> jnp.ndarray:
    """Round-trip ``x`` through FP8 using XLA's native f8 converts.

    Saturating (clips to the max finite value first, as FP8 tensor-core
    hardware does) and round-to-nearest-even, matching ml_dtypes.
    """
    f = _FMT[fmt]
    clipped = jnp.clip(x, -f["max"], f["max"])
    return clipped.astype(f["dtype"]).astype(x.dtype)


def qdq_emulated(x: jnp.ndarray, fmt: str = "e4m3") -> jnp.ndarray:
    """Pure-f32 emulation of saturating FP8 round-trip (the oracle).

    Uses the classic add-subtract rounding trick: for a value with
    exponent e, the FP8 ulp is 2^(e - mant); adding then subtracting a
    large constant of magnitude 2^(e - mant + 23) forces f32's
    round-to-nearest-even at exactly the FP8 precision. Subnormals fall
    out naturally by flooring the exponent at the format's min_exp.
    """
    f = _FMT[fmt]
    mant = f["mant"]
    min_exp = f["min_exp"]
    xf = x.astype(jnp.float32)
    ax = jnp.abs(xf)
    clipped = jnp.clip(ax, 0.0, f["max"])
    # exponent of the value, floored at min_exp (subnormal range)
    safe = jnp.maximum(clipped, 1e-45)
    e = jnp.floor(jnp.log2(safe))
    # log2 can land on the wrong side for exact powers of two, correct it
    e = jnp.where(2.0 ** e > safe, e - 1.0, e)
    e = jnp.where(2.0 ** (e + 1.0) <= safe, e + 1.0, e)
    e = jnp.maximum(e, float(min_exp))
    ulp = 2.0 ** (e - mant)
    # round-half-even at the fp8 grid
    q = jnp.round(clipped / ulp)
    # round() rounds half away from zero in jnp? jnp.round is half-even. good.
    rounded = q * ulp
    # rounding can bump into the next binade where the grid is coarser;
    # that value is still representable, so no fixup needed. Saturate:
    rounded = jnp.minimum(rounded, f["max"])
    out = jnp.sign(xf) * rounded
    out = jnp.where(ax == 0.0, 0.0, out)
    return out.astype(x.dtype)


def qdq(x: jnp.ndarray, fmt: str = "e4m3", native: bool = True) -> jnp.ndarray:
    return qdq_native(x, fmt) if native else qdq_emulated(x, fmt)


# ---------------------------------------------------------------------------
# Scale formats (Fig 12 ablation)
# ---------------------------------------------------------------------------


def scale_fp32(amax: jnp.ndarray, fmt: str = "e4m3") -> jnp.ndarray:
    """Arbitrary FP32 scale: amax maps to the format's max value."""
    return jnp.maximum(amax, 1e-12) / fp8_max(fmt)


def scale_ue8m0(amax: jnp.ndarray, fmt: str = "e4m3") -> jnp.ndarray:
    """Power-of-2 (UE8M0) scale: ceil to the next 2^k so no overflow."""
    s = scale_fp32(amax, fmt)
    return 2.0 ** jnp.ceil(jnp.log2(s))


def make_scale(amax: jnp.ndarray, fmt: str, scale_fmt: str) -> jnp.ndarray:
    if scale_fmt == "fp32":
        return scale_fp32(amax, fmt)
    if scale_fmt == "ue8m0":
        return scale_ue8m0(amax, fmt)
    raise ValueError(f"unknown scale format {scale_fmt!r}")


# ---------------------------------------------------------------------------
# Blockwise / tilewise quantization
# ---------------------------------------------------------------------------


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    m, n = x.shape
    pm = (-m) % mult0
    pn = (-n) % mult1
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def block_amax(w: jnp.ndarray, block: Tuple[int, int] = (128, 128)) -> jnp.ndarray:
    """Per-block max-abs of a 2-D weight matrix (padded blocks)."""
    bm, bn = block
    wp = _pad_to(w, bm, bn)
    m, n = wp.shape
    blocks = wp.reshape(m // bm, bm, n // bn, bn)
    return jnp.max(jnp.abs(blocks), axis=(1, 3))


def quant_weight_blockwise(
    w: jnp.ndarray,
    block: Tuple[int, int] = (128, 128),
    fmt: str = "e4m3",
    scale_fmt: str = "fp32",
    native: bool = True,
) -> jnp.ndarray:
    """Blockwise fake-quant of a weight matrix (paper eq. 1).

    Returns the dequantized f32 weights (what the FP8 GEMM 'sees'); the
    Rust side (`fp8::blockwise`) produces the actual (codes, scales) pair
    for the weight-sync pipeline, and the two agree bit-exactly.
    """
    bm, bn = block
    orig_m, orig_n = w.shape
    wp = _pad_to(w, bm, bn)
    m, n = wp.shape
    amax = block_amax(w, block)
    scale = make_scale(amax, fmt, scale_fmt)
    scale_full = jnp.repeat(jnp.repeat(scale, bm, axis=0), bn, axis=1)
    q = qdq(wp / scale_full, fmt, native=native) * scale_full
    return q[:orig_m, :orig_n]


def quant_act_tilewise(
    x: jnp.ndarray,
    tile: int = 128,
    fmt: str = "e4m3",
    scale_fmt: str = "fp32",
    native: bool = True,
) -> jnp.ndarray:
    """Dynamic per-(1 x tile) activation fake-quant along the last axis."""
    shape = x.shape
    n = shape[-1]
    pn = (-n) % tile
    xp = jnp.pad(x.reshape(-1, n), ((0, 0), (0, pn)))
    r, npad = xp.shape
    tiles = xp.reshape(r, npad // tile, tile)
    amax = jnp.max(jnp.abs(tiles), axis=-1, keepdims=True)
    scale = make_scale(amax, fmt, scale_fmt)
    q = qdq(tiles / scale, fmt, native=native) * scale
    return q.reshape(r, npad)[:, :n].reshape(shape)


def quant_grad_blockwise(
    g: jnp.ndarray,
    fmt: str,
    block: Tuple[int, int] = (128, 128),
    scale_fmt: str = "fp32",
    native: bool = True,
) -> jnp.ndarray:
    """Backward-pass grad fake-quant (hybrid recipe: e5m2; pure: e4m3)."""
    g2 = g.reshape(-1, g.shape[-1])
    out = quant_weight_blockwise(g2, block, fmt, scale_fmt, native)
    return out.reshape(g.shape)


def tile_exceedance(
    g: jnp.ndarray, block: Tuple[int, int] = (128, 128)
) -> jnp.ndarray:
    """Fraction of blocks whose amax exceeds E4M3's range *relative to the
    block scale being pinned by outliers* — the paper's Fig 11 profiling
    metric: share of tiles where >some% of entries underflow to zero after
    E4M3 quantization at the block scale.

    We measure: fraction of tiles where the dynamic range amax/|median|
    exceeds E4M3's representable span (448 / 2^-9 would never trip, so the
    operative failure is *underflow*: entries smaller than the tile's
    smallest representable step get flushed to zero). Returns the fraction
    of tiles with >=50% of entries flushed, matching the paper's
    "up to 50% of gradient data lost" framing.
    """
    g2 = jnp.abs(g.reshape(-1, g.shape[-1]))
    bm, bn = block
    gp = _pad_to(g2, bm, bn)
    m, n = gp.shape
    blocks = gp.reshape(m // bm, bm, n // bn, bn)
    amax = jnp.max(blocks, axis=(1, 3), keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / E4M3_MAX
    # smallest positive e4m3 (subnormal) times scale = flush threshold
    thresh = scale * E4M3_MIN_SUBNORMAL
    nonzero = blocks > 0.0
    flushed = jnp.logical_and(nonzero, blocks < thresh)
    frac = jnp.sum(flushed, axis=(1, 3)) / jnp.maximum(
        jnp.sum(nonzero, axis=(1, 3)), 1
    )
    return frac  # per-block flushed fraction


__all__ = [
    "E4M3_MAX",
    "E5M2_MAX",
    "fp8_max",
    "qdq",
    "qdq_native",
    "qdq_emulated",
    "scale_fp32",
    "scale_ue8m0",
    "make_scale",
    "block_amax",
    "quant_weight_blockwise",
    "quant_act_tilewise",
    "quant_grad_blockwise",
    "tile_exceedance",
]
