"""L2: the policy model — a Qwen3-family tiny transformer, dense and MoE.

Everything the Rust coordinator executes is defined here and lowered by
``aot.py``:

* ``prefill``     — process a padded prompt batch, fill the KV cache,
                    return per-position logits (rollout path, pallas
                    attention, precision per `RolloutVariant`).
* ``decode_step`` — one generation step over the dense KV cache (rollout
                    hot path; pallas attention + pallas W8A8 linears when
                    FP8).
* ``logprobs``    — teacher-forced token logprobs + entropy under the
                    trainer's precision (pure jnp — the *different kernel
                    implementation* is deliberate: it reproduces the
                    paper's kernel-level train/inference mismatch).
* ``train_step``  — one DAPO update (token-level policy-gradient loss with
                    clip-higher, token-level TIS correction, Adam) with the
                    FP8-training fake-quant recipes (hybrid E4M3/E5M2 or
                    pure E4M3) and gradient tile-exceedance profiling.
* ``calibrate``   — K/V amax scan for QKV-scale recalibration (both the
                    inference-side and trainer-side strategies call this
                    on different data — paper Fig 7).

Architecture follows Qwen3: RMSNorm, RoPE, GQA attention, SwiGLU MLP,
optional top-k-routed MoE with softmax gating. All math f32; "BF16"
paths round through bfloat16 to model BF16 compute error.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import fp8_numerics as F8
from .kernels.attention import blocked_attention
from .kernels.fp8_quant import w8a8_matmul

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of the policy. ``moe=False`` -> dense (Qwen3-8B
    stand-in), ``moe=True`` -> top-k routed MoE (Qwen3-30B-A3B stand-in)."""

    vocab: int = 32
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    d_head: int = 32
    d_ff: int = 256
    moe: bool = False
    n_experts: int = 8
    top_k: int = 2
    d_expert: int = 128
    max_seq: int = 64
    rope_base: float = 10000.0

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head


@dataclasses.dataclass(frozen=True)
class RolloutVariant:
    """Precision of the rollout (inference) path — paper §2.1/§2.3."""

    name: str = "bf16"
    fp8_linear: bool = False      # W8A8 blockwise linears
    fp8_kv: bool = False          # FP8 KV-cache storage
    fp8_attn: bool = False        # FP8 attention (Q & probabilities)
    router: str = "bf16"          # 'fp8' | 'bf16' | 'fp32' (MoE only)
    pow2_scale: bool = False      # UE8M0 scales instead of FP32


@dataclasses.dataclass(frozen=True)
class TrainVariant:
    """Precision of the training path — paper §2.4."""

    name: str = "bf16"
    fp8: bool = False
    bwd_fmt: str = "e5m2"         # 'e5m2' (hybrid) | 'e4m3' (pure recipe)
    router: str = "fp32"          # trainer router precision
    pow2_scale: bool = False


# The named variants the experiment figures use.
ROLLOUT_VARIANTS: Dict[str, RolloutVariant] = {
    v.name: v
    for v in [
        RolloutVariant("bf16"),
        RolloutVariant("fp8lin", fp8_linear=True),
        RolloutVariant("kvfp8", fp8_kv=True),
        RolloutVariant("fullfp8", fp8_linear=True, fp8_kv=True, fp8_attn=True),
        RolloutVariant("fp8lin_rfp8", fp8_linear=True, router="fp8"),
        RolloutVariant("fp8lin_rfp32", fp8_linear=True, router="fp32"),
        RolloutVariant("fp8lin_ue8m0", fp8_linear=True, pow2_scale=True),
    ]
}

TRAIN_VARIANTS: Dict[str, TrainVariant] = {
    v.name: v
    for v in [
        TrainVariant("bf16"),
        TrainVariant("fp8hybrid", fp8=True, bwd_fmt="e5m2"),
        TrainVariant("fp8e4m3", fp8=True, bwd_fmt="e4m3"),
        TrainVariant("fp8hybrid_ue8m0", fp8=True, bwd_fmt="e5m2",
                     pow2_scale=True),
    ]
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the Rust<->Python ABI for params."""
    d, q, kv, ff = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.d_ff
    spec: List[Tuple[str, Tuple[int, ...]]] = [("embed", (cfg.vocab, d))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1", (d,)),
            (p + "q_proj", (d, q)),
            (p + "k_proj", (d, kv)),
            (p + "v_proj", (d, kv)),
            (p + "o_proj", (q, d)),
            (p + "ln2", (d,)),
        ]
        if cfg.moe:
            spec.append((p + "router", (d, cfg.n_experts)))
            for e in range(cfg.n_experts):
                ep = p + f"expert{e}."
                spec += [
                    (ep + "gate_proj", (d, cfg.d_expert)),
                    (ep + "up_proj", (d, cfg.d_expert)),
                    (ep + "down_proj", (cfg.d_expert, d)),
                ]
        else:
            spec += [
                (p + "gate_proj", (d, ff)),
                (p + "up_proj", (d, ff)),
                (p + "down_proj", (ff, d)),
            ]
    spec += [("ln_f", (d,)), ("lm_head", (d, cfg.vocab))]
    return spec


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    """Scaled-normal init; norm gains at 1."""
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "embed":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            std = shape[0] ** -0.5
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def flatten_params(cfg: ModelConfig, params: Dict[str, jnp.ndarray]):
    return [params[n] for n, _ in param_spec(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> Dict[str, jnp.ndarray]:
    return {n: a for (n, _), a in zip(param_spec(cfg), flat)}


# ---------------------------------------------------------------------------
# Precision helpers
# ---------------------------------------------------------------------------


def _bf16_round(x: jnp.ndarray) -> jnp.ndarray:
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def rollout_linear(x, w, rv: RolloutVariant):
    """Linear layer on the rollout path (x 2-D).

    FP8: the pallas W8A8 blockwise kernel (weights one scale per 128x128
    block, activations per 1x128 tile — paper §2.1.1).
    BF16: operands and result rounded through bfloat16 (models BF16 tensor
    cores; the trainer's f32 math then differs slightly — the paper's
    baseline-level train/inference mismatch).
    """
    if rv.fp8_linear:
        m, k = x.shape
        # §Perf iteration 2: larger M-blocks cut interpret-mode grid
        # steps 4x at decode batch 32 (TPU would keep bm at the MXU's 8)
        bm = 32 if m % 32 == 0 else (8 if m % 8 == 0 else 1)
        bk = 128 if k % 128 == 0 else k
        bn = 128 if w.shape[1] % 128 == 0 else w.shape[1]
        return w8a8_matmul(
            x, w, block=(bm, bk, bn), act_tile=min(128, bk),
            pow2_scale=rv.pow2_scale,
        )
    return _bf16_round(_bf16_round(x) @ _bf16_round(w))


def router_logits(x, w, precision: str):
    """MoE router matmul at configurable precision (Fig 6 ablation)."""
    if precision == "fp8":
        xq = F8.quant_act_tilewise(x, min(128, x.shape[-1]), "e4m3", "fp32")
        wq = F8.quant_weight_blockwise(
            w, (min(128, w.shape[0]), min(128, w.shape[1])), "e4m3", "fp32"
        )
        return xq @ wq
    if precision == "bf16":
        return _bf16_round(_bf16_round(x) @ _bf16_round(w))
    return x @ w  # fp32


# --- FP8 training linear (fake-quant fwd E4M3, bwd per recipe) -------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def fp8_train_linear(x, w, bwd_fmt: str, pow2_scale: bool):
    return _fp8_fwd_value(x, w, pow2_scale)


def _fp8_fwd_value(x, w, pow2_scale):
    scale_fmt = "ue8m0" if pow2_scale else "fp32"
    xq = F8.quant_act_tilewise(x, min(128, x.shape[-1]), "e4m3", scale_fmt)
    wq = F8.quant_weight_blockwise(
        w, (min(128, w.shape[0]), min(128, w.shape[1])), "e4m3", scale_fmt
    )
    return xq @ wq


def _fp8_fwd(x, w, bwd_fmt, pow2_scale):
    return _fp8_fwd_value(x, w, pow2_scale), (x, w)


def _fp8_bwd(bwd_fmt, pow2_scale, res, g):
    """Backward GEMMs with the grad-output quantized to ``bwd_fmt`` —
    E5M2 (hybrid recipe) or E4M3 (DeepSeek-V3-style pure recipe)."""
    x, w = res
    scale_fmt = "ue8m0" if pow2_scale else "fp32"
    gq = F8.quant_grad_blockwise(
        g, bwd_fmt, (min(128, g.shape[0]), min(128, g.shape[-1])), scale_fmt
    )
    dx = gq @ w.T
    dw = x.T @ gq
    return dx, dw


fp8_train_linear.defvjp(_fp8_fwd, _fp8_bwd)


def train_linear(x, w, tv: TrainVariant):
    if tv.fp8:
        shp = x.shape
        x2 = x.reshape(-1, shp[-1])
        out = fp8_train_linear(x2, w, tv.bwd_fmt, tv.pow2_scale)
        return out.reshape(*shp[:-1], w.shape[1])
    return x @ w  # f32 master math = "BF16 mixed precision" stand-in


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gamma


def rope(x, pos, base: float):
    """Rotary embedding. x: (..., T, H, D), pos: (..., T) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., :, None, None].astype(jnp.float32) * freqs  # (...,T,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _repeat_kv(x, n_rep: int):
    """(B, T, Hkv, D) -> (B, T, Hkv*n_rep, D) for GQA."""
    if n_rep == 1:
        return x
    b, t, h, d = x.shape
    return jnp.broadcast_to(
        x[:, :, :, None, :], (b, t, h, n_rep, d)
    ).reshape(b, t, h * n_rep, d)


def swiglu(x, gate_w, up_w, down_w, linear):
    g = linear(x, gate_w)
    u = linear(x, up_w)
    return linear(jax.nn.silu(g) * u, down_w)


def _topk_oldxla(logits, k: int):
    """Top-k via iterative argmax + mask. `jax.lax.top_k` lowers to a
    Sort carrying a `largest` attribute that xla_extension 0.5.1's HLO
    text parser rejects; this uses only argmax/select/iota (k is 2)."""
    n, v = logits.shape
    x = logits
    vals, idxs = [], []
    for _ in range(k):
        i = jnp.argmax(x, axis=-1)  # (N,)
        onehot = i[:, None] == jnp.arange(v)[None, :]
        vals.append(jnp.sum(jnp.where(onehot, x, 0.0), axis=-1))
        idxs.append(i)
        x = jnp.where(onehot, -jnp.inf, x)
    return jnp.stack(vals, -1), jnp.stack(idxs, -1)


def moe_block(x, params, prefix, cfg: ModelConfig, linear, router_prec):
    """Top-k softmax-gated MoE. x: (N, d). Dense expert compute (tiny
    models) with discrete top-k routing — precision really flips routing."""
    logits = router_logits(x, params[prefix + "router"], router_prec)
    topv, topi = _topk_oldxla(logits, cfg.top_k)  # (N, k)
    gates = jax.nn.softmax(topv, axis=-1)
    out = jnp.zeros((x.shape[0], cfg.d_model), jnp.float32)
    for e in range(cfg.n_experts):
        ep = prefix + f"expert{e}."
        y = swiglu(
            x, params[ep + "gate_proj"], params[ep + "up_proj"],
            params[ep + "down_proj"], linear,
        )
        w_e = jnp.sum(jnp.where(topi == e, gates, 0.0), axis=-1)  # (N,)
        out = out + y * w_e[:, None]
    return out, logits


# ---------------------------------------------------------------------------
# Rollout path (pallas attention; KV cache as explicit state)
# ---------------------------------------------------------------------------
# KV cache layout: k_cache, v_cache: (L, B, Hkv, Tmax, Dh) f32. FP8-KV
# variants store fake-quant values (bit-identical to u8 codes x scale; the
# Rust engine accounts capacity at 1 byte/elem).


def _attn_rollout(cfg, rv, q, k_all, v_all, pos, kscale, vscale, tq):
    """q: (B, TQ, Hq, Dh); k_all/v_all: (B, Hkv, Tmax, Dh); pos: (B,)."""
    b = q.shape[0]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    # fold batch into heads for the pallas kernel
    qh = q.transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, tq, cfg.d_head)
    kh = jnp.broadcast_to(
        k_all[:, :, None],
        (b, cfg.n_kv_heads, n_rep, cfg.max_seq, cfg.d_head),
    ).reshape(b * cfg.n_heads, cfg.max_seq, cfg.d_head)
    vh = jnp.broadcast_to(
        v_all[:, :, None],
        (b, cfg.n_kv_heads, n_rep, cfg.max_seq, cfg.d_head),
    ).reshape(b * cfg.n_heads, cfg.max_seq, cfg.d_head)
    qpos = jnp.repeat(pos, cfg.n_heads).reshape(b * cfg.n_heads, 1)
    out = blocked_attention(
        qh, kh, vh,
        kscale.reshape(1, 1), vscale.reshape(1, 1),
        qpos.astype(jnp.int32),
        causal=True,
        kv_block=min(64, cfg.max_seq),
        fp8_kv=rv.fp8_kv,
        fp8_attn=rv.fp8_attn,
    )
    return out.reshape(b, cfg.n_heads, tq, cfg.d_head).transpose(0, 2, 1, 3)


def _rollout_block(cfg, rv, params, i, x, k_cache, v_cache, pos, tq,
                   kscale, vscale):
    """One transformer layer on the rollout path.

    x: (B, TQ, d); writes K/V at positions pos..pos+TQ-1; returns new x
    and this layer's updated K/V planes.
    """
    p = f"layer{i}."
    b = x.shape[0]

    def lin(a, w):
        out = rollout_linear(a.reshape(-1, a.shape[-1]), w, rv)
        return out.reshape(*a.shape[:-1], w.shape[1])

    h = rmsnorm(x, params[p + "ln1"])
    q = lin(h, params[p + "q_proj"]).reshape(b, tq, cfg.n_heads, cfg.d_head)
    k = lin(h, params[p + "k_proj"]).reshape(b, tq, cfg.n_kv_heads, cfg.d_head)
    v = lin(h, params[p + "v_proj"]).reshape(b, tq, cfg.n_kv_heads, cfg.d_head)
    tpos = pos[:, None] + jnp.arange(tq)[None, :]  # (B, TQ)
    q = rope(q, tpos, cfg.rope_base)
    k = rope(k, tpos, cfg.rope_base)

    if rv.fp8_kv:
        # quantize at write time against the per-step recalibrated scales
        k = F8.qdq(k / kscale) * kscale
        v = F8.qdq(v / vscale) * vscale

    # scatter K/V into the cache at per-row positions (one-hot overwrite)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, TQ, Dh)
    vt = v.transpose(0, 2, 1, 3)
    onehot = (
        tpos[:, None, :, None]
        == jnp.arange(cfg.max_seq)[None, None, None, :]
    ).astype(jnp.float32)  # (B, 1, TQ, Tmax)
    write_k = jnp.einsum("bhqd,bxqt->bhtd", kt, onehot)
    write_v = jnp.einsum("bhqd,bxqt->bhtd", vt, onehot)
    mask_t = jnp.max(onehot, axis=2)[:, :, :, None]  # (B, 1, Tmax, 1)
    new_k = k_cache[i] * (1.0 - mask_t) + write_k
    new_v = v_cache[i] * (1.0 - mask_t) + write_v

    attn = _attn_rollout(cfg, rv, q, new_k, new_v, pos, kscale, vscale, tq)
    attn = attn.reshape(b, tq, cfg.q_dim)
    x = x + lin(attn, params[p + "o_proj"])

    h2 = rmsnorm(x, params[p + "ln2"])
    if cfg.moe:
        flat = h2.reshape(-1, cfg.d_model)
        mout, _ = moe_block(
            flat, params, p, cfg,
            lambda a, w: rollout_linear(a, w, rv), rv.router,
        )
        x = x + mout.reshape(b, tq, cfg.d_model)
    else:
        x = x + swiglu(
            h2, params[p + "gate_proj"], params[p + "up_proj"],
            params[p + "down_proj"], lin,
        )
    return x, new_k, new_v


def rollout_forward(cfg, rv, params, tokens, pos, k_cache, v_cache,
                    kscale, vscale):
    """Shared prefill/decode forward. tokens: (B, TQ); pos: (B,) start
    positions. Returns (logits (B, TQ, V), k_cache', v_cache')."""
    b, tq = tokens.shape
    x = params["embed"][tokens]  # (B, TQ, d)
    new_ks, new_vs = [], []
    for i in range(cfg.n_layers):
        x, nk, nv = _rollout_block(
            cfg, rv, params, i, x, k_cache, v_cache, pos, tq, kscale, vscale
        )
        new_ks.append(nk)
        new_vs.append(nv)
    x = rmsnorm(x, params["ln_f"])
    # lm_head stays high precision (paper: excluded from quantization)
    logits = _bf16_round(x.reshape(-1, cfg.d_model) @ params["lm_head"])
    return (
        logits.reshape(b, tq, cfg.vocab),
        jnp.stack(new_ks),
        jnp.stack(new_vs),
    )


def make_prefill(cfg: ModelConfig, rv: RolloutVariant, batch: int,
                 prompt_len: int):
    """f(flat_params..., tokens (B,P) i32, kscale (1,1), vscale (1,1))
    -> (logits (B,P,V), k_cache, v_cache)."""

    def prefill(*args):
        n = len(param_spec(cfg))
        params = unflatten_params(cfg, args[:n])
        tokens, kscale, vscale = args[n], args[n + 1], args[n + 2]
        zeros = jnp.zeros(
            (cfg.n_layers, batch, cfg.n_kv_heads, cfg.max_seq, cfg.d_head),
            jnp.float32,
        )
        pos = jnp.zeros((batch,), jnp.int32)
        logits, kc, vc = rollout_forward(
            cfg, rv, params, tokens, pos, zeros, zeros,
            kscale[0, 0], vscale[0, 0],
        )
        return logits, kc, vc

    return prefill


def make_decode(cfg: ModelConfig, rv: RolloutVariant, batch: int):
    """f(flat_params..., k_cache, v_cache, tokens (B,1) i32, pos (B,1) i32,
    kscale (1,1), vscale (1,1)) -> (logits (B,V), k_cache', v_cache')."""

    def decode(*args):
        n = len(param_spec(cfg))
        params = unflatten_params(cfg, args[:n])
        k_cache, v_cache, tokens, pos, kscale, vscale = args[n:n + 6]
        logits, kc, vc = rollout_forward(
            cfg, rv, params, tokens, pos[:, 0], k_cache, v_cache,
            kscale[0, 0], vscale[0, 0],
        )
        return logits[:, 0], kc, vc

    return decode


# ---------------------------------------------------------------------------
# Trainer path (pure jnp, teacher-forced)
# ---------------------------------------------------------------------------


def train_forward(cfg: ModelConfig, tv: TrainVariant, params, tokens):
    """Teacher-forced forward. tokens: (B, T) -> logits (B, T, V).

    Deliberately a different implementation than the rollout path (dense
    causal attention, f32 math or FP8 fake-quant linears) — the kernel
    difference is the paper's residual mismatch source.
    """
    b, t = tokens.shape

    def lin(a, w):
        return train_linear(a, w, tv)

    x = params["embed"][tokens]
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    causal = jnp.tril(jnp.ones((t, t), bool))
    n_rep = cfg.n_heads // cfg.n_kv_heads
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rmsnorm(x, params[p + "ln1"])
        q = lin(h, params[p + "q_proj"]).reshape(b, t, cfg.n_heads, cfg.d_head)
        k = lin(h, params[p + "k_proj"]).reshape(
            b, t, cfg.n_kv_heads, cfg.d_head
        )
        v = lin(h, params[p + "v_proj"]).reshape(
            b, t, cfg.n_kv_heads, cfg.d_head
        )
        q = rope(q, pos, cfg.rope_base)
        k = rope(k, pos, cfg.rope_base)
        k = _repeat_kv(k, n_rep)
        v = _repeat_kv(v, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(
            jnp.float32(cfg.d_head)
        )
        s = jnp.where(causal[None, None], s, -1e30)
        a = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, t, cfg.q_dim)
        x = x + lin(attn, params[p + "o_proj"])
        h2 = rmsnorm(x, params[p + "ln2"])
        if cfg.moe:
            flat = h2.reshape(-1, cfg.d_model)
            mout, _ = moe_block(flat, params, p, cfg, lin, tv.router)
            x = x + mout.reshape(b, t, cfg.d_model)
        else:
            x = x + swiglu(
                h2, params[p + "gate_proj"], params[p + "up_proj"],
                params[p + "down_proj"], lin,
            )
    x = rmsnorm(x, params["ln_f"])
    return (x.reshape(-1, cfg.d_model) @ params["lm_head"]).reshape(
        b, t, cfg.vocab
    )


def token_logprobs_entropy(cfg, tv, params, tokens):
    """logp[b, t] = log p(tokens[b, t+1] | tokens[b, :t+1]); entropy of the
    predictive distribution at each position. Shapes (B, T-1)."""
    logits = train_forward(cfg, tv, params, tokens)  # (B, T, V)
    logz = jax.nn.logsumexp(logits, axis=-1, keepdims=True)
    logp_all = logits - logz
    nxt = tokens[:, 1:]
    lp = jnp.take_along_axis(logp_all[:, :-1], nxt[..., None], -1)[..., 0]
    probs = jnp.exp(logp_all)
    ent = -jnp.sum(probs * logp_all, axis=-1)[:, :-1]
    return lp, ent


def make_logprobs(cfg: ModelConfig, tv: TrainVariant, batch: int, t: int):
    """f(flat_params..., tokens (B,T) i32) -> (logp (B,T-1), ent (B,T-1))."""

    def logprobs(*args):
        n = len(param_spec(cfg))
        params = unflatten_params(cfg, args[:n])
        tokens = args[n]
        return token_logprobs_entropy(cfg, tv, params, tokens)

    return logprobs


# ---------------------------------------------------------------------------
# DAPO train step
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
CLIP_LOW, CLIP_HIGH = 0.2, 0.28  # DAPO clip-higher
GRAD_CLIP = 1.0

METRIC_NAMES = [
    "loss", "entropy", "kl_k1", "kl_k3", "tis_mean", "ratio_raw_mean",
    "grad_norm", "exceed_fc1", "exceed_other", "exceed_p99", "lr",
    "r12", "r13", "r14", "r15", "r16",
]


def dapo_loss(cfg, tv, params, tokens, mask, adv, rollout_logp, tis_c,
              ent_coef, mis_mode):
    """Token-level DAPO objective with importance-sampling rollout
    correction (paper eq. 2-3, §2.1.3) plus an entropy bonus (prevents
    early policy collapse at this scale).

    Two correction variants (paper: "token-level TIS/MIS variants"):
      * TIS (mis_mode <= 0): w = min(pi_old/pi_fp8, C) — clip the weight.
      * MIS (mis_mode > 0): mask out tokens whose raw ratio falls outside
        [1/C, C] entirely (IcePop-style masked IS) — unreliable tokens
        contribute nothing rather than a clipped amount.

    tokens (B,T) i32; mask/adv/rollout_logp (B,T-1) f32 aligned to the
    *predicted* token; tis_c scalar (<=0 disables the correction).
    """
    lp, ent = token_logprobs_entropy(cfg, tv, params, tokens)
    lp_old = jax.lax.stop_gradient(lp)  # one update/batch: pi_old == pi_theta
    ratio = jnp.exp(lp - lp_old)
    raw_w = jnp.exp(lp_old - rollout_logp)
    tis_w = jnp.where(
        mis_mode > 0.0,
        # MIS: keep weight 1 inside the trust band, 0 outside
        jnp.where(
            (raw_w <= tis_c) & (raw_w >= 1.0 / jnp.maximum(tis_c, 1e-6)),
            jnp.ones_like(raw_w),
            jnp.zeros_like(raw_w),
        ),
        # TIS: clipped weight
        jnp.minimum(raw_w, tis_c),
    )
    tis_w = jnp.where(tis_c > 0.0, tis_w, jnp.ones_like(raw_w))
    clipped = jnp.clip(ratio, 1.0 - CLIP_LOW, 1.0 + CLIP_HIGH)
    obj = jnp.minimum(ratio * adv, clipped * adv) * tis_w
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    mean_ent = jnp.sum(ent * mask) / denom
    loss = -jnp.sum(obj * mask) / denom - ent_coef * mean_ent
    # mismatch KL: D_KL(pi_fp8 || pi_theta) on pi_fp8 samples.
    # k1 = E[log(pi_fp8/pi_theta)]; k3 = E[(r-1) - log r], r = pi_theta/pi_fp8
    dlog = lp_old - rollout_logp  # log(pi_theta / pi_fp8)
    k1 = -jnp.sum(dlog * mask) / denom
    k3 = jnp.sum(((jnp.exp(dlog) - 1.0) - dlog) * mask) / denom
    aux = {
        "entropy": jnp.sum(ent * mask) / denom,
        "kl_k1": k1,
        "kl_k3": k3,
        "tis_mean": jnp.sum(tis_w * mask) / denom,
        "ratio_raw_mean": jnp.sum(raw_w * mask) / denom,
    }
    return loss, aux


def make_train_step(cfg: ModelConfig, tv: TrainVariant, batch: int, t: int):
    """f(flat_params..., m..., v..., step (1,1), tokens (B,T) i32,
    mask/adv/rollout_logp (B,T-1), hp (1,4)=[lr, tis_c, _, _])
    -> (flat_params'..., m'..., v'..., step', metrics (1,16)).

    metrics order: METRIC_NAMES.
    """
    names = [n for n, _ in param_spec(cfg)]
    n = len(names)

    def step_fn(*args):
        params = unflatten_params(cfg, args[:n])
        m_st = {nm: a for nm, a in zip(names, args[n:2 * n])}
        v_st = {nm: a for nm, a in zip(names, args[2 * n:3 * n])}
        step = args[3 * n][0, 0]
        tokens, mask, adv, rollout_logp, hp = args[3 * n + 1:3 * n + 6]
        lr, tis_c, ent_coef, mis_mode = (
            hp[0, 0], hp[0, 1], hp[0, 2], hp[0, 3],
        )

        (loss, aux), grads = jax.value_and_grad(
            lambda p: dapo_loss(
                cfg, tv, p, tokens, mask, adv, rollout_logp, tis_c,
                ent_coef, mis_mode,
            ),
            has_aux=True,
        )(params)

        # ---- gradient tile-exceedance profiling (Fig 11) ----
        fc1_fracs, other_fracs, fc1_maxes = [], [], []
        for name in names:
            g = grads[name]
            if g.ndim != 2:
                continue
            blk = (min(32, g.shape[0]), min(32, g.shape[1]))
            frac = F8.tile_exceedance(g, blk)
            if ("gate_proj" in name) or ("up_proj" in name):
                fc1_fracs.append(jnp.mean(frac))
                fc1_maxes.append(jnp.max(frac))
            else:
                other_fracs.append(jnp.mean(frac))
        ex_fc1 = (
            jnp.mean(jnp.stack(fc1_fracs)) if fc1_fracs else jnp.float32(0)
        )
        ex_other = (
            jnp.mean(jnp.stack(other_fracs)) if other_fracs else jnp.float32(0)
        )
        ex_p99 = (
            jnp.max(jnp.stack(fc1_maxes)) if fc1_maxes else jnp.float32(0)
        )

        # ---- global grad-norm clip + Adam ----
        gnorm = jnp.sqrt(sum(jnp.sum(grads[nm] ** 2) for nm in names))
        clip_coef = jnp.minimum(1.0, GRAD_CLIP / (gnorm + 1e-12))
        t_new = step + 1.0
        bc1 = 1.0 - ADAM_B1 ** t_new
        bc2 = 1.0 - ADAM_B2 ** t_new
        new_p, new_m, new_v = [], [], []
        for nm in names:
            g = grads[nm] * clip_coef
            m_new = ADAM_B1 * m_st[nm] + (1 - ADAM_B1) * g
            v_new = ADAM_B2 * v_st[nm] + (1 - ADAM_B2) * g * g
            upd = lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + ADAM_EPS)
            new_p.append(params[nm] - upd)
            new_m.append(m_new)
            new_v.append(v_new)

        metrics = jnp.stack([
            loss, aux["entropy"], aux["kl_k1"], aux["kl_k3"],
            aux["tis_mean"], aux["ratio_raw_mean"], gnorm,
            ex_fc1, ex_other, ex_p99, lr,
            jnp.float32(0), jnp.float32(0), jnp.float32(0),
            jnp.float32(0), jnp.float32(0),
        ]).reshape(1, 16)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (
            jnp.array([[0.0]], jnp.float32) + t_new.reshape(1, 1),
            metrics,
        )

    return step_fn


# ---------------------------------------------------------------------------
# QKV scale calibration (paper §2.3.1 — both strategies call this)
# ---------------------------------------------------------------------------


def make_calibrate(cfg: ModelConfig, batch: int, t: int):
    """f(flat_params..., tokens (B,T) i32) -> (kscale (1,1), vscale (1,1)).

    Runs a high-precision forward tracking per-layer K/V amax and returns
    the recalibrated global KV scales for the next rollout. The
    inference-side strategy feeds rollout prompts; the trainer-side
    strategy feeds training-batch data (prompts + previous responses)."""

    def calibrate(*args):
        n = len(param_spec(cfg))
        params = unflatten_params(cfg, args[:n])
        tokens = args[n]
        b, tt = tokens.shape
        x = params["embed"][tokens]
        pos = jnp.broadcast_to(jnp.arange(tt)[None], (b, tt))
        causal = jnp.tril(jnp.ones((tt, tt), bool))
        n_rep = cfg.n_heads // cfg.n_kv_heads
        k_amax = jnp.float32(0)
        v_amax = jnp.float32(0)
        for i in range(cfg.n_layers):
            p = f"layer{i}."
            h = rmsnorm(x, params[p + "ln1"])
            q = (h @ params[p + "q_proj"]).reshape(
                b, tt, cfg.n_heads, cfg.d_head
            )
            k = (h @ params[p + "k_proj"]).reshape(
                b, tt, cfg.n_kv_heads, cfg.d_head
            )
            v = (h @ params[p + "v_proj"]).reshape(
                b, tt, cfg.n_kv_heads, cfg.d_head
            )
            q = rope(q, pos, cfg.rope_base)
            k = rope(k, pos, cfg.rope_base)
            k_amax = jnp.maximum(k_amax, jnp.max(jnp.abs(k)))
            v_amax = jnp.maximum(v_amax, jnp.max(jnp.abs(v)))
            kr, vr = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
            s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / jnp.sqrt(
                jnp.float32(cfg.d_head)
            )
            s = jnp.where(causal[None, None], s, -1e30)
            a = jax.nn.softmax(s, axis=-1)
            attn = jnp.einsum("bhqk,bkhd->bqhd", a, vr).reshape(
                b, tt, cfg.q_dim
            )
            x = x + attn @ params[p + "o_proj"]
            h2 = rmsnorm(x, params[p + "ln2"])
            if cfg.moe:
                flat = h2.reshape(-1, cfg.d_model)
                mout, _ = moe_block(
                    flat, params, p, cfg, lambda a2, w: a2 @ w, "fp32"
                )
                x = x + mout.reshape(b, tt, cfg.d_model)
            else:
                x = x + swiglu(
                    h2, params[p + "gate_proj"], params[p + "up_proj"],
                    params[p + "down_proj"], lambda a2, w: a2 @ w,
                )
        kscale = jnp.maximum(k_amax, 1e-6) / F8.E4M3_MAX
        vscale = jnp.maximum(v_amax, 1e-6) / F8.E4M3_MAX
        return kscale.reshape(1, 1), vscale.reshape(1, 1)

    return calibrate
