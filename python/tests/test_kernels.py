"""Pallas kernels vs pure-jnp oracles (the L1 correctness contract).

Hypothesis sweeps shapes/seeds; assert_allclose against ref.py. Kernels
run interpret=True so tolerances are float32-tight."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import fp8_quant as K
from compile.kernels import ref as R


def arr(rng, *shape, scale=1.0):
    return jnp.asarray(
        (rng.standard_normal(shape) * scale).astype(np.float32)
    )


@settings(max_examples=12, deadline=None)
@given(
    st.sampled_from([(32, 32), (64, 32), (64, 128), (128, 64)]),
    st.booleans(),
    st.integers(0, 2**31 - 1),
)
def test_blockwise_quant_matches_ref(shape, pow2, seed):
    rng = np.random.default_rng(seed)
    w = arr(rng, *shape, scale=3.0)
    block = (32, 32)
    deq, scales = K.blockwise_quant(w, block, pow2_scale=pow2)
    rdeq, rscales = R.ref_blockwise_quant(w, block, pow2_scale=pow2)
    np.testing.assert_allclose(deq, rdeq, rtol=0, atol=1e-6)
    np.testing.assert_allclose(scales, rscales, rtol=1e-6)


@settings(max_examples=12, deadline=None)
@given(
    st.sampled_from([(8, 64), (16, 128), (4, 32)]),
    st.integers(0, 2**31 - 1),
)
def test_act_quant_matches_ref(shape, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, *shape, scale=5.0)
    tile = min(32, shape[1])
    q = K.act_quant(x, tile)
    r = R.ref_act_quant(x, tile)
    np.testing.assert_allclose(q, r, atol=5e-6)


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([(8, 64, 32), (16, 128, 64), (8, 32, 32)]),
    st.booleans(),
    st.integers(0, 2**31 - 1),
)
def test_w8a8_matmul_matches_ref(dims, pow2, seed):
    m, k, n = dims
    rng = np.random.default_rng(seed)
    x = arr(rng, m, k)
    w = arr(rng, k, n)
    block = (8, 32, 32)
    out = K.w8a8_matmul(x, w, block, act_tile=32, pow2_scale=pow2)
    ref = R.ref_w8a8_matmul(x, w, block, act_tile=32, pow2_scale=pow2)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4)


def test_w8a8_error_vs_exact_is_bounded():
    rng = np.random.default_rng(3)
    x = arr(rng, 16, 128)
    w = arr(rng, 128, 64)
    out = np.asarray(K.w8a8_matmul(x, w, (8, 128, 64), act_tile=64))
    exact = np.asarray(x @ w)
    rel = np.abs(out - exact).max() / np.abs(exact).max()
    # fp8 fake-quant GEMM error stays within a few percent
    assert rel < 0.08, rel


@settings(max_examples=8, deadline=None)
@given(
    st.sampled_from([(2, 1, 64, 16), (4, 8, 128, 32), (2, 4, 64, 32)]),
    st.sampled_from([(False, False), (True, False), (True, True)]),
    st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(dims, flags, seed):
    h, tq, tk, d = dims
    fp8_kv, fp8_attn = flags
    rng = np.random.default_rng(seed)
    q = arr(rng, h, tq, d)
    k = arr(rng, h, tk, d)
    v = arr(rng, h, tk, d)
    ks = jnp.asarray(np.abs(np.asarray(k)).max() / 448.0).reshape(1, 1)
    vs = jnp.asarray(np.abs(np.asarray(v)).max() / 448.0).reshape(1, 1)
    qpos = jnp.asarray(
        rng.integers(tq - 1, tk, size=(h, 1)).astype(np.int32)
    )
    out = A.blocked_attention(
        q, k, v, ks, vs, qpos, kv_block=32, fp8_kv=fp8_kv,
        fp8_attn=fp8_attn,
    )
    ref = R.ref_attention(
        q, k, v, ks, vs, qpos, fp8_kv=fp8_kv, fp8_attn=fp8_attn
    )
    # Tolerances by variant:
    # * plain: online-vs-dense softmax is float-tight.
    # * fp8_kv: f8 casts can flip ties on boundary elements (~one V-ulp).
    # * fp8_attn: genuinely different quantization points — the online
    #   kernel rounds p = exp(s - m_running) per KV block then rescales,
    #   the dense ref rounds p = exp(s - m_global); both are valid
    #   "quantized attention" definitions (hardware kernels do the
    #   former), differing by up to ~one probability-ulp (1/16 relative).
    atol = 5e-2 if fp8_attn else (1e-2 if fp8_kv else 1e-5)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=atol)


def test_attention_causal_mask_position_operand():
    # moving qpos must change attention (it is a live runtime operand,
    # not baked at trace time)
    rng = np.random.default_rng(4)
    q = arr(rng, 1, 1, 16)
    k = arr(rng, 1, 64, 16)
    v = arr(rng, 1, 64, 16)
    one = jnp.ones((1, 1))
    out_early = A.blocked_attention(
        q, k, v, one, one, jnp.asarray([[3]], jnp.int32), kv_block=32
    )
    out_late = A.blocked_attention(
        q, k, v, one, one, jnp.asarray([[60]], jnp.int32), kv_block=32
    )
    assert not np.allclose(np.asarray(out_early), np.asarray(out_late))


def test_fp8_kv_attention_error_small():
    rng = np.random.default_rng(5)
    q = arr(rng, 2, 1, 32)
    k = arr(rng, 2, 64, 32)
    v = arr(rng, 2, 64, 32)
    ks = jnp.asarray(np.abs(np.asarray(k)).max() / 448.0).reshape(1, 1)
    vs = jnp.asarray(np.abs(np.asarray(v)).max() / 448.0).reshape(1, 1)
    qpos = jnp.asarray([[63], [63]], jnp.int32)
    exact = A.blocked_attention(q, k, v, ks, vs, qpos, kv_block=32)
    quant = A.blocked_attention(
        q, k, v, ks, vs, qpos, kv_block=32, fp8_kv=True
    )
    err = np.abs(np.asarray(exact) - np.asarray(quant)).max()
    assert 0 < err < 0.05, err
