"""FP8 numerics: native jax casts vs the pure-f32 emulation oracle, plus
the golden table shared with rust/tests/quantizer_parity.rs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import fp8_numerics as F8

# (input, e4m3 qdq, e5m2 qdq) — must match the Rust golden table
GOLDEN = [
    (0.0, 0.0, 0.0),
    (1.0, 1.0, 1.0),
    (1.7, 1.75, 1.75),
    (-300.0, -288.0, -320.0),
    (500.0, 448.0, 512.0),
    (0.001, 0.001953125, 0.0009765625),
    (448.0, 448.0, 448.0),
    (57344.0, 448.0, 57344.0),
    (-0.17, -0.171875, -0.15625),
    (3.14159, 3.25, 3.0),
    (1e-9, 0.0, 0.0),
    (0.0625, 0.0625, 0.0625),
]


@pytest.mark.parametrize("x,e4,e5", GOLDEN)
def test_golden_native(x, e4, e5):
    xv = jnp.asarray([x], jnp.float32)
    assert float(F8.qdq_native(xv, "e4m3")[0]) == e4
    assert float(F8.qdq_native(xv, "e5m2")[0]) == e5


@pytest.mark.parametrize("x,e4,e5", GOLDEN)
def test_golden_emulated(x, e4, e5):
    xv = jnp.asarray([x], jnp.float32)
    assert float(F8.qdq_emulated(xv, "e4m3")[0]) == e4
    assert float(F8.qdq_emulated(xv, "e5m2")[0]) == e5


@settings(max_examples=200, deadline=None)
@given(
    st.floats(
        min_value=-1000.0, max_value=1000.0,
        allow_nan=False, allow_infinity=False, width=32,
    ),
    st.sampled_from(["e4m3", "e5m2"]),
)
def test_native_matches_emulated(x, fmt):
    xv = jnp.asarray([x], jnp.float32)
    a = float(F8.qdq_native(xv, fmt)[0])
    b = float(F8.qdq_emulated(xv, fmt)[0])
    assert a == b, f"{fmt}({x}): native {a} vs emulated {b}"


@settings(max_examples=100, deadline=None)
@given(st.floats(min_value=-448.0, max_value=448.0, allow_nan=False,
                 width=32))
def test_qdq_is_projection(x):
    xv = jnp.asarray([x], jnp.float32)
    once = F8.qdq_native(xv, "e4m3")
    twice = F8.qdq_native(once, "e4m3")
    assert float(once[0]) == float(twice[0])


def test_saturation_not_nan():
    # the raw jax cast maps overflow to NaN; our qdq must saturate
    big = jnp.asarray([1e9, -1e9], jnp.float32)
    out = F8.qdq_native(big, "e4m3")
    assert list(np.asarray(out)) == [448.0, -448.0]


def test_scale_formats():
    amax = jnp.asarray([3.0])
    s_fp32 = F8.scale_fp32(amax)
    assert np.isclose(float(s_fp32[0]), 3.0 / 448.0)
    s_p2 = F8.scale_ue8m0(amax)
    v = float(s_p2[0])
    assert np.log2(v) == int(np.log2(v))  # power of two
    assert v >= float(s_fp32[0])  # ceil: never overflows


def test_blockwise_weight_quant_properties():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(64, 96)).astype(np.float32))
    q = F8.quant_weight_blockwise(w, (32, 32))
    # per-block relative error bound: half-ulp at the block amax
    wq = np.asarray(q)
    wn = np.asarray(w)
    for bi in range(2):
        for bj in range(3):
            blk = wn[bi * 32:(bi + 1) * 32, bj * 32:(bj + 1) * 32]
            blkq = wq[bi * 32:(bi + 1) * 32, bj * 32:(bj + 1) * 32]
            scale = np.abs(blk).max() / 448.0
            assert np.abs(blk - blkq).max() <= scale * 32.0


def test_act_tilewise_shapes_and_padding():
    rng = np.random.default_rng(1)
    for shape in [(4, 130), (3, 7), (8, 128), (1, 1)]:
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        q = F8.quant_act_tilewise(x, 128)
        assert q.shape == x.shape
        assert np.all(np.isfinite(np.asarray(q)))


def test_grad_quant_e5m2_has_wider_range():
    g = jnp.asarray([[1000.0, 1e-5] * 8] * 2, jnp.float32)
    q5 = F8.quant_grad_blockwise(g, "e5m2", (2, 16))
    q3 = F8.quant_grad_blockwise(g, "e4m3", (2, 16))
    # same block scale, but e5m2's extra exponent bits keep more of the
    # tiny entries alive
    alive5 = np.count_nonzero(np.asarray(q5))
    alive3 = np.count_nonzero(np.asarray(q3))
    assert alive5 >= alive3


def test_tile_exceedance_flags_wide_dynamic_range():
    rng = np.random.default_rng(2)
    ok = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    assert float(jnp.mean(F8.tile_exceedance(ok, (32, 32)))) < 0.05
    # adversarial: one huge outlier pins the scale, flushing the rest
    bad = np.full((32, 32), 1e-6, np.float32)
    bad[0, 0] = 1e4
    frac = F8.tile_exceedance(jnp.asarray(bad), (32, 32))
    assert float(jnp.mean(frac)) > 0.9
