"""AOT pipeline tests: manifest consistency and HLO-text lowering of a
small entrypoint (full artifact generation is exercised by `make
artifacts`; here we keep it fast)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot as A
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entrypoint_registry_covers_required_kinds():
    for arch, cfg in A.ARCHS.items():
        entries = A.build_entrypoints(arch, cfg)
        kinds = {meta["kind"] for _, _, _, meta in entries}
        assert kinds == {"prefill", "decode", "train", "logprobs",
                         "calibrate"}
        names = [n for n, *_ in entries]
        assert len(names) == len(set(names))


def test_lowering_emits_parseable_hlo_text():
    # lower the smallest entrypoint and sanity-check the HLO text
    cfg = A.ARCHS["dense"]
    entries = A.build_entrypoints("dense", cfg)
    name, fn, specs, meta = next(
        e for e in entries if e[3]["kind"] == "calibrate"
    )
    lowered = jax.jit(fn).lower(*specs)
    text = A.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # old-XLA compatibility: no sort-with-largest attribute anywhere
    assert "largest" not in text


def test_input_signatures_match_model_spec():
    cfg = A.ARCHS["dense"]
    n_params = len(M.param_spec(cfg))
    for name, _, specs, meta in A.build_entrypoints("dense", cfg):
        if meta["kind"] == "train":
            assert len(specs) == 3 * n_params + 6, name
        elif meta["kind"] == "decode":
            assert len(specs) == n_params + 6, name
        elif meta["kind"] == "prefill":
            assert len(specs) == n_params + 3, name


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_built_manifest_consistent_with_disk():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["constants"]["b_rollout"] == A.B_ROLLOUT
    assert man["constants"]["t_train"] == A.T_TRAIN
    for e in man["entrypoints"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        assert os.path.getsize(path) > 1000
    for arch in man["models"]:
        pb = os.path.join(ART, f"params_{arch}.bin")
        total = sum(
            int(np.prod(p["shape"])) if (np := __import__("numpy")) else 0
            for p in man["models"][arch]["params"]
        )
        assert os.path.getsize(pb) == total * 4


def test_moe_routing_is_discrete_in_lowered_fn():
    # the rollout variant with an fp8 router must produce different HLO
    # than the bf16-router variant (the ablation is real, not a no-op)
    cfg = A.ARCHS["moe"]
    rv8 = M.ROLLOUT_VARIANTS["fp8lin_rfp8"]
    rv16 = M.ROLLOUT_VARIANTS["fp8lin"]
    b, p = 2, 4
    small = M.ModelConfig(
        **{**cfg.__dict__, "n_layers": 1, "max_seq": 8}
    )
    pspecs = [
        jax.ShapeDtypeStruct(s, jnp.float32)
        for _, s in M.param_spec(small)
    ]
    extras = [
        jax.ShapeDtypeStruct((b, p), jnp.int32),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
    ]
    t8 = A.to_hlo_text(
        jax.jit(M.make_prefill(small, rv8, b, p)).lower(*pspecs, *extras)
    )
    t16 = A.to_hlo_text(
        jax.jit(M.make_prefill(small, rv16, b, p)).lower(*pspecs, *extras)
    )
    assert t8 != t16
