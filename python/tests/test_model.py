"""L2 model tests: shapes, decode/train-path consistency, precision
variants, DAPO train-step behaviour, calibration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(
    vocab=32, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, max_seq=32,
)
MOE_CFG = M.ModelConfig(
    vocab=32, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, moe=True, n_experts=4, top_k=2, d_expert=64,
    max_seq=32,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_params():
    return M.init_params(MOE_CFG, jax.random.PRNGKey(0))


def test_param_spec_roundtrip(params):
    flat = M.flatten_params(CFG, params)
    back = M.unflatten_params(CFG, flat)
    assert set(back) == set(params)
    assert all(back[k] is params[k] for k in params)


def test_param_spec_moe_has_experts():
    names = [n for n, _ in M.param_spec(MOE_CFG)]
    assert "layer0.router" in names
    assert "layer1.expert3.down_proj" in names


@pytest.mark.parametrize("variant", ["bf16", "fp8lin", "kvfp8", "fullfp8"])
def test_prefill_decode_shapes(params, variant):
    rv = M.ROLLOUT_VARIANTS[variant]
    flat = M.flatten_params(CFG, params)
    b, p = 4, 8
    toks = jnp.ones((b, p), jnp.int32)
    one = jnp.ones((1, 1))
    logits, kc, vc = M.make_prefill(CFG, rv, b, p)(*flat, toks, one, one)
    assert logits.shape == (b, p, CFG.vocab)
    assert kc.shape == (CFG.n_layers, b, CFG.n_kv_heads, CFG.max_seq,
                        CFG.d_head)
    pos = jnp.full((b, 1), p, jnp.int32)
    nxt = jnp.ones((b, 1), jnp.int32)
    lg, kc2, vc2 = M.make_decode(CFG, rv, b)(
        *flat, kc, vc, nxt, pos, one, one
    )
    assert lg.shape == (b, CFG.vocab)
    # decode must only touch position p in the cache
    diff = np.asarray(kc2 - kc)
    touched = np.nonzero(np.abs(diff).sum(axis=(0, 2, 4)))
    assert set(touched[1].tolist()) <= {p}


def test_decode_consistent_with_train_forward(params):
    """Teacher-forcing the same tokens through the rollout path must give
    (approximately) the trainer's logits — the residual gap IS the
    paper's kernel-level mismatch, so assert it is small but nonzero."""
    rv = M.ROLLOUT_VARIANTS["bf16"]
    tv = M.TRAIN_VARIANTS["bf16"]
    flat = M.flatten_params(CFG, params)
    b, p = 4, 6
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 31, size=(b, p)).astype(np.int32))
    one = jnp.ones((1, 1))
    logits_r, _, _ = M.make_prefill(CFG, rv, b, p)(*flat, toks, one, one)
    logits_t = M.train_forward(CFG, tv, params, toks)
    gap = np.abs(np.asarray(logits_r) - np.asarray(logits_t)).max()
    assert gap < 0.2, f"paths diverged too much: {gap}"
    assert gap > 0.0, "suspiciously identical: bf16 rounding is dead?"


def test_fp8_rollout_diverges_more_than_bf16(params):
    flat = M.flatten_params(CFG, params)
    b, p = 4, 6
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, 31, size=(b, p)).astype(np.int32))
    one = jnp.ones((1, 1))
    tv = M.TRAIN_VARIANTS["bf16"]
    ref = np.asarray(M.train_forward(CFG, tv, params, toks))
    gaps = {}
    for v in ["bf16", "fp8lin"]:
        rv = M.ROLLOUT_VARIANTS[v]
        lg, _, _ = M.make_prefill(CFG, rv, b, p)(*flat, toks, one, one)
        gaps[v] = np.abs(np.asarray(lg) - ref).max()
    assert gaps["fp8lin"] > gaps["bf16"]


def test_moe_router_precision_changes_routing(moe_params):
    # fp8 router quantization must flip at least one top-k decision on
    # random inputs (the Fig 6 mechanism)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    w = moe_params["layer0.router"]
    lo = M.router_logits(x, w, "fp32")
    hi = M.router_logits(x, w, "fp8")
    _, top_lo = M._topk_oldxla(lo, 2)
    _, top_hi = M._topk_oldxla(hi, 2)
    flips = int(np.sum(np.asarray(top_lo) != np.asarray(top_hi)))
    assert flips > 0, "fp8 router never flips expert selection?"


def test_train_step_improves_selected_tokens(params):
    tv = M.TRAIN_VARIANTS["bf16"]
    b, t = 4, 12
    step_fn = M.make_train_step(CFG, tv, b, t)
    flat = M.flatten_params(CFG, params)
    zeros = [jnp.zeros_like(a) for a in flat]
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, 31, size=(b, t)).astype(np.int32))
    mask = jnp.ones((b, t - 1))
    adv = jnp.ones((b, t - 1))  # "all these tokens were good"
    rlp = -2.0 * jnp.ones((b, t - 1))
    hp = jnp.asarray([[3e-3, -1.0, 0.0, 0.0]], jnp.float32)

    lp0, _ = M.token_logprobs_entropy(CFG, tv, params, toks)
    state = list(flat) + zeros + zeros + [jnp.zeros((1, 1))]
    for _ in range(5):
        outs = jax.jit(step_fn)(*state[:-1], state[-1], toks, mask, adv,
                                rlp, hp)
        n = len(flat)
        state = list(outs[: 3 * n]) + [outs[3 * n]]
    new_params = M.unflatten_params(CFG, state[: len(flat)])
    lp1, _ = M.token_logprobs_entropy(CFG, tv, new_params, toks)
    assert float(jnp.mean(lp1)) > float(jnp.mean(lp0))
    metrics = np.asarray(outs[-1])[0]
    assert metrics.shape == (16,)
    assert np.isfinite(metrics).all()


def test_tis_weight_capped_in_metrics(params):
    tv = M.TRAIN_VARIANTS["bf16"]
    b, t = 2, 8
    step_fn = M.make_train_step(CFG, tv, b, t)
    flat = M.flatten_params(CFG, params)
    zeros = [jnp.zeros_like(a) for a in flat]
    toks = jnp.ones((b, t), jnp.int32)
    mask = jnp.ones((b, t - 1))
    adv = jnp.ones((b, t - 1))
    rlp = -50.0 * jnp.ones((b, t - 1))  # rollout says "impossible tokens"
    hp = jnp.asarray([[1e-3, 2.0, 0.0, 0.0]], jnp.float32)
    outs = jax.jit(step_fn)(
        *flat, *zeros, *zeros, jnp.zeros((1, 1)), toks, mask, adv, rlp, hp
    )
    metrics = np.asarray(outs[-1])[0]
    names = M.METRIC_NAMES
    tis_mean = metrics[names.index("tis_mean")]
    raw_mean = metrics[names.index("ratio_raw_mean")]
    assert tis_mean <= 2.0 + 1e-4  # clipped at C
    assert raw_mean > tis_mean  # raw ratios exploded


def test_fp8_train_variants_run_and_differ(params):
    b, t = 2, 8
    flat = M.flatten_params(CFG, params)
    zeros = [jnp.zeros_like(a) for a in flat]
    toks = jnp.ones((b, t), jnp.int32)
    mask = jnp.ones((b, t - 1))
    adv = jnp.ones((b, t - 1))
    rlp = -2.0 * jnp.ones((b, t - 1))
    hp = jnp.asarray([[1e-3, 2.0, 0.0, 0.0]], jnp.float32)
    outs = {}
    for v in ["bf16", "fp8hybrid", "fp8e4m3"]:
        tv = M.TRAIN_VARIANTS[v]
        step_fn = M.make_train_step(CFG, tv, b, t)
        o = jax.jit(step_fn)(
            *flat, *zeros, *zeros, jnp.zeros((1, 1)), toks, mask, adv,
            rlp, hp,
        )
        outs[v] = np.asarray(o[0])  # updated embed
    assert not np.allclose(outs["bf16"], outs["fp8hybrid"])
    assert not np.allclose(outs["fp8hybrid"], outs["fp8e4m3"])


def test_calibrate_returns_positive_scales(params):
    flat = M.flatten_params(CFG, params)
    cal = M.make_calibrate(CFG, 4, 10)
    toks = jnp.ones((4, 10), jnp.int32)
    ks, vs = jax.jit(cal)(*flat, toks)
    assert ks.shape == (1, 1) and vs.shape == (1, 1)
    assert float(ks[0, 0]) > 0 and float(vs[0, 0]) > 0
    # scales track activation magnitude: doubling weights raises amax
    boosted = [a * 2.0 for a in flat]
    ks2, _ = jax.jit(cal)(*boosted, toks)
    assert float(ks2[0, 0]) > float(ks[0, 0])


def test_mis_masks_out_of_band_tokens(params):
    """MIS (mis_mode=1) zeroes the IS weight for tokens whose raw ratio
    leaves [1/C, C]; TIS clips it instead (paper §2.1.3 variants)."""
    tv = M.TRAIN_VARIANTS["bf16"]
    b, t = 2, 8
    flat = M.flatten_params(CFG, params)
    zeros = [jnp.zeros_like(a) for a in flat]
    toks = jnp.ones((b, t), jnp.int32)
    mask = jnp.ones((b, t - 1))
    adv = jnp.ones((b, t - 1))
    rlp = -50.0 * jnp.ones((b, t - 1))  # impossible under rollout => huge ratio
    step_fn = M.make_train_step(CFG, tv, b, t)
    names = M.METRIC_NAMES

    def run(mis):
        hp = jnp.asarray([[1e-3, 2.0, 0.0, mis]], jnp.float32)
        outs = jax.jit(step_fn)(
            *flat, *zeros, *zeros, jnp.zeros((1, 1)), toks, mask, adv,
            rlp, hp,
        )
        return np.asarray(outs[-1])[0]

    tis_metrics = run(0.0)
    mis_metrics = run(1.0)
    # TIS clips at C=2; MIS masks to zero
    assert tis_metrics[names.index("tis_mean")] > 1.0
    assert mis_metrics[names.index("tis_mean")] < 1e-6
