//! pallas-model CLI: bounded exhaustive exploration of the pool
//! fence protocol and the KV refcount/prefix algebra, with optional
//! counterexample replay against the real implementation.
//!
//! Exit codes: 0 = every property holds to the bound (and, with
//! `--replay-clean`, the bridge agrees); 1 = a property violated (a
//! counterexample trace is printed, and with `--replay` the replayed
//! plan's divergences are printed) or the clean replay diverged;
//! 2 = the state cap was exceeded (inconclusive — treat as failure in
//! gating CI) or a usage error.
//!
//! Examples:
//!
//! ```text
//! pallas-model --model pool --replicas 2 --requests 3 --fences 2
//! pallas-model --model kv
//! pallas-model --model pool --mutant admit_past_fence --replay
//! pallas-model --model kv --mutant skip_rc0_purge --replay
//! pallas-model --model all --replay-clean
//! ```

use std::process::exit;

use pallas_model::explore::{explore, Outcome, Stats};
use pallas_model::kv_model::{KvCfg, KvModel, KvMutant};
use pallas_model::pool_model::{PoolCfg, PoolModel, PoolMutant};
use pallas_model::replay::{
    canonical_clean_kv_trace, canonical_clean_trace,
    extend_with_next_alloc, replay_kv_trace, replay_pool_trace,
};

struct Args {
    model: String,
    pool: PoolCfg,
    kv: KvCfg,
    mutant: Option<String>,
    max_states: usize,
    trace_out: Option<String>,
    replay: bool,
    replay_clean: bool,
}

fn usage() -> String {
    "usage: pallas-model [--model pool|kv|all]\n\
     \x20 pool bound:  --replicas N --requests N --fences N \
     --aborts N --kills N\n\
     \x20 kv bound:    --blocks N --block-tokens N --slots N \
     --appends N --allocs N --kv-fences N\n\
     \x20 checking:    --mutant NAME --max-states N \
     --trace-out PATH --replay --replay-clean\n\
     \x20 pool mutants: admit_past_fence skip_fence_ack \
     install_with_inflight stamp_skew\n\
     \x20 kv mutants:   skip_rc0_purge skip_cow"
        .to_string()
}

fn take(
    argv: &[String],
    i: &mut usize,
    flag: &str,
) -> Result<String, String> {
    let v = argv
        .get(*i)
        .cloned()
        .ok_or_else(|| format!("missing value for {flag}"))?;
    *i += 1;
    Ok(v)
}

fn take_num(
    argv: &[String],
    i: &mut usize,
    flag: &str,
) -> Result<usize, String> {
    let v = take(argv, i, flag)?;
    v.parse::<usize>()
        .map_err(|_| format!("{flag}: not a number: {v}"))
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        model: "all".to_string(),
        pool: PoolCfg { requests: 3, ..PoolCfg::default() },
        kv: KvCfg::default(),
        mutant: None,
        max_states: 4_000_000,
        trace_out: None,
        replay: false,
        replay_clean: false,
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        let flag = argv[i].clone();
        i += 1;
        match flag.as_str() {
            "--model" => args.model = take(&argv, &mut i, &flag)?,
            "--replicas" => {
                args.pool.replicas = take_num(&argv, &mut i, &flag)?
            }
            "--requests" => {
                args.pool.requests = take_num(&argv, &mut i, &flag)?
            }
            "--fences" => {
                args.pool.fences = take_num(&argv, &mut i, &flag)?
            }
            "--aborts" => {
                args.pool.aborts = take_num(&argv, &mut i, &flag)?
            }
            "--kills" => {
                args.pool.kills = take_num(&argv, &mut i, &flag)?
            }
            "--blocks" => {
                args.kv.total_blocks = take_num(&argv, &mut i, &flag)?
            }
            "--block-tokens" => {
                args.kv.block_tokens = take_num(&argv, &mut i, &flag)?
            }
            "--slots" => {
                args.kv.slots = take_num(&argv, &mut i, &flag)?
            }
            "--appends" => {
                args.kv.max_appends = take_num(&argv, &mut i, &flag)?
            }
            "--allocs" => {
                args.kv.allocs = take_num(&argv, &mut i, &flag)?
            }
            "--kv-fences" => {
                args.kv.fences = take_num(&argv, &mut i, &flag)?
            }
            "--mutant" => {
                args.mutant = Some(take(&argv, &mut i, &flag)?)
            }
            "--max-states" => {
                args.max_states = take_num(&argv, &mut i, &flag)?
            }
            "--trace-out" => {
                args.trace_out = Some(take(&argv, &mut i, &flag)?)
            }
            "--replay" => args.replay = true,
            "--replay-clean" => args.replay_clean = true,
            "--help" | "-h" => return Err(usage()),
            other => {
                return Err(format!("unknown flag {other}\n{}", usage()))
            }
        }
    }
    if !matches!(args.model.as_str(), "pool" | "kv" | "all") {
        return Err(format!("--model must be pool|kv|all\n{}", usage()));
    }
    if args.pool.replicas == 0 {
        return Err("--replicas must be >= 1".to_string());
    }
    Ok(args)
}

fn stats_line(st: &Stats) -> String {
    format!(
        "states={} transitions={} depth={} terminals={}",
        st.states, st.transitions, st.depth, st.terminals
    )
}

fn dump_trace<A: std::fmt::Debug>(
    trace: &[A],
    message: &str,
    path: Option<&str>,
) {
    eprintln!("counterexample ({} steps):", trace.len());
    for (i, a) in trace.iter().enumerate() {
        eprintln!("  {:>3}. {a:?}", i + 1);
    }
    if let Some(path) = path {
        let mut body = format!("violation: {message}\n");
        for (i, a) in trace.iter().enumerate() {
            body.push_str(&format!("{:>3}. {a:?}\n", i + 1));
        }
        match std::fs::write(path, body) {
            Ok(()) => eprintln!("trace written to {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

fn report_divergence(diverged: &[String]) {
    if diverged.is_empty() {
        println!("replay: AGREED (model and implementation match)");
    } else {
        println!("replay: DIVERGED ({} mismatch(es))", diverged.len());
        for d in diverged {
            println!("  {d}");
        }
    }
}

fn run_pool(args: &Args) -> i32 {
    let mut cfg = args.pool;
    if let Some(name) = &args.mutant {
        match PoolMutant::parse(name) {
            Some(m) => cfg.mutant = Some(m),
            None => {
                if args.model == "pool" {
                    eprintln!("unknown pool mutant {name}\n{}", usage());
                    return 2;
                }
                // `all` with a kv-only mutant: run the pool clean
            }
        }
    }
    let m = PoolModel::new(cfg);
    println!(
        "pallas-model: pool bound replicas={} requests={} fences={} \
         aborts={} kills={} mutant={:?}",
        cfg.replicas, cfg.requests, cfg.fences, cfg.aborts, cfg.kills,
        cfg.mutant
    );
    let mut code = match explore(&m, args.max_states) {
        Outcome::Ok(st) => {
            println!("pallas-model: pool OK — {}", stats_line(&st));
            0
        }
        Outcome::Violation(st, v) => {
            println!(
                "pallas-model: pool VIOLATION — {} ({})",
                v.message,
                stats_line(&st)
            );
            dump_trace(&v.trace, &v.message, args.trace_out.as_deref());
            if args.replay {
                match replay_pool_trace(&m, &v.trace) {
                    Ok(d) => report_divergence(&d),
                    Err(e) => println!("replay: SKIPPED — {e}"),
                }
            }
            1
        }
        Outcome::CapExceeded(st) => {
            println!(
                "pallas-model: pool INCONCLUSIVE — state cap hit \
                 ({})",
                stats_line(&st)
            );
            2
        }
    };
    if args.replay_clean && cfg.mutant.is_none() && code == 0 {
        let trace = canonical_clean_trace(&m);
        match replay_pool_trace(&m, &trace) {
            Ok(d) => {
                report_divergence(&d);
                if !d.is_empty() {
                    code = 1;
                }
            }
            Err(e) => {
                println!("replay: ERROR — {e}");
                code = 2;
            }
        }
    }
    code
}

fn run_kv(args: &Args) -> i32 {
    let mut cfg = args.kv;
    if let Some(name) = &args.mutant {
        match KvMutant::parse(name) {
            Some(m) => cfg.mutant = Some(m),
            None => {
                if args.model == "kv" {
                    eprintln!("unknown kv mutant {name}\n{}", usage());
                    return 2;
                }
            }
        }
    }
    let m = KvModel::new(cfg);
    println!(
        "pallas-model: kv bound blocks={} block_tokens={} slots={} \
         appends={} allocs={} fences={} mutant={:?}",
        cfg.total_blocks,
        cfg.block_tokens,
        cfg.slots,
        cfg.max_appends,
        cfg.allocs,
        cfg.fences,
        cfg.mutant
    );
    let mut code = match explore(&m, args.max_states) {
        Outcome::Ok(st) => {
            println!("pallas-model: kv OK — {}", stats_line(&st));
            0
        }
        Outcome::Violation(st, v) => {
            println!(
                "pallas-model: kv VIOLATION — {} ({})",
                v.message,
                stats_line(&st)
            );
            dump_trace(&v.trace, &v.message, args.trace_out.as_deref());
            if args.replay {
                // one more allocation turns a stale-registry state
                // into an observable grant divergence
                let extended = extend_with_next_alloc(&m, &v.trace)
                    .unwrap_or_else(|_| v.trace.clone());
                match replay_kv_trace(&m, &extended) {
                    Ok(d) => report_divergence(&d),
                    Err(e) => println!("replay: SKIPPED — {e}"),
                }
            }
            1
        }
        Outcome::CapExceeded(st) => {
            println!(
                "pallas-model: kv INCONCLUSIVE — state cap hit ({})",
                stats_line(&st)
            );
            2
        }
    };
    if args.replay_clean && cfg.mutant.is_none() && code == 0 {
        let trace = canonical_clean_kv_trace(&m);
        match replay_kv_trace(&m, &trace) {
            Ok(d) => {
                report_divergence(&d);
                if !d.is_empty() {
                    code = 1;
                }
            }
            Err(e) => {
                println!("replay: ERROR — {e}");
                code = 2;
            }
        }
    }
    code
}

fn main() {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            exit(2);
        }
    };
    let mut code = 0;
    if matches!(args.model.as_str(), "pool" | "all") {
        code = code.max(run_pool(&args));
    }
    if matches!(args.model.as_str(), "kv" | "all") {
        code = code.max(run_kv(&args));
    }
    exit(code);
}
