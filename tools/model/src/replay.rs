//! Counterexample replay: turn a model-checker trace into a concrete
//! `testkit::interleave` plan and run it against the REAL
//! implementation, comparing the model's predicted per-request
//! outcomes with what the implementation actually does.
//!
//! This is what keeps the model honest. A clean trace must replay
//! with zero divergence (model and implementation agree). A mutant
//! counterexample must diverge — the mutant lives only in the model,
//! so its predicted outcomes cannot match the correct implementation.
//! A mutant whose counterexample replayed *cleanly* would mean the
//! model is checking properties the implementation does not actually
//! have, i.e. the bridge is vacuous; the in-crate tests and the CI
//! `model-check` job assert both directions.
//!
//! ## Projection
//!
//! Pool-side actions project onto plan events
//! (`Submit -> Event::Submit`, `Fence -> Event::Sync`,
//! `Abort -> Event::Abort`, `PoolDrain -> Event::Poll`); worker-side
//! actions order *internal* steps and project to nothing — the real
//! worker threads schedule those themselves. `Kill`/`Reap` traces are
//! not plan-expressible (the public pool API cannot kill a replica
//! mid-session) and are rejected.
//!
//! ## Prediction
//!
//! The (possibly mutant) model is stepped over the trace in lenient
//! mode — property failures are carried through the way the real
//! implementation would carry them — and then quiesced with internal
//! actions only (ingest, drain-inflight-then-apply-fence, drain
//! events; never a new pool-side send), yielding a predicted
//! resolution for every ticket. Requests with an abort in flight are
//! excluded from comparison: abort-vs-completion is a true race and
//! both outcomes are legal.

use std::collections::BTreeMap;
use std::sync::Arc;

use fp8_rl::rollout::{
    hermetic_runtime_factory, Completed, EngineConfig, EnginePool,
    KvBlockManager, KvGeometry, KvPrecision, PoolConfig, Request,
    RoutePolicy, SamplingParams,
};
use fp8_rl::runtime::{HostArray, Runtime};
use fp8_rl::sync::{WeightSync, WeightSyncConfig};
use fp8_rl::testkit::hb::{HbHandle, HbRecorder};
use fp8_rl::testkit::interleave::{
    run, Event, InterleaveSpec, InterleaveTarget, Plan,
};
use fp8_rl::util::units::{Blocks, Tokens};

use crate::explore::Model;
use crate::kv_model::{prompt_for, KvAct, KvModel, KvState};
use crate::pool_model::{
    step_unchecked, PoolAct, PoolModel, PoolState, Resolution,
};

// ---------------------------------------------------------------------
// pool replay
// ---------------------------------------------------------------------

/// Project a model trace onto an interleave plan. Errors when the
/// trace is not plan-expressible (contains `Kill`/`Reap`).
pub fn project_plan(
    trace: &[PoolAct],
) -> Result<(Plan, InterleaveSpec), String> {
    let mut events = Vec::new();
    let (mut subs, mut syncs, mut aborts, mut polls) = (0, 0, 0, 0);
    for a in trace {
        match *a {
            PoolAct::Submit => {
                events.push(Event::Submit(subs));
                subs += 1;
            }
            PoolAct::Fence => {
                events.push(Event::Sync(syncs));
                syncs += 1;
            }
            PoolAct::Abort { req } => {
                events.push(Event::Abort(req as usize));
                aborts += 1;
            }
            PoolAct::PoolDrain { .. } => {
                events.push(Event::Poll);
                polls += 1;
            }
            PoolAct::Kill { .. } | PoolAct::Reap { .. } => {
                return Err(
                    "trace kills a replica: not expressible as an \
                     interleave plan (the public pool API cannot kill \
                     a worker mid-session)"
                        .to_string(),
                );
            }
            PoolAct::WorkerIngest { .. }
            | PoolAct::WorkerComplete { .. }
            | PoolAct::WorkerApplyFence { .. } => {}
        }
    }
    let spec = InterleaveSpec {
        n_requests: subs,
        n_syncs: syncs,
        n_aborts: aborts,
        n_polls: polls,
    };
    Ok((Plan { seed: 0, events }, spec))
}

/// Pick the next internal (worker/drain) action, if any. Deterministic
/// priority per replica: ingest the channel, finish inflight work,
/// apply a parked fence once the engine is idle, drain events.
fn next_internal(m: &PoolModel, s: &PoolState) -> Option<PoolAct> {
    let inflight_gate = |rep: &crate::pool_model::Replica| {
        rep.inflight.is_empty()
            || m.cfg.mutant
                == Some(crate::pool_model::PoolMutant::InstallWithInflight)
    };
    for (r, rep) in s.replicas.iter().enumerate() {
        let r8 = r as u8;
        if rep.alive && !rep.chan.is_empty() {
            return Some(PoolAct::WorkerIngest { replica: r8 });
        }
        if rep.alive && !rep.inflight.is_empty() {
            return Some(PoolAct::WorkerComplete { replica: r8, slot: 0 });
        }
        if rep.alive && rep.parked.is_some() && inflight_gate(rep) {
            return Some(PoolAct::WorkerApplyFence { replica: r8 });
        }
        if !rep.events.is_empty() {
            return Some(PoolAct::PoolDrain { replica: r8 });
        }
    }
    None
}

/// Drive the model to rest with internal actions only, recording what
/// was applied.
pub fn quiesce_recording(
    m: &PoolModel,
    s: &mut PoolState,
) -> Vec<PoolAct> {
    let mut applied = Vec::new();
    for _ in 0..10_000 {
        let Some(a) = next_internal(m, s) else { break };
        *s = step_unchecked(m, s, &a);
        applied.push(a);
    }
    applied
}

/// Step the (mutant) model over `trace` leniently, then quiesce:
/// the model's prediction of how every ticket resolves.
pub fn predict_pool(m: &PoolModel, trace: &[PoolAct]) -> PoolState {
    let mut s = m.initial();
    for a in trace {
        s = step_unchecked(m, &s, a);
    }
    quiesce_recording(m, &mut s);
    s
}

/// A canonical clean end-to-end trace at the model's bound: submits
/// and fences interleaved, one abort when the bound allows it, then a
/// full internal quiesce. Used to show the bridge passes on the clean
/// model.
pub fn canonical_clean_trace(m: &PoolModel) -> Vec<PoolAct> {
    let mut s = m.initial();
    let mut trace = Vec::new();
    let mut fences = 0usize;
    for i in 0..m.cfg.requests {
        let a = PoolAct::Submit;
        s = step_unchecked(m, &s, &a);
        trace.push(a);
        if fences < m.cfg.fences && i % 2 == 0 {
            let f = PoolAct::Fence;
            s = step_unchecked(m, &s, &f);
            trace.push(f);
            fences += 1;
        }
    }
    while fences < m.cfg.fences {
        let f = PoolAct::Fence;
        s = step_unchecked(m, &s, &f);
        trace.push(f);
        fences += 1;
    }
    if m.cfg.aborts > 0 && m.cfg.requests > 0 {
        let a = PoolAct::Abort { req: 0 };
        s = step_unchecked(m, &s, &a);
        trace.push(a);
    }
    trace.extend(quiesce_recording(m, &mut s));
    trace
}

/// How a request actually resolved in the real pool.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum RealOutcome {
    Done { epoch: u64 },
    Aborted,
    Failed,
}

struct ReplaySession {
    pool: EnginePool,
    requests: Vec<Request>,
    syncs: Vec<Arc<Vec<HostArray>>>,
    outcomes: BTreeMap<u64, RealOutcome>,
    errors: Vec<String>,
}

impl ReplaySession {
    fn record(&mut self, c: Completed) {
        let (id, out) = match c {
            Completed::Done(c) => {
                (c.id, RealOutcome::Done { epoch: c.epoch })
            }
            Completed::Aborted(id) => (id, RealOutcome::Aborted),
            Completed::Failed(id, _) => (id, RealOutcome::Failed),
        };
        if self.outcomes.insert(id, out).is_some() {
            self.errors.push(format!("ticket {id} resolved twice"));
        }
    }

    fn finish(&mut self) -> Result<(), String> {
        while let Some(c) =
            self.pool.next_resolved().map_err(|e| e.to_string())?
        {
            self.record(c);
        }
        Ok(())
    }
}

impl InterleaveTarget for ReplaySession {
    type Err = String;

    fn submit(&mut self, i: usize) -> Result<(), String> {
        let req = self.requests[i].clone();
        self.pool.submit(req).map(|_| ()).map_err(|e| e.to_string())
    }

    fn sync(&mut self, j: usize) -> Result<(), String> {
        self.pool
            .sync_weights(self.syncs[j].clone())
            .map(|_| ())
            .map_err(|e| e.to_string())
    }

    fn poll(&mut self) -> Result<(), String> {
        while let Some(c) = self.pool.poll() {
            self.record(c);
        }
        Ok(())
    }

    fn abort(&mut self, i: usize) -> Result<(), String> {
        self.pool
            .abort(self.requests[i].id)
            .map_err(|e| e.to_string())
    }
}

/// Trainer-step-`j` weights, perturbed then FP8-synced (the same
/// idiom the streaming property suite uses).
fn synced_weights(
    rt: &Runtime,
    j: usize,
) -> Result<Arc<Vec<HostArray>>, String> {
    let spec = rt
        .manifest
        .model("dense")
        .ok_or("no dense model in hermetic manifest")?
        .clone();
    let init = rt
        .manifest
        .load_initial_params("dense")
        .map_err(|e| e.to_string())?;
    let scale = 1.0 + 0.01 * (j as f32 + 1.0);
    let params: Vec<HostArray> = init
        .into_iter()
        .zip(&spec.params)
        .map(|(mut v, p)| {
            for x in v.iter_mut() {
                *x *= scale;
            }
            HostArray::f32(p.shape.clone(), v)
        })
        .collect();
    let sync = WeightSync::new(WeightSyncConfig::fp8());
    let (w, _) =
        sync.run_shared(&spec, &params).map_err(|e| e.to_string())?;
    Ok(w)
}

fn mk_requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: 1 + i as u64,
            prompt: vec![12, (i % 10) as i32, 10, 11],
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 2,
                ..Default::default()
            },
        })
        .collect()
}

/// Replay a pool-model trace against the real `EnginePool`.
///
/// Returns the list of divergences between the model's predicted
/// per-request outcomes and the implementation's actual ones (empty ==
/// the bridge agrees), or `Err` for infrastructure failures (trace not
/// plan-expressible, pool construction failed, ...).
pub fn replay_pool_trace(
    m: &PoolModel,
    trace: &[PoolAct],
) -> Result<Vec<String>, String> {
    let (plan, spec) = project_plan(trace)?;
    plan.check_well_formed(&spec);
    let predicted = predict_pool(m, trace);

    let rt = Runtime::hermetic();
    let syncs = (0..spec.n_syncs)
        .map(|j| synced_weights(&rt, j))
        .collect::<Result<Vec<_>, _>>()?;
    let pool = EnginePool::new_traced(
        PoolConfig {
            n_replicas: m.cfg.replicas,
            policy: RoutePolicy::RoundRobin,
            engine: EngineConfig::new("dense", "bf16"),
        },
        hermetic_runtime_factory(),
        HbHandle::traced(HbRecorder::new(m.cfg.replicas)),
    )
    .map_err(|e| e.to_string())?;
    let mut sess = ReplaySession {
        pool,
        requests: mk_requests(spec.n_requests),
        syncs,
        outcomes: BTreeMap::new(),
        errors: Vec::new(),
    };
    run(&plan, &mut sess)?;
    sess.finish()?;

    let mut diverged = sess.errors.clone();
    for (i, t) in predicted.tickets.iter().enumerate() {
        if t.abort_sent {
            // abort-vs-completion is a legal race; outcome not pinned
            continue;
        }
        let id = 1 + i as u64;
        let actual = sess.outcomes.get(&id).copied();
        let agree = match (t.resolution, actual) {
            (
                Some(Resolution::Done { epoch }),
                Some(RealOutcome::Done { epoch: e }),
            ) => u64::from(epoch) == e,
            (Some(Resolution::Aborted), Some(RealOutcome::Aborted)) => {
                true
            }
            (Some(Resolution::Failed), Some(RealOutcome::Failed)) => true,
            _ => false,
        };
        if !agree {
            diverged.push(format!(
                "request {i}: model predicted {:?}, real pool produced \
                 {:?}",
                t.resolution, actual
            ));
        }
    }
    Ok(diverged)
}

// ---------------------------------------------------------------------
// kv replay
// ---------------------------------------------------------------------

/// Replay a KV-model trace against the real `KvBlockManager`, running
/// `check_invariants` after every operation and comparing every
/// predicted `SharedGrant` shape with the real one.
///
/// Returns the divergence list (empty == the bridge agrees) or `Err`
/// for infrastructure failures.
pub fn replay_kv_trace(
    m: &KvModel,
    trace: &[KvAct],
) -> Result<Vec<String>, String> {
    let geometry = KvGeometry {
        n_layers: 1,
        n_kv_heads: 1,
        d_head: 2,
        block_tokens: m.cfg.block_tokens,
        precision: KvPrecision::Bf16,
    };
    let mut mgr =
        KvBlockManager::new(geometry, Blocks::new(m.cfg.total_blocks))
            .map_err(|e| format!("{e:?}"))?;
    let mut diverged = Vec::new();
    let mut state = m.initial();
    let mut next_id = 0u64;
    let mut live_ids: Vec<Option<u64>> = vec![None; m.cfg.slots];

    let mut release_real =
        |mgr: &mut KvBlockManager,
         live_ids: &mut Vec<Option<u64>>,
         slot: usize| {
            if let Some(id) = live_ids[slot].take() {
                mgr.release(id);
            }
        };

    for (step, a) in trace.iter().enumerate() {
        match *a {
            KvAct::Alloc { slot } => {
                let i = slot as usize;
                let predicted = m.grant(&state, i);
                next_id += 1;
                let prompt = prompt_for(i);
                let real = mgr.allocate_shared(
                    next_id,
                    Tokens::new(prompt.len()),
                    prompt,
                );
                match (predicted, real) {
                    (Some(p), Some(g)) => {
                        live_ids[i] = Some(next_id);
                        let got = (
                            g.shared_blocks.get(),
                            g.new_blocks.get(),
                            g.shared_tokens.get(),
                        );
                        let want = (
                            p.shared_blocks,
                            p.new_blocks,
                            p.shared_tokens,
                        );
                        if got != want {
                            diverged.push(format!(
                                "step {step}: slot {i} alloc — model \
                                 predicted grant (shared_blocks, \
                                 new_blocks, shared_tokens) = {want:?}, \
                                 real manager returned {got:?}",
                            ));
                        }
                    }
                    (p, g) => {
                        if g.is_some() {
                            live_ids[i] = Some(next_id);
                        }
                        diverged.push(format!(
                            "step {step}: slot {i} alloc — model \
                             predicted {p:?}, real manager returned \
                             {:?}",
                            g.map(|g| (
                                g.shared_blocks.get(),
                                g.new_blocks.get(),
                                g.shared_tokens.get(),
                            ))
                        ));
                    }
                }
            }
            KvAct::Append { slot } => {
                let i = slot as usize;
                let id = live_ids[i]
                    .ok_or_else(|| format!("step {step}: append on idle slot {i}"))?;
                match mgr.append_token(id) {
                    Ok(true) => {}
                    Ok(false) => diverged.push(format!(
                        "step {step}: slot {i} append ran out of blocks \
                         where the model had capacity"
                    )),
                    Err(e) => diverged.push(format!(
                        "step {step}: slot {i} append failed: {e}"
                    )),
                }
            }
            KvAct::Release { slot } => {
                release_real(&mut mgr, &mut live_ids, slot as usize);
            }
            KvAct::FencePreempt => {
                for i in 0..m.cfg.slots {
                    release_real(&mut mgr, &mut live_ids, i);
                }
            }
        }
        if let Err(e) = mgr.check_invariants() {
            diverged.push(format!(
                "step {step}: real manager invariant broken after \
                 {a:?}: {e}"
            ));
        }
        state = m.apply(&state, a).map_err(|e| {
            format!("step {step}: model could not apply {a:?}: {e}")
        })?;
    }
    Ok(diverged)
}

fn kv_try(
    m: &KvModel,
    s: &mut KvState,
    tr: &mut Vec<KvAct>,
    a: KvAct,
) {
    let mut acts = Vec::new();
    m.actions(s, &mut acts);
    if acts.contains(&a) {
        if let Ok(next) = m.apply(s, &a) {
            *s = next;
            tr.push(a);
        }
    }
}

/// A canonical clean KV trace at the model's bound: allocate every
/// slot (exercising full-prefix and partial-tail sharing), append once
/// per live sequence (exercising boundary, COW, and in-place paths),
/// then release everything through a fence-preempt storm.
pub fn canonical_clean_kv_trace(m: &KvModel) -> Vec<KvAct> {
    let mut s = m.initial();
    let mut tr = Vec::new();
    for i in 0..m.cfg.slots {
        kv_try(m, &mut s, &mut tr, KvAct::Alloc { slot: i as u8 });
    }
    for i in 0..m.cfg.slots {
        kv_try(m, &mut s, &mut tr, KvAct::Append { slot: i as u8 });
    }
    kv_try(m, &mut s, &mut tr, KvAct::FencePreempt);
    for i in 0..m.cfg.slots {
        kv_try(m, &mut s, &mut tr, KvAct::Release { slot: i as u8 });
    }
    tr
}

/// Extend a (typically violating) KV trace with the next allocation
/// the model believes is possible — this is what turns a stale-registry
/// state into an observable grant divergence on replay.
pub fn extend_with_next_alloc(
    m: &KvModel,
    trace: &[KvAct],
) -> Result<Vec<KvAct>, String> {
    let mut state: KvState = m.initial();
    for a in trace {
        state = m
            .apply(&state, a)
            .map_err(|e| format!("could not apply {a:?}: {e}"))?;
    }
    for i in 0..m.cfg.slots {
        if state.slots[i].live.is_none() && m.grant(&state, i).is_some()
        {
            let mut out = trace.to_vec();
            out.push(KvAct::Alloc { slot: i as u8 });
            return Ok(out);
        }
    }
    Err("no further allocation possible in the model state".to_string())
}
