//! Abstract model of the `KvBlockManager` refcount / prefix-registry
//! algebra (rust/src/rollout/kvcache.rs), checked exhaustively by
//! `explore`.
//!
//! ## Abstraction mapping (see DESIGN.md §11)
//!
//! Block payloads are collapsed to the token list written into each
//! block (`content`); geometry is collapsed to `block_tokens`. The
//! modeled operations mirror the real manager call for call:
//!
//! * `Alloc{slot}`    -> `allocate_shared(id, tokens, prompt)`:
//!   longest-prefix registry probe (whole-prompt partial first, then
//!   full-block prefixes descending), rc bump on hits, LIFO free-list
//!   take for the remainder, `register_all` of every full-block prefix
//!   plus the whole prompt when it ends mid-block (first-writer-wins);
//! * `Append{slot}`   -> `append_token(id)`: fresh block at a block
//!   boundary, copy-on-write when the partial tail is shared
//!   (`rc > 1`), in-place write otherwise;
//! * `Release{slot}`  -> `release(id)`: unref every block, return
//!   rc-0 blocks to the free list and eagerly purge registry entries
//!   naming them (the ABA guard);
//! * `FencePreempt`   -> the epoch-fence cancel storm: every live
//!   sequence is released in slot order, modeling the trainer
//!   preempting all rollouts at a weight install.
//!
//! Sequences are bounded slots; slot `i` allocates prompt
//! `PROMPTS[i % 2]`, so one prompt pair shares a full-block prefix and
//! the other shares a partial tail (the COW trigger). Appended tokens
//! are distinct per slot so a clobbered block is observable.
//!
//! ## Properties
//!
//! State invariants: refcount conservation (`rc[b]` == live references
//! to `b`), free-list exactness (free xor referenced, no duplicates),
//! no duplicate block within a sequence, token/block occupancy bounds,
//! registry well-formedness (`blocks.len() == ceil(tokens/bt)`, no
//! entry names an rc-0 block — the eager-purge guarantee), and content
//! faithfulness: every claimant of a block (sequence or registry
//! entry) sees exactly its own token prefix in the block. The content
//! check is what catches both a skipped COW (a sharer's token gets
//! clobbered in place) and ABA re-registration through a stale entry.
//! Terminal obligations: all refcounts zero, free list full, registry
//! empty — nothing leaks.

use crate::explore::Model;

/// The two prompts. Index 0 ends mid-block (3 tokens, bt = 2): its
/// whole-prompt registration makes the partial tail shareable and COW
/// reachable. Index 1 is block-aligned and shares the `[1, 2]` prefix
/// block with index 0.
pub const PROMPTS: [&[i32]; 2] = [&[1, 2, 5], &[1, 2, 3, 4]];

/// Token appended by slot `i` (distinct per slot so in-place clobber
/// of a shared block is observable in `content`).
pub fn append_token(slot: usize) -> i32 {
    90 + slot as i32
}

pub fn prompt_for(slot: usize) -> &'static [i32] {
    PROMPTS[slot % PROMPTS.len()]
}

/// Exploration bound + mutant selection.
#[derive(Clone, Copy, Debug)]
pub struct KvCfg {
    pub total_blocks: usize,
    pub block_tokens: usize,
    pub slots: usize,
    /// Max `Append` actions per live sequence.
    pub max_appends: usize,
    /// Allocation rounds per slot (>= 2 exercises ABA reuse).
    pub allocs: usize,
    /// Max `FencePreempt` storms.
    pub fences: usize,
    pub mutant: Option<KvMutant>,
}

impl Default for KvCfg {
    fn default() -> Self {
        // the documented bound: >= 2 sharers x preempt/cancel, with a
        // second allocation round so freed blocks get re-registered.
        KvCfg {
            total_blocks: 6,
            block_tokens: 2,
            slots: 3,
            max_appends: 1,
            allocs: 2,
            fences: 2,
            mutant: None,
        }
    }
}

/// Deliberately injected algebra bugs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KvMutant {
    /// `release` returns rc-0 blocks to the free list but skips the
    /// registry purge — stale entries name freed (and later reused)
    /// blocks: the ABA hazard.
    SkipRc0Purge,
    /// `append_token` writes in place even when the partial tail is
    /// shared — a sharer's token gets clobbered.
    SkipCow,
}

impl KvMutant {
    pub fn parse(name: &str) -> Option<KvMutant> {
        match name {
            "skip_rc0_purge" => Some(KvMutant::SkipRc0Purge),
            "skip_cow" => Some(KvMutant::SkipCow),
            _ => None,
        }
    }

    pub const ALL: [(&'static str, KvMutant); 2] = [
        ("skip_rc0_purge", KvMutant::SkipRc0Purge),
        ("skip_cow", KvMutant::SkipCow),
    ];
}

/// A live sequence: its logical token stream and block table.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct Seq {
    pub toks: Vec<i32>,
    pub blocks: Vec<u8>,
    pub appends: u8,
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct Slot {
    pub allocs_done: u8,
    pub live: Option<Seq>,
}

/// A prefix-registry entry, keyed by token content (the real registry
/// is hash-keyed and token-verified, which is equivalent here).
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct RegEnt {
    pub tokens: Vec<i32>,
    pub blocks: Vec<u8>,
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct KvState {
    pub rc: Vec<u8>,
    /// LIFO free stack; initialized `(0..total).rev()` like the real
    /// manager, so block 0 is taken first.
    pub free: Vec<u8>,
    /// Physical tokens written into each block (cleared on free).
    pub content: Vec<Vec<i32>>,
    pub slots: Vec<Slot>,
    /// Kept sorted for state canonicalization.
    pub registry: Vec<RegEnt>,
    pub fences_done: u8,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KvAct {
    Alloc { slot: u8 },
    Append { slot: u8 },
    Release { slot: u8 },
    FencePreempt,
}

/// What `allocate_shared` would return: (shared_blocks, new_blocks,
/// shared_tokens) — the model's prediction of the real `SharedGrant`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct GrantShape {
    pub shared_blocks: usize,
    pub new_blocks: usize,
    pub shared_tokens: usize,
}

pub struct KvModel {
    pub cfg: KvCfg,
}

impl KvModel {
    pub fn new(cfg: KvCfg) -> KvModel {
        KvModel { cfg }
    }

    fn mutant(&self, m: KvMutant) -> bool {
        self.cfg.mutant == Some(m)
    }

    /// Mirror of `find_prefix`: whole-prompt partial hit first, then
    /// full-block prefixes longest-first. Returns the hit entry's
    /// blocks and the shared token count.
    fn find_prefix(
        &self,
        s: &KvState,
        prompt: &[i32],
    ) -> (Vec<u8>, usize) {
        let bt = self.cfg.block_tokens;
        let p = prompt.len();
        if p % bt != 0 {
            if let Some(e) =
                s.registry.iter().find(|e| e.tokens == prompt)
            {
                if e.blocks.len() == p.div_ceil(bt) {
                    return (e.blocks.clone(), p);
                }
            }
        }
        for k in (1..=p / bt).rev() {
            let key = &prompt[..k * bt];
            if let Some(e) =
                s.registry.iter().find(|e| e.tokens == key)
            {
                if e.blocks.len() == k {
                    return (e.blocks.clone(), k * bt);
                }
            }
        }
        (Vec::new(), 0)
    }

    /// The grant `Alloc{slot}` would produce in `s`, or `None` when
    /// the free list cannot cover the remainder (the action is then
    /// disabled, mirroring `allocate_shared` returning `None`).
    pub fn grant(&self, s: &KvState, slot: usize) -> Option<GrantShape> {
        let prompt = prompt_for(slot);
        let bt = self.cfg.block_tokens;
        let (shared, shared_tokens) = self.find_prefix(s, prompt);
        let total = prompt.len().div_ceil(bt);
        let new = total - shared.len();
        if s.free.len() < new {
            return None;
        }
        Some(GrantShape {
            shared_blocks: shared.len(),
            new_blocks: new,
            shared_tokens,
        })
    }

    /// First-writer-wins registration, kept sorted for canonical form.
    fn register(&self, s: &mut KvState, tokens: &[i32], blocks: &[u8]) {
        if s.registry.iter().any(|e| e.tokens == tokens) {
            return;
        }
        s.registry.push(RegEnt {
            tokens: tokens.to_vec(),
            blocks: blocks.to_vec(),
        });
        s.registry.sort();
    }

    fn unref(&self, s: &mut KvState, b: u8) {
        let bi = b as usize;
        s.rc[bi] -= 1;
        if s.rc[bi] == 0 {
            s.free.push(b);
            s.content[bi].clear();
            if !self.mutant(KvMutant::SkipRc0Purge) {
                s.registry.retain(|e| !e.blocks.contains(&b));
            }
        }
    }

    fn release_slot(&self, s: &mut KvState, slot: usize) {
        if let Some(seq) = s.slots[slot].live.take() {
            for b in seq.blocks {
                self.unref(s, b);
            }
        }
    }

    /// Tokens the sequence currently holds in its tail block.
    fn tail_fill(&self, seq: &Seq) -> usize {
        seq.toks.len() - (seq.blocks.len() - 1) * self.cfg.block_tokens
    }
}

impl Model for KvModel {
    type State = KvState;
    type Action = KvAct;

    fn initial(&self) -> KvState {
        KvState {
            rc: vec![0; self.cfg.total_blocks],
            free: (0..self.cfg.total_blocks as u8).rev().collect(),
            content: vec![Vec::new(); self.cfg.total_blocks],
            slots: (0..self.cfg.slots)
                .map(|_| Slot { allocs_done: 0, live: None })
                .collect(),
            registry: Vec::new(),
            fences_done: 0,
        }
    }

    fn actions(&self, s: &KvState, out: &mut Vec<KvAct>) {
        let bt = self.cfg.block_tokens;
        let mut any_live = false;
        for (i, slot) in s.slots.iter().enumerate() {
            let i8t = i as u8;
            match &slot.live {
                None => {
                    if (slot.allocs_done as usize) < self.cfg.allocs
                        && self.grant(s, i).is_some()
                    {
                        out.push(KvAct::Alloc { slot: i8t });
                    }
                }
                Some(seq) => {
                    any_live = true;
                    out.push(KvAct::Release { slot: i8t });
                    if (seq.appends as usize) < self.cfg.max_appends {
                        let boundary = seq.toks.len() % bt == 0;
                        let tail = *seq.blocks.last().unwrap() as usize;
                        let needs_block = boundary
                            || (s.rc[tail] > 1
                                && !self.mutant(KvMutant::SkipCow));
                        if !needs_block || !s.free.is_empty() {
                            out.push(KvAct::Append { slot: i8t });
                        }
                    }
                }
            }
        }
        if any_live && (s.fences_done as usize) < self.cfg.fences {
            out.push(KvAct::FencePreempt);
        }
    }

    fn apply(
        &self,
        prev: &KvState,
        a: &KvAct,
    ) -> Result<KvState, String> {
        let mut s = prev.clone();
        let bt = self.cfg.block_tokens;
        match *a {
            KvAct::Alloc { slot } => {
                let i = slot as usize;
                let prompt = prompt_for(i);
                let (shared, _) = self.find_prefix(&s, prompt);
                for &b in &shared {
                    s.rc[b as usize] += 1;
                }
                let mut blocks = shared;
                // cover the remainder from the LIFO free stack,
                // writing each new block's token slice
                let mut covered = blocks.len() * bt;
                while covered < prompt.len() {
                    let b = s.free.pop().ok_or_else(|| {
                        "alloc enabled without free blocks".to_string()
                    })?;
                    s.rc[b as usize] = 1;
                    let end = prompt.len().min(covered + bt);
                    s.content[b as usize] = prompt[covered..end].to_vec();
                    blocks.push(b);
                    covered += bt;
                }
                // register_all: every full-block prefix, plus the
                // whole prompt when it ends mid-block
                for k in 1..=prompt.len() / bt {
                    let key = &prompt[..k * bt];
                    let pre = blocks[..k].to_vec();
                    self.register(&mut s, key, &pre);
                }
                if prompt.len() % bt != 0 {
                    let all = blocks.clone();
                    self.register(&mut s, prompt, &all);
                }
                s.slots[i].allocs_done += 1;
                s.slots[i].live = Some(Seq {
                    toks: prompt.to_vec(),
                    blocks,
                    appends: 0,
                });
            }
            KvAct::Append { slot } => {
                let i = slot as usize;
                let mut seq = s.slots[i]
                    .live
                    .take()
                    .ok_or_else(|| "append on idle slot".to_string())?;
                let tok = append_token(i);
                let boundary = seq.toks.len() % bt == 0;
                if boundary {
                    let b = s.free.pop().ok_or_else(|| {
                        "append enabled without free block".to_string()
                    })?;
                    s.rc[b as usize] = 1;
                    s.content[b as usize] = vec![tok];
                    seq.blocks.push(b);
                } else {
                    let tail = *seq.blocks.last().ok_or_else(|| {
                        "live sequence with no blocks".to_string()
                    })?;
                    let fill = self.tail_fill(&seq);
                    let shared_tail = s.rc[tail as usize] > 1;
                    if shared_tail && !self.mutant(KvMutant::SkipCow) {
                        // copy-on-write: private copy of the claimed
                        // prefix, then extend it
                        let b = s.free.pop().ok_or_else(|| {
                            "cow enabled without free block".to_string()
                        })?;
                        s.rc[b as usize] = 1;
                        let mut copied =
                            s.content[tail as usize][..fill].to_vec();
                        copied.push(tok);
                        s.content[b as usize] = copied;
                        let last = seq.blocks.len() - 1;
                        seq.blocks[last] = b;
                        self.unref(&mut s, tail);
                    } else {
                        // in-place write at the sequence's own fill
                        // position (under SkipCow this clobbers a
                        // longer-claiming sharer's token)
                        let c = &mut s.content[tail as usize];
                        if fill < c.len() {
                            c[fill] = tok;
                        } else {
                            c.push(tok);
                        }
                    }
                }
                seq.toks.push(tok);
                seq.appends += 1;
                s.slots[i].live = Some(seq);
            }
            KvAct::Release { slot } => {
                self.release_slot(&mut s, slot as usize);
            }
            KvAct::FencePreempt => {
                for i in 0..s.slots.len() {
                    self.release_slot(&mut s, i);
                }
                s.fences_done += 1;
            }
        }
        Ok(s)
    }

    fn check(&self, s: &KvState) -> Option<String> {
        let bt = self.cfg.block_tokens;
        let n = self.cfg.total_blocks;
        // refcount conservation
        let mut refs = vec![0u8; n];
        for slot in &s.slots {
            if let Some(seq) = &slot.live {
                for &b in &seq.blocks {
                    refs[b as usize] += 1;
                }
            }
        }
        for b in 0..n {
            if s.rc[b] != refs[b] {
                return Some(format!(
                    "block {b}: rc={} but {} live references",
                    s.rc[b], refs[b]
                ));
            }
        }
        // free-list exactness
        let mut in_free = vec![false; n];
        for &b in &s.free {
            if in_free[b as usize] {
                return Some(format!("block {b} on the free list twice"));
            }
            in_free[b as usize] = true;
        }
        for b in 0..n {
            if in_free[b] && s.rc[b] != 0 {
                return Some(format!("block {b} free while referenced"));
            }
            if !in_free[b] && s.rc[b] == 0 {
                return Some(format!("block {b} leaked (rc 0, not free)"));
            }
        }
        // per-sequence shape + content faithfulness
        for (i, slot) in s.slots.iter().enumerate() {
            let Some(seq) = &slot.live else { continue };
            let mut seen = vec![false; n];
            for &b in &seq.blocks {
                if seen[b as usize] {
                    return Some(format!(
                        "slot {i}: block {b} appears twice in the table"
                    ));
                }
                seen[b as usize] = true;
            }
            let lo = (seq.blocks.len() - 1) * bt;
            let hi = seq.blocks.len() * bt;
            if seq.toks.len() <= lo || seq.toks.len() > hi {
                return Some(format!(
                    "slot {i}: {} tokens in {} blocks",
                    seq.toks.len(),
                    seq.blocks.len()
                ));
            }
            if let Some(msg) =
                claims_check(s, &seq.toks, &seq.blocks, bt, &format!("slot {i}"))
            {
                return Some(msg);
            }
        }
        // registry well-formedness + content faithfulness (ABA guard)
        for (j, e) in s.registry.iter().enumerate() {
            if e.blocks.len() != e.tokens.len().div_ceil(bt) {
                return Some(format!(
                    "registry[{j}]: {} tokens but {} blocks",
                    e.tokens.len(),
                    e.blocks.len()
                ));
            }
            for &b in &e.blocks {
                if s.rc[b as usize] == 0 {
                    return Some(format!(
                        "registry[{j}] ({:?}) names freed block {b} — \
                         rc-0 purge skipped (ABA hazard)",
                        e.tokens
                    ));
                }
            }
            if s.registry[j + 1..].iter().any(|o| o.tokens == e.tokens) {
                return Some(format!(
                    "registry: duplicate key {:?}",
                    e.tokens
                ));
            }
            if let Some(msg) = claims_check(
                s,
                &e.tokens,
                &e.blocks,
                bt,
                &format!("registry[{j}]"),
            ) {
                return Some(msg);
            }
        }
        None
    }

    fn check_terminal(&self, s: &KvState) -> Option<String> {
        if s.rc.iter().any(|&r| r != 0) {
            return Some("terminal state holds references".to_string());
        }
        if s.free.len() != self.cfg.total_blocks {
            return Some(format!(
                "free list has {} of {} blocks — leak",
                s.free.len(),
                self.cfg.total_blocks
            ));
        }
        if !s.registry.is_empty() {
            return Some(format!(
                "{} registry entr(ies) survived full release",
                s.registry.len()
            ));
        }
        None
    }
}

/// Every claimant of a block must see exactly its own token prefix in
/// the block's physical content.
fn claims_check(
    s: &KvState,
    toks: &[i32],
    blocks: &[u8],
    bt: usize,
    who: &str,
) -> Option<String> {
    for (pos, &b) in blocks.iter().enumerate() {
        let lo = pos * bt;
        let claim = toks.len().saturating_sub(lo).min(bt);
        let c = &s.content[b as usize];
        if claim > c.len() {
            return Some(format!(
                "{who}: claims {claim} tokens of block {b} holding {}",
                c.len()
            ));
        }
        if c[..claim] != toks[lo..lo + claim] {
            return Some(format!(
                "{who}: block {b} holds {:?} where {:?} was expected — \
                 shared content clobbered or stale",
                &c[..claim],
                &toks[lo..lo + claim]
            ));
        }
    }
    None
}
