//! pallas-model: bounded exhaustive model checking for the streaming
//! pool's epoch-fence protocol and the `KvBlockManager` refcount /
//! prefix-registry algebra, with counterexample replay against the
//! real implementation.
//!
//! * [`explore`] — the generic BFS explorer (states, traces, stats);
//! * [`pool_model`] — the pool protocol as a transition system, plus
//!   deliberately injected mutants;
//! * [`kv_model`] — the block-allocator algebra likewise;
//! * [`replay`] — the bridge that projects counterexample traces onto
//!   `testkit::interleave` plans / real `KvBlockManager` call
//!   sequences and compares predictions against reality;
//! * [`vocab`] — the protocol vocabulary pinned to the implementation
//!   enums by lint rule M1 (tools/lint + mirror.py).
//!
//! The in-crate tests below are the bridge's non-vacuity proof: clean
//! models explore to `Ok` and replay with zero divergence; every
//! mutant yields a counterexample, and the flagship mutants' replayed
//! plans demonstrably disagree with the real pool / manager.

pub mod explore;
pub mod kv_model;
pub mod pool_model;
pub mod replay;
pub mod vocab;

#[cfg(test)]
mod tests {
    use crate::explore::{explore, Outcome};
    use crate::kv_model::{KvCfg, KvModel, KvMutant};
    use crate::pool_model::{PoolCfg, PoolModel, PoolMutant};
    use crate::replay::{
        canonical_clean_kv_trace, canonical_clean_trace,
        extend_with_next_alloc, replay_kv_trace, replay_pool_trace,
    };

    const CAP: usize = 4_000_000;

    fn pool_cfg(
        replicas: usize,
        requests: usize,
        fences: usize,
        aborts: usize,
        kills: usize,
        mutant: Option<PoolMutant>,
    ) -> PoolCfg {
        PoolCfg { replicas, requests, fences, aborts, kills, mutant }
    }

    #[test]
    fn pool_clean_bound_explores_ok() {
        let m = PoolModel::new(pool_cfg(2, 2, 2, 0, 0, None));
        match explore(&m, CAP) {
            Outcome::Ok(st) => {
                assert!(st.terminals >= 1, "no terminal state reached");
            }
            Outcome::Violation(_, v) => {
                panic!("clean pool model violated: {} @ {:?}", v.message, v.trace)
            }
            Outcome::CapExceeded(st) => {
                panic!("state cap exceeded at {} states", st.states)
            }
        }
    }

    #[test]
    fn pool_clean_with_aborts_explores_ok() {
        let m = PoolModel::new(pool_cfg(2, 2, 1, 1, 0, None));
        match explore(&m, CAP) {
            Outcome::Ok(_) => {}
            Outcome::Violation(_, v) => {
                panic!("abort config violated: {} @ {:?}", v.message, v.trace)
            }
            Outcome::CapExceeded(st) => {
                panic!("state cap exceeded at {} states", st.states)
            }
        }
    }

    #[test]
    fn pool_clean_with_kill_and_reaper_explores_ok() {
        let m = PoolModel::new(pool_cfg(2, 2, 1, 0, 1, None));
        match explore(&m, CAP) {
            Outcome::Ok(_) => {}
            Outcome::Violation(_, v) => {
                panic!("kill config violated: {} @ {:?}", v.message, v.trace)
            }
            Outcome::CapExceeded(st) => {
                panic!("state cap exceeded at {} states", st.states)
            }
        }
    }

    #[test]
    fn every_pool_mutant_yields_a_counterexample() {
        for (name, mutant) in PoolMutant::ALL {
            let m =
                PoolModel::new(pool_cfg(2, 2, 1, 0, 0, Some(mutant)));
            match explore(&m, CAP) {
                Outcome::Violation(_, v) => {
                    assert!(
                        !v.trace.is_empty(),
                        "mutant {name}: empty counterexample trace"
                    );
                }
                Outcome::Ok(_) => {
                    panic!("mutant {name} explored clean — property gap")
                }
                Outcome::CapExceeded(_) => {
                    panic!("mutant {name}: state cap exceeded")
                }
            }
        }
    }

    #[test]
    fn clean_pool_trace_replays_in_agreement() {
        let m = PoolModel::new(pool_cfg(2, 2, 2, 0, 0, None));
        let trace = canonical_clean_trace(&m);
        let diverged = replay_pool_trace(&m, &trace)
            .expect("clean replay infrastructure");
        assert!(
            diverged.is_empty(),
            "clean model diverged from the real pool: {diverged:?}"
        );
    }

    #[test]
    fn admit_past_fence_counterexample_fails_against_real_pool() {
        let m = PoolModel::new(pool_cfg(
            2,
            1,
            1,
            0,
            0,
            Some(PoolMutant::AdmitPastFence),
        ));
        let v = match explore(&m, CAP) {
            Outcome::Violation(_, v) => v,
            _ => panic!("admit_past_fence mutant did not violate"),
        };
        assert!(
            v.message.contains("completion epoch")
                || v.message.contains("stamp"),
            "unexpected violation: {}",
            v.message
        );
        let diverged = replay_pool_trace(&m, &v.trace)
            .expect("mutant trace must be plan-expressible");
        assert!(
            !diverged.is_empty(),
            "mutant counterexample replayed cleanly against the real \
             pool — the bridge is vacuous"
        );
    }

    #[test]
    fn kv_clean_bound_explores_ok() {
        let m = KvModel::new(KvCfg::default());
        match explore(&m, CAP) {
            Outcome::Ok(st) => {
                assert!(st.terminals >= 1, "no terminal state reached");
            }
            Outcome::Violation(_, v) => {
                panic!("clean kv model violated: {} @ {:?}", v.message, v.trace)
            }
            Outcome::CapExceeded(st) => {
                panic!("state cap exceeded at {} states", st.states)
            }
        }
    }

    #[test]
    fn every_kv_mutant_yields_a_counterexample() {
        for (name, mutant) in KvMutant::ALL {
            let m = KvModel::new(KvCfg {
                mutant: Some(mutant),
                ..KvCfg::default()
            });
            match explore(&m, CAP) {
                Outcome::Violation(_, v) => {
                    assert!(
                        !v.trace.is_empty(),
                        "kv mutant {name}: empty counterexample trace"
                    );
                }
                Outcome::Ok(_) => {
                    panic!("kv mutant {name} explored clean — property gap")
                }
                Outcome::CapExceeded(_) => {
                    panic!("kv mutant {name}: state cap exceeded")
                }
            }
        }
    }

    #[test]
    fn clean_kv_trace_replays_in_agreement() {
        let m = KvModel::new(KvCfg::default());
        let trace = canonical_clean_kv_trace(&m);
        assert!(
            trace.len() >= m.cfg.slots,
            "canonical kv trace unexpectedly short: {trace:?}"
        );
        let diverged =
            replay_kv_trace(&m, &trace).expect("clean kv replay");
        assert!(
            diverged.is_empty(),
            "clean kv model diverged from the real manager: {diverged:?}"
        );
    }

    #[test]
    fn stale_registry_counterexample_diverges_on_real_manager() {
        let m = KvModel::new(KvCfg {
            mutant: Some(KvMutant::SkipRc0Purge),
            ..KvCfg::default()
        });
        let v = match explore(&m, CAP) {
            Outcome::Violation(_, v) => v,
            _ => panic!("skip_rc0_purge mutant did not violate"),
        };
        assert!(
            v.message.contains("purge") || v.message.contains("freed"),
            "unexpected violation: {}",
            v.message
        );
        // the violation itself is a stale-registry state; one more
        // allocation turns it into an observable grant divergence
        let trace = extend_with_next_alloc(&m, &v.trace)
            .expect("stale state must still admit an allocation");
        let diverged =
            replay_kv_trace(&m, &trace).expect("kv replay infra");
        assert!(
            !diverged.is_empty(),
            "stale-registry counterexample replayed cleanly against \
             the real manager — the bridge is vacuous"
        );
    }

    #[test]
    fn vocab_pairs_are_unique_and_nonempty() {
        let v = crate::vocab::PROTOCOL_VOCAB;
        assert!(v.len() >= 17);
        for (i, a) in v.iter().enumerate() {
            assert!(
                !v[i + 1..].contains(a),
                "duplicate vocab pair {a:?}"
            );
        }
    }
}
