//! Generic bounded explicit-state exploration.
//!
//! Breadth-first search over a [`Model`]'s transition system with a
//! canonical `Ord` state for deduplication and parent pointers for
//! counterexample reconstruction. BFS (not DFS) on purpose: the first
//! violation found is at minimal depth, so counterexample traces are
//! already short without a shrinking pass.
//!
//! A model reports violations through three channels:
//!
//! * `apply` returns `Err` for a transition-level violation (the
//!   property is about the step itself, e.g. "this completion's epoch
//!   does not match its admission epoch");
//! * `check` returns `Some` for a state invariant;
//! * `check_terminal` returns `Some` for an end-state obligation in a
//!   state with no enabled actions (every-ticket-resolves, owed == 0).
//!   Deadlock-freedom is folded in here: a stuck state that does not
//!   meet the terminal obligations *is* the deadlock counterexample.

use std::collections::{BTreeMap, VecDeque};

/// An abstracted transition system with safety properties.
pub trait Model {
    type State: Clone + Ord;
    type Action: Clone + std::fmt::Debug;

    fn initial(&self) -> Self::State;

    /// Enabled actions in `s`; an empty set marks `s` terminal.
    fn actions(&self, s: &Self::State, out: &mut Vec<Self::Action>);

    /// Successor of `s` under `a`; `Err` is a transition violation.
    fn apply(
        &self,
        s: &Self::State,
        a: &Self::Action,
    ) -> Result<Self::State, String>;

    /// State invariant; `Some(msg)` names the violated property.
    fn check(&self, s: &Self::State) -> Option<String>;

    /// Obligations of a terminal state (deadlock-freedom included).
    fn check_terminal(&self, s: &Self::State) -> Option<String>;
}

/// Exploration totals, reported even on violation.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub states: usize,
    pub transitions: usize,
    pub depth: usize,
    pub terminals: usize,
}

/// A violated property plus the linearized action trace reaching it.
#[derive(Clone, Debug)]
pub struct Violation<A> {
    pub message: String,
    pub trace: Vec<A>,
}

/// Result of a bounded exploration.
pub enum Outcome<A> {
    /// Every reachable state within the bound satisfies every property.
    Ok(Stats),
    /// A property failed; the trace replays from the initial state.
    Violation(Stats, Violation<A>),
    /// The state cap was hit before the frontier emptied: the check is
    /// inconclusive and must be treated as a failure by gating CI.
    CapExceeded(Stats),
}

/// Exhaustively explore `m` up to `max_states` distinct states.
pub fn explore<M: Model>(m: &M, max_states: usize) -> Outcome<M::Action> {
    let mut stats = Stats::default();
    let init = m.initial();
    if let Some(msg) = m.check(&init) {
        return Outcome::Violation(
            stats,
            Violation { message: msg, trace: Vec::new() },
        );
    }
    // seen maps canonical state -> id; parents[id] reconstructs traces.
    let mut seen: BTreeMap<M::State, usize> = BTreeMap::new();
    let mut parents: Vec<Option<(usize, M::Action)>> = vec![None];
    let mut depth_of: Vec<usize> = vec![0];
    seen.insert(init.clone(), 0);
    let mut queue: VecDeque<(M::State, usize)> = VecDeque::new();
    queue.push_back((init, 0));
    stats.states = 1;

    let mut acts: Vec<M::Action> = Vec::new();
    while let Some((state, id)) = queue.pop_front() {
        let depth = depth_of[id];
        stats.depth = stats.depth.max(depth);
        acts.clear();
        m.actions(&state, &mut acts);
        if acts.is_empty() {
            stats.terminals += 1;
            if let Some(msg) = m.check_terminal(&state) {
                return Outcome::Violation(
                    stats,
                    Violation {
                        message: format!("terminal-state violation: {msg}"),
                        trace: trace_to(&parents, id),
                    },
                );
            }
            continue;
        }
        for a in &acts {
            stats.transitions += 1;
            let next = match m.apply(&state, a) {
                Ok(next) => next,
                Err(msg) => {
                    let mut trace = trace_to(&parents, id);
                    trace.push(a.clone());
                    return Outcome::Violation(
                        stats,
                        Violation { message: msg, trace },
                    );
                }
            };
            if let Some(msg) = m.check(&next) {
                let mut trace = trace_to(&parents, id);
                trace.push(a.clone());
                return Outcome::Violation(
                    stats,
                    Violation {
                        message: format!("invariant violation: {msg}"),
                        trace,
                    },
                );
            }
            if seen.contains_key(&next) {
                continue;
            }
            if stats.states >= max_states {
                return Outcome::CapExceeded(stats);
            }
            let nid = parents.len();
            seen.insert(next.clone(), nid);
            parents.push(Some((id, a.clone())));
            depth_of.push(depth + 1);
            queue.push_back((next, nid));
            stats.states += 1;
        }
    }
    Outcome::Ok(stats)
}

fn trace_to<A: Clone>(
    parents: &[Option<(usize, A)>],
    mut id: usize,
) -> Vec<A> {
    let mut rev = Vec::new();
    while let Some(Some((pid, a))) = parents.get(id) {
        rev.push(a.clone());
        id = *pid;
    }
    rev.reverse();
    rev
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy counter that must never reach 3 and must end even.
    struct Counter {
        limit: u8,
        bad: Option<u8>,
    }

    impl Model for Counter {
        type State = u8;
        type Action = u8;

        fn initial(&self) -> u8 {
            0
        }

        fn actions(&self, s: &u8, out: &mut Vec<u8>) {
            if *s < self.limit {
                out.push(1);
                out.push(2);
            }
        }

        fn apply(&self, s: &u8, a: &u8) -> Result<u8, String> {
            Ok(s.saturating_add(*a).min(self.limit))
        }

        fn check(&self, s: &u8) -> Option<String> {
            (Some(*s) == self.bad).then(|| format!("reached {s}"))
        }

        fn check_terminal(&self, s: &u8) -> Option<String> {
            (s % 2 != 0).then(|| format!("odd terminal {s}"))
        }
    }

    #[test]
    fn clean_model_explores_to_ok() {
        let m = Counter { limit: 6, bad: None };
        match explore(&m, 1000) {
            Outcome::Ok(st) => {
                assert!(st.states >= 7);
                assert!(st.terminals >= 1);
            }
            _ => panic!("expected Ok"),
        }
    }

    #[test]
    fn invariant_violation_yields_minimal_trace() {
        let m = Counter { limit: 6, bad: Some(3) };
        match explore(&m, 1000) {
            Outcome::Violation(_, v) => {
                // BFS: 3 is reached in 2 steps (1+2 or 2+1), never 3.
                assert_eq!(v.trace.len(), 2);
                assert!(v.message.contains("reached 3"));
            }
            _ => panic!("expected Violation"),
        }
    }

    #[test]
    fn terminal_obligation_is_checked() {
        let m = Counter { limit: 5, bad: None };
        match explore(&m, 1000) {
            Outcome::Violation(_, v) => {
                assert!(v.message.contains("odd terminal 5"));
            }
            _ => panic!("expected terminal violation"),
        }
    }

    #[test]
    fn cap_exceeded_is_reported() {
        let m = Counter { limit: 200, bad: None };
        match explore(&m, 10) {
            Outcome::CapExceeded(st) => assert_eq!(st.states, 10),
            _ => panic!("expected CapExceeded"),
        }
    }
}
