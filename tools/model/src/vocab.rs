//! The protocol vocabulary this model checker claims to cover.
//!
//! Every (enum, variant) pair below names a message or state of the
//! real implementation: the `Ctl` / `ToWorker` / `Ordered` / `Fence` /
//! `Event` enums in `rust/src/rollout/pool.rs` and the `FenceState`
//! enum in `rust/src/testkit/hb.rs`. Lint rule M1 (tools/lint +
//! mirror.py) extracts the variants from those files and byte-compares
//! them against this table in both directions:
//!
//! * a variant added to the implementation but missing here fails the
//!   lint at the variant's declaration line — you cannot grow the
//!   protocol without consciously extending (or explicitly abstracting
//!   it in) the model;
//! * a pair listed here that no longer exists in the implementation
//!   fails the lint at this file — the model cannot drift ahead of
//!   reality either.
//!
//! The M1 extractor parses this file lexically: each pair must sit on
//! its own line of the form `("Enum", "Variant"),`. Keep it that way.
//!
//! How each variant maps into the abstract model (see pool_model.rs /
//! kv_model.rs and DESIGN.md §11):
//!
//! * `Ctl::Abort`         -> `Msg::Abort` (inflight-cancel / backlog-pull)
//! * `Ctl::Discard`       -> abstracted: same channel position as Abort,
//!                           no completion emitted; covered by Abort's
//!                           FIFO interleavings
//! * `Ctl::Stats`         -> abstracted: read-only side channel, no
//!                           protocol state touched
//! * `Ctl::Shutdown`      -> `Act::Kill` (serve-loop exit dropping
//!                           channel, backlog, inflight, parked fence)
//! * `ToWorker::Ordered`  -> FIFO-ordered half of `Msg`
//! * `ToWorker::Ctl`      -> control half of `Msg` (same FIFO channel)
//! * `Ordered::Submit`    -> `Msg::Submit { req, stamp }`
//! * `Ordered::Fence`     -> `Msg::Fence { target }`
//! * `Fence::Weights`     -> fence payload, collapsed: only `target()`
//!                           matters to the protocol
//! * `Fence::KvScales`    -> fence payload, collapsed likewise
//! * `Event::Done`        -> `Ev::Done { req, epoch }`
//! * `Event::Aborted`     -> `Ev::Aborted { req }`
//! * `Event::Failed`      -> `Ev::Failed { req }`
//! * `Event::Fence`       -> `Ev::FenceAck { target }`
//! * `FenceState::Running`   -> replica with `parked == None`
//! * `FenceState::Draining`  -> replica with `parked == Some(target)`
//! * `FenceState::Installed` -> `engine_epoch` bumped by `ApplyFence`

/// (enum name, variant name) pairs pinned by lint rule M1.
pub const PROTOCOL_VOCAB: &[(&str, &str)] = &[
    ("Ctl", "Abort"),
    ("Ctl", "Discard"),
    ("Ctl", "Stats"),
    ("Ctl", "Shutdown"),
    ("ToWorker", "Ordered"),
    ("ToWorker", "Ctl"),
    ("Ordered", "Submit"),
    ("Ordered", "Fence"),
    ("Fence", "Weights"),
    ("Fence", "KvScales"),
    ("Event", "Done"),
    ("Event", "Aborted"),
    ("Event", "Failed"),
    ("Event", "Fence"),
    ("FenceState", "Running"),
    ("FenceState", "Draining"),
    ("FenceState", "Installed"),
];
