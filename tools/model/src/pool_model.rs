//! Abstract model of the streaming pool's epoch-fence protocol
//! (rust/src/rollout/pool.rs), checked exhaustively by `explore`.
//!
//! ## Abstraction mapping (see DESIGN.md §11 and vocab.rs)
//!
//! One pool thread plus N worker actors. Each replica's `ToWorker`
//! channel is a bounded FIFO ([`Msg`]); completions flow back through
//! a per-replica FIFO ([`Ev`]) — the real implementation multiplexes
//! one shared channel, and per-replica queues with a free interleaving
//! of drains is a superset of the merge orders that channel can
//! produce. Weight payloads are collapsed to their fence target;
//! engine execution is collapsed to "an inflight entry completes".
//!
//! The worker's serve loop becomes three atomic actions, justified by
//! the loop's single-threadedness:
//!
//! * `WorkerIngest` — handle one channel message (Ctl immediately,
//!   Ordered into the backlog while a fence is parked);
//! * `WorkerComplete` — one inflight request finishes and emits Done;
//! * `WorkerApplyFence` — install + ack + backlog replay as one step.
//!   In the real worker, `fence.is_none()` implies an empty backlog at
//!   ingest time and the replay runs to completion without an
//!   interleaved recv, so the merged action loses no interleavings.
//!
//! ## Properties
//!
//! Transition-level: a completion's epoch equals its admission epoch
//! (no completion spans an install), a drained Done's epoch equals the
//! ticket's submit stamp, fence targets are consecutive, acks arrive
//! exactly once and in order and only when owed. State invariant: an
//! un-parked replica has an empty backlog; per-replica ack accounting
//! conserves (`sent == acked + owed + quarantined`). Terminal: every
//! submitted ticket resolved exactly once, no acks owed by any live or
//! reaped replica (deadlock-freedom folds in: a stuck state missing
//! these obligations is the counterexample).
//!
//! ## Known abstractions (soundness caveats)
//!
//! * `place()` skips dead replicas directly instead of reaping them on
//!   send failure; `Reap` is a separate action.
//! * `Abort` is only enabled while the ticket's replica is alive (the
//!   real abort retries through the reaper).
//! * `Ctl::Discard` / `Ctl::Stats` are not modeled (Discard shares
//!   Abort's FIFO position without emitting a completion; Stats is
//!   read-only).

use crate::explore::Model;

/// Exploration bound + mutant selection.
#[derive(Clone, Copy, Debug)]
pub struct PoolCfg {
    pub replicas: usize,
    pub requests: usize,
    pub fences: usize,
    pub aborts: usize,
    pub kills: usize,
    pub mutant: Option<PoolMutant>,
}

impl Default for PoolCfg {
    fn default() -> Self {
        // the documented bound: 2 replicas x 3 requests x 2 fences,
        // plus one abort. Kills get their own smaller config (the CLI
        // runs both; see main.rs).
        PoolCfg {
            replicas: 2,
            requests: 3,
            fences: 2,
            aborts: 1,
            kills: 0,
            mutant: None,
        }
    }
}

/// Deliberately injected protocol bugs; each must yield a
/// counterexample whose replay diverges from the real pool.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PoolMutant {
    /// Ingest admits an `Ordered::Submit` past a parked fence,
    /// skipping both the backlog and the stamp check — the request
    /// runs under the old weights while stamped for the new ones.
    AdmitPastFence,
    /// `ApplyFence` installs without emitting the ack — the pool's
    /// `owed` accounting never drains.
    SkipFenceAck,
    /// `ApplyFence` fires with inflight requests still running — the
    /// install is no longer quiescent.
    InstallWithInflight,
    /// The pool stamps submissions one epoch ahead of the weights it
    /// actually installed.
    StampSkew,
}

impl PoolMutant {
    pub fn parse(name: &str) -> Option<PoolMutant> {
        match name {
            "admit_past_fence" => Some(PoolMutant::AdmitPastFence),
            "skip_fence_ack" => Some(PoolMutant::SkipFenceAck),
            "install_with_inflight" => {
                Some(PoolMutant::InstallWithInflight)
            }
            "stamp_skew" => Some(PoolMutant::StampSkew),
            _ => None,
        }
    }

    pub const ALL: [(&'static str, PoolMutant); 4] = [
        ("admit_past_fence", PoolMutant::AdmitPastFence),
        ("skip_fence_ack", PoolMutant::SkipFenceAck),
        ("install_with_inflight", PoolMutant::InstallWithInflight),
        ("stamp_skew", PoolMutant::StampSkew),
    ];
}

/// `ToWorker` collapsed: Ordered::{Submit,Fence} + Ctl::Abort ride the
/// same FIFO, exactly like the real channel.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Msg {
    Submit { req: u8, stamp: u8 },
    Fence { target: u8 },
    Abort { req: u8 },
}

/// `Event` collapsed (Fence ack result is always Ok in-model).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Ev {
    Done { req: u8, epoch: u8 },
    Aborted { req: u8 },
    Failed { req: u8 },
    FenceAck { target: u8 },
}

/// How a ticket resolved at the pool.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Resolution {
    Done { epoch: u8 },
    Aborted,
    Failed,
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct Ticket {
    pub stamp: u8,
    pub replica: u8,
    pub resolution: Option<Resolution>,
    pub abort_sent: bool,
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct Replica {
    pub alive: bool,
    pub reaped: bool,
    /// ToWorker FIFO (head at index 0).
    pub chan: Vec<Msg>,
    /// Completion FIFO back to the pool (head at index 0).
    pub events: Vec<Ev>,
    pub engine_epoch: u8,
    /// Parked fence target (FenceState::Draining).
    pub parked: Option<u8>,
    /// Ordered messages deferred behind the parked fence.
    pub backlog: Vec<Msg>,
    /// (req, admission epoch) pairs the engine is running.
    pub inflight: Vec<(u8, u8)>,
    /// Fence messages successfully sent to this replica.
    pub fenced: u8,
    /// Acks the pool is still owed.
    pub owed: u8,
    /// Ack targets received, in arrival order.
    pub acked: Vec<u8>,
    /// Acks written off by the reaper.
    pub quarantined: u8,
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct PoolState {
    pub epoch: u8,
    pub fences_sent: u8,
    pub next_req: u8,
    pub aborts_sent: u8,
    pub kills_done: u8,
    pub tickets: Vec<Ticket>,
    pub replicas: Vec<Replica>,
}

/// One interleaving step. Pool-side actions project onto
/// `testkit::interleave::Event`s for replay; worker-side actions are
/// internal.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PoolAct {
    Submit,
    Fence,
    Abort { req: u8 },
    WorkerIngest { replica: u8 },
    WorkerComplete { replica: u8, slot: u8 },
    WorkerApplyFence { replica: u8 },
    PoolDrain { replica: u8 },
    Kill { replica: u8 },
    Reap { replica: u8 },
}

pub struct PoolModel {
    pub cfg: PoolCfg,
    /// When set, transition-level property failures are handled the
    /// way the real implementation handles them (admission mismatch
    /// emits `Failed`, a mid-install completion is tagged with the
    /// current engine epoch, ...) instead of aborting exploration.
    /// Used by the replay bridge to compute a mutant model's
    /// *predicted* outcomes past the violation point.
    pub lenient: bool,
}

impl PoolModel {
    pub fn new(cfg: PoolCfg) -> PoolModel {
        PoolModel { cfg, lenient: false }
    }

    fn mutant(&self, m: PoolMutant) -> bool {
        self.cfg.mutant == Some(m)
    }

    /// Round-robin placement skipping dead replicas, mirroring
    /// `place()`'s retry loop (abstraction: no reap on send failure).
    fn place(&self, s: &PoolState, start: usize) -> Option<usize> {
        (0..self.cfg.replicas)
            .map(|k| (start + k) % self.cfg.replicas)
            .find(|&r| s.replicas[r].alive)
    }

    /// `handle_ordered`: admit (stamp-checked) or park a fence.
    fn handle_ordered(
        &self,
        s: &mut PoolState,
        r: usize,
        msg: Msg,
    ) -> Result<(), String> {
        let rep = &mut s.replicas[r];
        match msg {
            Msg::Submit { req, stamp } => {
                if stamp != rep.engine_epoch {
                    // the real worker emits Event::Failed here; in the
                    // clean model FIFO ordering makes this unreachable,
                    // so reaching it is a protocol violation
                    if !self.lenient {
                        return Err(format!(
                            "req {req}: admitted with stamp {stamp} at \
                             engine epoch {} — submission crossed the \
                             fence FIFO",
                            rep.engine_epoch
                        ));
                    }
                    rep.events.push(Ev::Failed { req });
                } else {
                    rep.inflight.push((req, rep.engine_epoch));
                }
            }
            Msg::Fence { target } => {
                if target != rep.engine_epoch + 1 && !self.lenient {
                    return Err(format!(
                        "replica {r}: fence target {target} not \
                         consecutive after epoch {}",
                        rep.engine_epoch
                    ));
                }
                rep.parked = Some(target);
            }
            Msg::Abort { .. } => {
                return Err(format!(
                    "replica {r}: Ctl message routed into the ordered \
                     path"
                ));
            }
        }
        Ok(())
    }

    /// One pool-side event-channel drain, shared by `PoolDrain` and
    /// the reaper's pump.
    fn handle_event(
        &self,
        s: &mut PoolState,
        r: usize,
        ev: Ev,
    ) -> Result<(), String> {
        match ev {
            Ev::Done { req, epoch } => {
                let t = &mut s.tickets[req as usize];
                // the real pool gates on `outstanding.remove()`: an
                // event for an already-resolved ticket is dropped
                if t.resolution.is_none() {
                    if epoch != t.stamp && !self.lenient {
                        return Err(format!(
                            "req {req}: completion epoch {epoch} != \
                             submit stamp {} (completion crossed a \
                             weight install)",
                            t.stamp
                        ));
                    }
                    t.resolution = Some(Resolution::Done { epoch });
                }
            }
            Ev::Aborted { req } => {
                let t = &mut s.tickets[req as usize];
                if t.resolution.is_none() {
                    t.resolution = Some(Resolution::Aborted);
                }
            }
            Ev::Failed { req } => {
                let t = &mut s.tickets[req as usize];
                if t.resolution.is_none() {
                    t.resolution = Some(Resolution::Failed);
                }
            }
            Ev::FenceAck { target } => {
                let rep = &mut s.replicas[r];
                if !self.lenient {
                    if rep.owed == 0 {
                        return Err(format!(
                            "replica {r}: fence ack {target} arrived \
                             with zero acks owed (duplicate ack)"
                        ));
                    }
                    if rep.acked.last().is_some_and(|&l| target <= l) {
                        return Err(format!(
                            "replica {r}: fence ack {target} out of \
                             order after {:?}",
                            rep.acked
                        ));
                    }
                }
                rep.owed = rep.owed.saturating_sub(1);
                rep.acked.push(target);
            }
        }
        Ok(())
    }
}

impl Model for PoolModel {
    type State = PoolState;
    type Action = PoolAct;

    fn initial(&self) -> PoolState {
        PoolState {
            epoch: 0,
            fences_sent: 0,
            next_req: 0,
            aborts_sent: 0,
            kills_done: 0,
            tickets: Vec::new(),
            replicas: (0..self.cfg.replicas)
                .map(|_| Replica {
                    alive: true,
                    reaped: false,
                    chan: Vec::new(),
                    events: Vec::new(),
                    engine_epoch: 0,
                    parked: None,
                    backlog: Vec::new(),
                    inflight: Vec::new(),
                    fenced: 0,
                    owed: 0,
                    acked: Vec::new(),
                    quarantined: 0,
                })
                .collect(),
        }
    }

    fn actions(&self, s: &PoolState, out: &mut Vec<PoolAct>) {
        if (s.next_req as usize) < self.cfg.requests {
            out.push(PoolAct::Submit);
        }
        if (s.fences_sent as usize) < self.cfg.fences {
            out.push(PoolAct::Fence);
        }
        if (s.aborts_sent as usize) < self.cfg.aborts {
            for (i, t) in s.tickets.iter().enumerate() {
                let alive = s.replicas[t.replica as usize].alive;
                if t.resolution.is_none() && !t.abort_sent && alive {
                    out.push(PoolAct::Abort { req: i as u8 });
                }
            }
        }
        for (r, rep) in s.replicas.iter().enumerate() {
            let r8 = r as u8;
            if rep.alive && !rep.chan.is_empty() {
                out.push(PoolAct::WorkerIngest { replica: r8 });
            }
            if rep.alive {
                for slot in 0..rep.inflight.len() {
                    out.push(PoolAct::WorkerComplete {
                        replica: r8,
                        slot: slot as u8,
                    });
                }
            }
            let quiescent = rep.inflight.is_empty()
                || self.mutant(PoolMutant::InstallWithInflight);
            if rep.alive && rep.parked.is_some() && quiescent {
                out.push(PoolAct::WorkerApplyFence { replica: r8 });
            }
            if !rep.events.is_empty() {
                out.push(PoolAct::PoolDrain { replica: r8 });
            }
            if rep.alive && (s.kills_done as usize) < self.cfg.kills {
                out.push(PoolAct::Kill { replica: r8 });
            }
            if !rep.alive && !rep.reaped {
                out.push(PoolAct::Reap { replica: r8 });
            }
        }
    }

    fn apply(
        &self,
        prev: &PoolState,
        a: &PoolAct,
    ) -> Result<PoolState, String> {
        let mut s = prev.clone();
        match *a {
            PoolAct::Submit => {
                let req = s.next_req;
                let stamp = if self.mutant(PoolMutant::StampSkew) {
                    s.epoch + 1
                } else {
                    s.epoch
                };
                match self.place(&s, req as usize % self.cfg.replicas) {
                    Some(r) => {
                        s.replicas[r].chan.push(Msg::Submit { req, stamp });
                        s.tickets.push(Ticket {
                            stamp,
                            replica: r as u8,
                            resolution: None,
                            abort_sent: false,
                        });
                    }
                    None => {
                        // submit() fails outright with no live replica
                        s.tickets.push(Ticket {
                            stamp,
                            replica: 0,
                            resolution: Some(Resolution::Failed),
                            abort_sent: false,
                        });
                    }
                }
                s.next_req += 1;
            }
            PoolAct::Fence => {
                // send_fence bumps the epoch unconditionally, then
                // counts owed acks per successful send
                s.epoch += 1;
                s.fences_sent += 1;
                let target = s.epoch;
                for rep in &mut s.replicas {
                    if rep.alive {
                        rep.chan.push(Msg::Fence { target });
                        rep.fenced += 1;
                        rep.owed += 1;
                    }
                }
            }
            PoolAct::Abort { req } => {
                let r = s.tickets[req as usize].replica as usize;
                s.tickets[req as usize].abort_sent = true;
                s.aborts_sent += 1;
                s.replicas[r].chan.push(Msg::Abort { req });
            }
            PoolAct::WorkerIngest { replica } => {
                let r = replica as usize;
                let msg = s.replicas[r].chan.remove(0);
                match msg {
                    Msg::Abort { req } => {
                        let rep = &mut s.replicas[r];
                        if let Some(pos) = rep
                            .inflight
                            .iter()
                            .position(|&(q, _)| q == req)
                        {
                            // engine.cancel: pull the running request
                            rep.inflight.remove(pos);
                            rep.events.push(Ev::Aborted { req });
                        } else if let Some(pos) =
                            rep.backlog.iter().position(|m| {
                                matches!(m, Msg::Submit { req: q, .. }
                                    if *q == req)
                            })
                        {
                            // backlog-cancel: the abort jumps the fence
                            rep.backlog.remove(pos);
                            rep.events.push(Ev::Aborted { req });
                        }
                        // unknown id: already completed — no-op
                    }
                    ordered => {
                        let parked = s.replicas[r].parked.is_some();
                        let admit_anyway = self
                            .mutant(PoolMutant::AdmitPastFence)
                            && matches!(ordered, Msg::Submit { .. });
                        if parked && admit_anyway {
                            // MUTANT: admit under the old weights,
                            // skipping backlog AND stamp check
                            if let Msg::Submit { req, .. } = ordered {
                                let rep = &mut s.replicas[r];
                                let e = rep.engine_epoch;
                                rep.inflight.push((req, e));
                            }
                        } else if parked {
                            s.replicas[r].backlog.push(ordered);
                        } else {
                            self.handle_ordered(&mut s, r, ordered)?;
                        }
                    }
                }
            }
            PoolAct::WorkerComplete { replica, slot } => {
                let r = replica as usize;
                let (req, admit_epoch) =
                    s.replicas[r].inflight.remove(slot as usize);
                let engine_epoch = s.replicas[r].engine_epoch;
                if admit_epoch != engine_epoch && !self.lenient {
                    return Err(format!(
                        "req {req}: admitted at epoch {admit_epoch} but \
                         completing at engine epoch {engine_epoch} — a \
                         weight install landed mid-flight"
                    ));
                }
                s.replicas[r]
                    .events
                    .push(Ev::Done { req, epoch: engine_epoch });
            }
            PoolAct::WorkerApplyFence { replica } => {
                let r = replica as usize;
                let target = s.replicas[r]
                    .parked
                    .ok_or_else(|| "apply without parked fence".to_string())?;
                s.replicas[r].engine_epoch = target;
                s.replicas[r].parked = None;
                if !self.mutant(PoolMutant::SkipFenceAck) {
                    s.replicas[r].events.push(Ev::FenceAck { target });
                }
                // backlog replay runs to completion (no interleaved
                // recv) and re-parks at the next fence, as in the
                // real worker's post-apply loop
                while s.replicas[r].parked.is_none()
                    && !s.replicas[r].backlog.is_empty()
                {
                    let msg = s.replicas[r].backlog.remove(0);
                    self.handle_ordered(&mut s, r, msg)?;
                }
            }
            PoolAct::PoolDrain { replica } => {
                let r = replica as usize;
                let ev = s.replicas[r].events.remove(0);
                self.handle_event(&mut s, r, ev)?;
            }
            PoolAct::Kill { replica } => {
                // the serve loop exits: channel contents, backlog,
                // inflight, and a parked fence are dropped on the
                // floor; already-emitted events remain drainable
                let r = replica as usize;
                let rep = &mut s.replicas[r];
                rep.alive = false;
                rep.chan.clear();
                rep.backlog.clear();
                rep.inflight.clear();
                rep.parked = None;
                s.kills_done += 1;
            }
            PoolAct::Reap { replica } => {
                let r = replica as usize;
                // pump: drain the dead replica's remaining events
                // before writing anything off (reap_dead_workers)
                while !s.replicas[r].events.is_empty() {
                    let ev = s.replicas[r].events.remove(0);
                    self.handle_event(&mut s, r, ev)?;
                }
                // write off exactly the owed acks
                let owed = s.replicas[r].owed;
                s.replicas[r].quarantined += owed;
                s.replicas[r].owed = 0;
                s.replicas[r].reaped = true;
                // re-route orphans at the CURRENT epoch, or fail them
                for (i, t) in s.tickets.iter_mut().enumerate() {
                    if t.replica as usize != r || t.resolution.is_some() {
                        continue;
                    }
                    let start = i % self.cfg.replicas;
                    let next = (0..self.cfg.replicas)
                        .map(|k| (start + k) % self.cfg.replicas)
                        .find(|&nr| s.replicas[nr].alive);
                    match next {
                        Some(nr) => {
                            t.replica = nr as u8;
                            t.stamp = s.epoch;
                            s.replicas[nr].chan.push(Msg::Submit {
                                req: i as u8,
                                stamp: s.epoch,
                            });
                        }
                        None => t.resolution = Some(Resolution::Failed),
                    }
                }
            }
        }
        Ok(s)
    }

    fn check(&self, s: &PoolState) -> Option<String> {
        for (r, rep) in s.replicas.iter().enumerate() {
            if rep.parked.is_none() && !rep.backlog.is_empty() {
                return Some(format!(
                    "replica {r}: backlog nonempty with no parked fence"
                ));
            }
            let acked = rep.acked.len() as u8;
            if rep.fenced != acked + rep.owed + rep.quarantined {
                return Some(format!(
                    "replica {r}: ack accounting broken — {} fences \
                     sent but {} acked + {} owed + {} quarantined",
                    rep.fenced,
                    acked,
                    rep.owed,
                    rep.quarantined
                ));
            }
        }
        None
    }

    fn check_terminal(&self, s: &PoolState) -> Option<String> {
        for (i, t) in s.tickets.iter().enumerate() {
            if t.resolution.is_none() {
                return Some(format!(
                    "ticket {i} never resolved (deadlocked or leaked)"
                ));
            }
        }
        for (r, rep) in s.replicas.iter().enumerate() {
            if rep.owed > 0 {
                return Some(format!(
                    "replica {r}: {} fence ack(s) still owed and never \
                     written off",
                    rep.owed
                ));
            }
            if !rep.alive && !rep.reaped {
                return Some(format!("replica {r}: dead but unreaped"));
            }
            if rep.alive
                && (rep.parked.is_some() || !rep.inflight.is_empty())
            {
                return Some(format!(
                    "replica {r}: stuck with parked fence or inflight \
                     work"
                ));
            }
        }
        None
    }
}

/// Apply a trace without enforcing transition properties (used by the
/// replay bridge to read a mutant model's *predicted* outcomes past
/// the violation point).
pub fn step_unchecked(
    m: &PoolModel,
    s: &PoolState,
    a: &PoolAct,
) -> PoolState {
    let lm = PoolModel { cfg: m.cfg, lenient: true };
    // lenient mode removes every Err site reachable from an enabled
    // action, so this cannot fail; keep the old state as a backstop
    lm.apply(s, a).unwrap_or_else(|_| s.clone())
}
