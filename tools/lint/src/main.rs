//! CLI for pallas-lint. Mirrors tools/lint/mirror.py:
//!   pallas-lint [--root DIR] [--write-baseline] [--verbose]
//! Exit code 0 when floors + ratchet pass, 1 on lint failure, 2 on
//! usage or I/O errors.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut write = false;
    let mut verbose = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => {
                let Some(dir) = args.next() else {
                    eprintln!("pallas-lint: --root requires a value");
                    return ExitCode::from(2);
                };
                root = PathBuf::from(dir);
            }
            "--write-baseline" => write = true,
            "--verbose" => verbose = true,
            other => {
                eprintln!("pallas-lint: unknown flag {other}");
                return ExitCode::from(2);
            }
        }
    }
    match pallas_lint::run(&root, write, verbose) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            ExitCode::from(2)
        }
    }
}
