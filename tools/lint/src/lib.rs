//! pallas-lint: a hermetic static-analysis pass over `rust/src`.
//!
//! Ten rule families, each encoding an invariant this repo has been
//! bitten by (see DESIGN.md §7 "Static invariants"):
//!
//! * **D1** — determinism: no `HashMap`/`HashSet`/`Instant`/
//!   `SystemTime`/`thread_rng` tokens inside the deterministic modules
//!   (`rollout/`, `sync/`, `coordinator/`, `testkit/`, `fp8/`).
//! * **D2** — ordering: no `partial_cmp` anywhere; no float `==`/`!=`
//!   where an operand is lexically float-typed (float literal or an
//!   `INFINITY`/`NEG_INFINITY`/`NAN` path).
//! * **P1** — panic-freedom: no `.unwrap()`/`.expect()`, no
//!   `panic!`/`unreachable!`/`todo!`/`unimplemented!`, no bare `[`
//!   indexing in non-test code.
//! * **C1** — fence protocol: channel sends must not be silently
//!   discarded (`let _ = x.send(..)` / `x.send(..).ok()`), because a
//!   dropped fence ack deadlocks the epoch barrier. Covers the pool's
//!   `send_ctl`/`send_ordered` wrappers too, so the `WorkerLink`
//!   indirection cannot erode the rule.
//! * **A1** — accounting arithmetic: in the resource-accounting files
//!   (`scheduler`/`kvcache`/`router`/`pool` and the `rl` module),
//!   unchecked `-`/`+=`/`-=` touching an accounting-flavored
//!   identifier (tokens/blocks/load/reserve/budget segments) must be
//!   `checked_*`/`saturating_*` or carry an audited allow — the
//!   `TrainBatch::assemble` usize underflow and the 0-token
//!   KV-allocator hole were both exactly this shape.
//! * **C2** — fence FIFO integrity: a raw `.send(ToWorker::..)` /
//!   `.try_send(ToWorker::..)` must not appear outside the audited
//!   `WorkerLink` wrapper — smuggling an ordered message around the
//!   wrapper would bypass the epoch-fence FIFO.
//! * **Q1** — scale provenance: quantized payloads are sealed inside
//!   `fp8/`. Outside it, constructing a `QuantizedTensor`/
//!   `Nvfp4Tensor` (`Type { .. }` / `Type::new`) or reading a payload
//!   field (`.codes`/`.scales`/`.packed`) through a binding the
//!   fn-scoped dataflow pass marked as quantized is flagged — the
//!   only sanctioned exits are the `dequantize`/`matmul_dequant`/
//!   accessor API, which keeps codes and scales together.
//! * **Q2** — scale freshness: in `rollout`/`sync`/`coordinator`,
//!   raw `kscale`/`vscale` plumbing and `ScaleSet` construction are
//!   confined to the epoch-fenced install path
//!   (`install_kv_scales`/`sync_kv_scales`/`kv_scales`); everything
//!   else reads scales through the `ScaleEpoch`-checked handle.
//! * **U1** — unit typing: in `fp8`/`rollout`/`sync`, a `+`/`-`/
//!   `+=`/`-=` whose operand chains resolve to *different* unit
//!   families (tokens/blocks/bytes/epoch) without a conversion-named
//!   factor in the chain (`block_tokens`, `bytes_per_token`) is
//!   flagged; the `Tokens`/`Blocks`/`Bytes`/`ScaleEpoch` newtypes in
//!   `util` carry the same invariant into the type system, the lint
//!   guards the residual `usize` boundary sites.
//! * **M1** — model drift: the protocol vocabulary that
//!   `tools/model/src/vocab.rs` pins (`("Enum", "Variant")` pairs, one
//!   per line) must match the implementation enums exactly, in both
//!   directions: every variant of `Ctl`/`ToWorker`/`Ordered`/`Fence`/
//!   `Event` in `rollout/pool.rs` and of `FenceState` in
//!   `testkit/hb.rs` must appear in the vocabulary, and every
//!   vocabulary pair must name a real variant. A drifted model checker
//!   silently verifies the wrong protocol, so M1 is a hard floor and
//!   has no allow escape.
//!
//! Per-site escape hatch: a `// lint: allow(<rule>): <reason>` comment
//! on the violation's line or the line immediately above. Allowed
//! sites are counted and reported, never hidden.
//!
//! `tools/lint/mirror.py` is a line-for-line Python mirror for
//! environments without a Rust toolchain; keep them in lockstep.
//!
//! The scanner is lexical on purpose: no `syn`, no type information.
//! It trades false positives (paid down via the baseline + `allow`)
//! for a zero-dependency build and sub-second scans.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Modules whose behavior must be bit-deterministic (rule D1).
pub const DET_MODULES: [&str; 5] =
    ["rollout", "sync", "coordinator", "testkit", "fp8"];
/// Modules where the P1 count must be zero (hard floor, baseline-proof).
pub const CORE_MODULES: [&str; 7] =
    ["rollout", "sync", "coordinator", "rl", "perfmodel", "root", "fp8"];
/// File stems whose arithmetic is accounting-critical (rule A1); the
/// `rl` module is in scope as a whole alongside these.
pub const A1_FILES: [&str; 4] = ["kvcache", "pool", "router", "scheduler"];
/// Modules where raw KV-scale plumbing is in scope for rule Q2.
pub const Q2_MODULES: [&str; 3] = ["rollout", "sync", "coordinator"];
/// Modules where unit-family mixing must be zero (rule U1 hard floor).
pub const U1_MODULES: [&str; 3] = ["fp8", "rollout", "sync"];

const RULE_NAMES: [&str; 10] =
    ["D1", "D2", "P1", "C1", "A1", "C2", "Q1", "Q2", "U1", "M1"];
const C1_METHODS: [&str; 4] = ["send", "try_send", "send_ctl", "send_ordered"];
/// Identifier segments that mark an accounting quantity (rule A1).
const ACCT_WORDS: [&str; 11] = [
    "block", "blocks", "budget", "budgets", "load", "loads", "reserve",
    "reserved", "reserves", "token", "tokens",
];
const D1_IDENTS: [&str; 5] =
    ["HashMap", "HashSet", "Instant", "SystemTime", "thread_rng"];
const FLOAT_CONSTS: [&str; 3] = ["INFINITY", "NEG_INFINITY", "NAN"];
const PANIC_MACROS: [&str; 4] =
    ["panic", "unreachable", "todo", "unimplemented"];
/// Sealed quantized-payload types (rule Q1).
const Q1_TYPES: [&str; 2] = ["QuantizedTensor", "Nvfp4Tensor"];
/// Their payload fields; reads outside `fp8/` are flagged.
const Q1_FIELDS: [&str; 3] = ["codes", "packed", "scales"];
/// Quantizing ctor fns whose results taint a binding as quantized.
const Q1_CTORS: [&str; 3] =
    ["quantize_blockwise", "quantize_default", "quantize_nvfp4"];
/// The epoch-fenced install path: the only fns allowed to touch raw
/// scales or build a `ScaleSet` (rule Q2).
const Q2_FNS: [&str; 3] =
    ["install_kv_scales", "kv_scales", "sync_kv_scales"];
const Q2_IDENTS: [&str; 2] = ["kscale", "vscale"];
/// Type constructors stepped over when resolving a param's type.
const TYPE_WRAPPERS: [&str; 5] = ["Arc", "Box", "Option", "Rc", "Vec"];
/// Identifier segments naming a unit family (rule U1); an identifier
/// spanning two families (`block_tokens`) is a conversion factor.
const UNIT_FAMILIES: [(&str, [&str; 2]); 4] = [
    ("blocks", ["block", "blocks"]),
    ("bytes", ["byte", "bytes"]),
    ("epoch", ["epoch", "epochs"]),
    ("tokens", ["token", "tokens"]),
];
/// Rule M1 sources of truth: (file under `rust/src`, enums pinned).
const M1_SOURCES: [(&str, &[&str]); 2] = [
    (
        "rollout/pool.rs",
        &["Ctl", "ToWorker", "Ordered", "Fence", "Event"],
    ),
    ("testkit/hb.rs", &["FenceState"]),
];
/// The model-side vocabulary file rule M1 cross-checks (repo-relative).
const M1_VOCAB: &str = "tools/model/src/vocab.rs";
const KEYWORDS: [&str; 31] = [
    "as", "box", "break", "const", "continue", "dyn", "else", "enum",
    "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod",
    "move", "mut", "pub", "ref", "return", "static", "struct", "trait",
    "type", "unsafe", "use", "where", "while", "yield",
];

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Id,
    Num,
    Fnum,
    Punct,
}

/// One lexical token; comments, strings, and chars are stripped.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

/// One rule hit at a source line, with its allow status resolved.
#[derive(Clone, Debug)]
pub struct Find {
    pub rule: &'static str,
    pub line: usize,
    pub what: String,
    pub allowed: bool,
}

/// (rule, module) -> (violations, allowed). BTreeMap so iteration
/// order matches the mirror's `sorted()` over string tuples.
pub type Counts = BTreeMap<(&'static str, String), (usize, usize)>;

/// One finding with its file, for `--verbose` reporting.
#[derive(Clone, Debug)]
pub struct Detail {
    pub rule: &'static str,
    pub rel: String,
    pub line: usize,
    pub what: String,
    pub allowed: bool,
}

fn txt(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map_or("", |t| t.text.as_str())
}

fn slice_str(b: &[u8], i: usize, j: usize) -> String {
    String::from_utf8_lossy(&b[i..j.min(b.len())]).into_owned()
}

/// Collect `// lint: allow(R)` markers on one physical line.
fn collect_allows(
    line: &str,
    ln: usize,
    allows: &mut BTreeSet<(usize, &'static str)>,
) {
    let b = line.as_bytes();
    let mut i = 0usize;
    while i + 1 < b.len() {
        if b[i] == b'/' && b[i + 1] == b'/' {
            let mut j = i + 2;
            while j < b.len() && b[j].is_ascii_whitespace() {
                j += 1;
            }
            if b[j..].starts_with(b"lint:") {
                j += 5;
                while j < b.len() && b[j].is_ascii_whitespace() {
                    j += 1;
                }
                if b[j..].starts_with(b"allow(") {
                    j += 6;
                    for rule in RULE_NAMES {
                        let nm = rule.as_bytes();
                        if b[j..].starts_with(nm)
                            && b.get(j + nm.len()) == Some(&b')')
                        {
                            allows.insert((ln, rule));
                        }
                    }
                }
            }
        }
        i += 1;
    }
}

/// Does a raw-string literal (`r"`, `r#"`, `br"`, ...) open at `i`?
/// Returns (index just past the opening quote, hash count).
fn raw_str_open(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b.get(j) == Some(&b'b') {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while b.get(j) == Some(&b'#') {
        j += 1;
        hashes += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some((j + 1, hashes))
    } else {
        None
    }
}

fn find_sub(b: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if from > b.len() {
        return None;
    }
    b[from..]
        .windows(needle.len().max(1))
        .position(|w| w == needle)
        .map(|p| from + p)
}

fn count_nl(b: &[u8], from: usize, to: usize) -> usize {
    b[from.min(b.len())..to.min(b.len())]
        .iter()
        .filter(|&&c| c == b'\n')
        .count()
}

/// Tokenize Rust source: returns (tokens, allow markers). Works on
/// bytes; non-ASCII appears only inside comments/strings, which are
/// stripped, so byte-wise classification matches the mirror.
pub fn tokenize(src: &str) -> (Vec<Tok>, BTreeSet<(usize, &'static str)>) {
    let mut allows = BTreeSet::new();
    for (ln0, line) in src.split('\n').enumerate() {
        collect_allows(line, ln0 + 1, &mut allows);
    }

    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let (mut i, mut line) = (0usize, 1usize);
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            continue;
        }
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i..].starts_with(b"/*") {
                    depth += 1;
                    i += 2;
                } else if b[i..].starts_with(b"*/") {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        if c == b'r' || c == b'b' {
            if let Some((open_end, hashes)) = raw_str_open(b, i) {
                let mut close = vec![b'"'];
                close.extend(std::iter::repeat(b'#').take(hashes));
                let j = find_sub(b, &close, open_end)
                    .map_or(n, |p| p + close.len());
                line += count_nl(b, i, j);
                i = j;
                continue;
            }
        }
        if c == b'"' || (c == b'b' && b.get(i + 1) == Some(&b'"')) {
            i += if c == b'b' { 2 } else { 1 };
            while i < n {
                if b[i] == b'\\' {
                    // count line continuations / escaped newlines
                    if b.get(i + 1) == Some(&b'\n') {
                        line += 1;
                    }
                    i += 2;
                } else if b[i] == b'"' {
                    i += 1;
                    break;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        if c == b'\'' || (c == b'b' && b.get(i + 1) == Some(&b'\'')) {
            let mut j = i + if c == b'b' { 2 } else { 1 };
            if b.get(j) == Some(&b'\\') {
                j += 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                i = j + 1;
                continue;
            }
            if j + 1 < n && b[j] != b'\'' && b[j + 1] == b'\'' {
                i = j + 2;
                continue;
            }
            // lifetime: consume the quote + identifier
            i += 1;
            while i < n && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            continue;
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i;
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Id,
                text: slice_str(b, i, j),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            let mut isf = false;
            if b[i..].starts_with(b"0x") || b[i..].starts_with(b"0b") {
                j = i + 2;
                while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_')
                {
                    j += 1;
                }
            } else {
                while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                    j += 1;
                }
                if j + 1 < n && b[j] == b'.' && b[j + 1].is_ascii_digit() {
                    isf = true;
                    j += 1;
                    while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                        j += 1;
                    }
                }
                let exp = j < n
                    && (b[j] == b'e' || b[j] == b'E')
                    && ((j + 1 < n && b[j + 1].is_ascii_digit())
                        || (j + 2 < n
                            && (b[j + 1] == b'+' || b[j + 1] == b'-')
                            && b[j + 2].is_ascii_digit()));
                if exp {
                    isf = true;
                    j += 1;
                    if b[j] == b'+' || b[j] == b'-' {
                        j += 1;
                    }
                    while j < n && b[j].is_ascii_digit() {
                        j += 1;
                    }
                }
                let mut sfx = j;
                while sfx < n
                    && (b[sfx].is_ascii_alphanumeric() || b[sfx] == b'_')
                {
                    sfx += 1;
                }
                if &b[j..sfx] == b"f32" || &b[j..sfx] == b"f64" {
                    isf = true;
                }
                j = sfx;
            }
            toks.push(Tok {
                kind: if isf { Kind::Fnum } else { Kind::Num },
                text: slice_str(b, i, j),
                line,
            });
            i = j;
            continue;
        }
        let two: &[u8] = &b[i..n.min(i + 2)];
        if two == b"::" || two == b"==" || two == b"!=" {
            toks.push(Tok {
                kind: Kind::Punct,
                text: slice_str(b, i, i + 2),
                line,
            });
            i += 2;
        } else {
            toks.push(Tok {
                kind: Kind::Punct,
                text: slice_str(b, i, i + 1),
                line,
            });
            i += 1;
        }
    }
    (toks, allows)
}

/// Line ranges covered by `#[cfg(test)]` items (attribute included).
pub fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    const PAT: [&str; 7] = ["#", "[", "cfg", "(", "test", ")", "]"];
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let is_cfg = PAT
            .iter()
            .enumerate()
            .all(|(k, &p)| txt(toks, i + k) == p);
        if !is_cfg {
            i += 1;
            continue;
        }
        let start_line = toks.get(i).map_or(1, |t| t.line);
        let mut j = i + 7;
        // skip further attributes on the same item
        while txt(toks, j) == "#" && txt(toks, j + 1) == "[" {
            let mut depth = 1usize;
            j += 2;
            while j < toks.len() && depth > 0 {
                match txt(toks, j) {
                    "[" => depth += 1,
                    "]" => depth -= 1,
                    _ => {}
                }
                j += 1;
            }
        }
        // find the item body's opening brace (or a terminating `;`)
        while j < toks.len() && !matches!(txt(toks, j), "{" | ";") {
            j += 1;
        }
        if txt(toks, j) == "{" {
            let mut depth = 1usize;
            j += 1;
            while j < toks.len() && depth > 0 {
                match txt(toks, j) {
                    "{" => depth += 1,
                    "}" => depth -= 1,
                    _ => {}
                }
                j += 1;
            }
        }
        let end_line = j
            .checked_sub(1)
            .and_then(|p| toks.get(p))
            .map_or(start_line, |t| t.line);
        out.push((start_line, end_line));
        i = j.max(i + 1);
    }
    out
}

/// Is the operand next to a comparison at `toks[i]` float-typed by
/// lexical evidence (float literal or an `INFINITY`/`NEG_INFINITY`/
/// `NAN` path)?
fn floaty(toks: &[Tok], i: usize, dir: isize) -> bool {
    let Some(mut j) = i.checked_add_signed(dir) else {
        return false;
    };
    if j >= toks.len() {
        return false;
    }
    if dir == 1 && txt(toks, j) == "-" {
        j += 1;
        if j >= toks.len() {
            return false;
        }
    }
    let Some(t) = toks.get(j) else {
        return false;
    };
    if t.kind == Kind::Fnum {
        return true;
    }
    let is_const = FLOAT_CONSTS.contains(&t.text.as_str());
    if t.kind == Kind::Id && is_const {
        return true;
    }
    // forward: `f32::INFINITY` — a path whose tail is a float const
    dir == 1
        && t.kind == Kind::Id
        && j + 2 < toks.len()
        && txt(toks, j + 1) == "::"
        && FLOAT_CONSTS.contains(&txt(toks, j + 2))
}

fn match_paren(toks: &[Tok], mut i: usize) -> usize {
    let mut depth = 0i64;
    while i < toks.len() {
        match txt(toks, i) {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
            _ => {}
        }
        i += 1;
    }
    toks.len()
}

/// Accounting-flavored identifier: any `_`-separated segment names a
/// resource quantity (rule A1).
fn is_acct(ident: &str) -> bool {
    ident.split('_').any(|s| ACCT_WORDS.contains(&s))
}

/// A compound `+=`/`-=`'s left-hand side: walk back from the operator
/// to the statement boundary and return the first accounting
/// identifier. Stops at `=`/`,` too, so `match` arms (`=>` lexes as
/// `=`,`>`) don't leak scrutinee identifiers into the LHS.
fn acct_lhs(toks: &[Tok], op: usize) -> Option<String> {
    let mut j = op;
    while j > 0 {
        j -= 1;
        let tok = toks.get(j)?;
        let t = tok.text.as_str();
        if matches!(t, ";" | "{" | "}" | "=" | ",") {
            return None;
        }
        if tok.kind == Kind::Id && !KEYWORDS.contains(&t) && is_acct(t) {
            return Some(t.to_string());
        }
    }
    None
}

/// Walk one operand chain LEFT from the operator at `op` (exclusive):
/// identifiers, `.`/`::` separators, and matched `()`/`[]` groups.
/// Returns the first accounting identifier found in the chain.
fn acct_left(toks: &[Tok], op: usize) -> Option<String> {
    let mut j = op;
    while j > 0 {
        j -= 1;
        let tok = toks.get(j)?;
        match tok.text.as_str() {
            close @ (")" | "]") => {
                let open = if close == ")" { "(" } else { "[" };
                let mut depth = 1usize;
                while j > 0 && depth > 0 {
                    j -= 1;
                    let u = txt(toks, j);
                    if u == close {
                        depth += 1;
                    } else if u == open {
                        depth -= 1;
                    }
                }
                if depth > 0 {
                    return None;
                }
            }
            "." | "::" => {}
            t => match tok.kind {
                Kind::Id if !KEYWORDS.contains(&t) => {
                    if is_acct(t) {
                        return Some(t.to_string());
                    }
                }
                Kind::Num | Kind::Fnum => {}
                _ => return None,
            },
        }
    }
    None
}

/// Walk one operand chain RIGHT from the operator at `op` (exclusive);
/// same chain grammar as `acct_left`.
fn acct_right(toks: &[Tok], op: usize) -> Option<String> {
    let mut j = op + 1;
    while j < toks.len() {
        let Some(tok) = toks.get(j) else { return None };
        match tok.text.as_str() {
            open @ ("(" | "[") => {
                let close = if open == "(" { ")" } else { "]" };
                let mut depth = 1usize;
                j += 1;
                while j < toks.len() && depth > 0 {
                    let u = txt(toks, j);
                    if u == open {
                        depth += 1;
                    } else if u == close {
                        depth -= 1;
                    }
                    j += 1;
                }
                if depth > 0 {
                    return None;
                }
            }
            "." | "::" => j += 1,
            t => match tok.kind {
                Kind::Id if !KEYWORDS.contains(&t) => {
                    if is_acct(t) {
                        return Some(t.to_string());
                    }
                    j += 1;
                }
                Kind::Num | Kind::Fnum => j += 1,
                _ => return None,
            },
        }
    }
    None
}

/// One `fn` item's token extent: `sig` is the index of the `fn`
/// keyword, `name` of the fn's name, `body_lo` of the body's opening
/// brace, `body_hi` one past its close.
#[derive(Clone, Copy, Debug)]
pub struct FnSpan {
    pub sig: usize,
    pub name: usize,
    pub body_lo: usize,
    pub body_hi: usize,
}

/// All fn bodies in token space (nested fns get their own spans —
/// the walk resumes just past each body's opening brace). Paren AND
/// bracket depth are tracked while looking for the body brace so
/// `-> [u8; 4]` return types don't read as bodyless trait decls.
pub fn fn_spans(toks: &[Tok]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let named = matches!(toks.get(i + 1), Some(t) if t.kind == Kind::Id);
        if txt(toks, i) != "fn" || !named {
            i += 1;
            continue;
        }
        let name = i + 1;
        let mut j = name + 1;
        let mut depth = 0i64;
        let mut open = None;
        while j < toks.len() {
            match txt(toks, j) {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "{" if depth == 0 => {
                    open = Some(j);
                    break;
                }
                ";" if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let Some(lo) = open else {
            i = j.max(i + 1);
            continue;
        };
        let mut d = 1i64;
        let mut k = lo + 1;
        while k < toks.len() && d > 0 {
            match txt(toks, k) {
                "{" => d += 1,
                "}" => d -= 1,
                _ => {}
            }
            k += 1;
        }
        out.push(FnSpan { sig: i, name, body_lo: lo, body_hi: k });
        i = lo + 1;
    }
    out
}

/// Index (into `spans`) of the innermost fn whose extent — signature
/// included, so params count — covers token `i`.
pub fn enclosing_fn(spans: &[FnSpan], i: usize) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (s, span) in spans.iter().enumerate() {
        if span.sig < i && i < span.body_hi {
            let better = match best.and_then(|b| spans.get(b)) {
                Some(prev) => prev.sig < span.sig,
                None => true,
            };
            if better {
                best = Some(s);
            }
        }
    }
    best
}

/// Fn-scoped dataflow (rule Q1): identifiers that lexically hold a
/// quantized payload — params typed with a Q1 type (behind `&`/`mut`/
/// wrapper generics), plus `let`/`for` bindings whose initializer
/// mentions a Q1 type, a quantizing ctor, or an already-marked name
/// (one forward pass; chains through re-bindings in source order).
fn quant_marks(toks: &[Tok], span: &FnSpan) -> BTreeSet<String> {
    let mut marks: BTreeSet<String> = BTreeSet::new();
    for i in span.sig..span.body_lo {
        let Some(tok) = toks.get(i) else { break };
        if tok.kind != Kind::Id || !Q1_TYPES.contains(&tok.text.as_str()) {
            continue;
        }
        let mut j = i;
        while j > span.sig {
            let p = txt(toks, j - 1);
            if matches!(p, "&" | "mut" | "<" | "(" | "[")
                || TYPE_WRAPPERS.contains(&p)
            {
                j -= 1;
            } else {
                break;
            }
        }
        if j >= 2 && txt(toks, j - 1) == ":" {
            if let Some(name) = toks.get(j - 2) {
                if name.kind == Kind::Id
                    && !KEYWORDS.contains(&name.text.as_str())
                {
                    marks.insert(name.text.clone());
                }
            }
        }
    }
    let mut i = span.body_lo;
    while i < span.body_hi {
        let kw = txt(toks, i);
        if kw != "let" && kw != "for" {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if kw == "let" && txt(toks, j) == "mut" {
            j += 1;
        }
        let name = match toks.get(j) {
            Some(t)
                if t.kind == Kind::Id
                    && !KEYWORDS.contains(&t.text.as_str()) =>
            {
                t.text.clone()
            }
            _ => {
                i = j;
                continue;
            }
        };
        let stop = if kw == "let" { ";" } else { "{" };
        let mut k = j + 1;
        let mut tainted = false;
        while k < span.body_hi && txt(toks, k) != stop {
            if let Some(u) = toks.get(k) {
                if u.kind == Kind::Id
                    && (Q1_TYPES.contains(&u.text.as_str())
                        || Q1_CTORS.contains(&u.text.as_str())
                        || marks.contains(&u.text))
                {
                    tainted = true;
                }
            }
            k += 1;
        }
        if tainted {
            marks.insert(name);
        }
        i = k;
    }
    marks
}

/// Is the receiver of the `.field` read at token `i` (the field
/// ident; `i-1` is the `.`) a marked binding, or a direct call of a
/// quantizing ctor / marked callable?
fn quant_receiver(
    toks: &[Tok],
    i: usize,
    marks: &BTreeSet<String>,
) -> bool {
    let Some(p) = i.checked_sub(2) else { return false };
    let Some(r) = toks.get(p) else { return false };
    match r.text.as_str() {
        close @ (")" | "]") => {
            let open = if close == ")" { "(" } else { "[" };
            let mut j = p;
            let mut depth = 1usize;
            while j > 0 && depth > 0 {
                j -= 1;
                let u = txt(toks, j);
                if u == close {
                    depth += 1;
                } else if u == open {
                    depth -= 1;
                }
            }
            if depth > 0 || j == 0 {
                return false;
            }
            match toks.get(j - 1) {
                Some(c) if c.kind == Kind::Id => {
                    Q1_CTORS.contains(&c.text.as_str())
                        || marks.contains(&c.text)
                }
                _ => false,
            }
        }
        _ => r.kind == Kind::Id && marks.contains(&r.text),
    }
}

/// Unit family of an identifier, by `_`-segment (rule U1): `None` if
/// no family word appears, the family if exactly one does, and the
/// `"*"` conversion sentinel — which exempts the whole operand chain
/// — when two families meet in one name (`block_tokens`,
/// `bytes_per_token`).
fn unit_class(ident: &str) -> Option<&'static str> {
    let mut found: Option<&'static str> = None;
    for seg in ident.split('_') {
        for (fam, words) in &UNIT_FAMILIES {
            if words.contains(&seg) {
                match found {
                    Some(f) if f != *fam => return Some("*"),
                    _ => found = Some(fam),
                }
            }
        }
    }
    found
}

/// A compound `+=`/`-=`'s left-hand unit family: walk back from the
/// operator to the statement boundary (same boundaries as `acct_lhs`)
/// and classify the first unit-flavored identifier. A conversion name
/// exempts the statement.
fn unit_lhs(toks: &[Tok], op: usize) -> Option<&'static str> {
    let mut j = op;
    while j > 0 {
        j -= 1;
        let tok = toks.get(j)?;
        let t = tok.text.as_str();
        if matches!(t, ";" | "{" | "}" | "=" | ",") {
            return None;
        }
        if tok.kind == Kind::Id && !KEYWORDS.contains(&t) {
            match unit_class(t) {
                Some("*") => return None,
                Some(f) => return Some(f),
                None => {}
            }
        }
    }
    None
}

/// Walk one operand chain LEFT from the operator at `op` (exclusive;
/// same chain grammar as `acct_left`) and return its unit family.
fn unit_left(toks: &[Tok], op: usize) -> Option<&'static str> {
    let mut j = op;
    while j > 0 {
        j -= 1;
        let tok = toks.get(j)?;
        match tok.text.as_str() {
            close @ (")" | "]") => {
                let open = if close == ")" { "(" } else { "[" };
                let mut depth = 1usize;
                while j > 0 && depth > 0 {
                    j -= 1;
                    let u = txt(toks, j);
                    if u == close {
                        depth += 1;
                    } else if u == open {
                        depth -= 1;
                    }
                }
                if depth > 0 {
                    return None;
                }
            }
            "." | "::" => {}
            t => match tok.kind {
                Kind::Id if !KEYWORDS.contains(&t) => match unit_class(t) {
                    Some("*") => return None,
                    Some(f) => return Some(f),
                    None => {}
                },
                Kind::Num | Kind::Fnum => {}
                _ => return None,
            },
        }
    }
    None
}

/// Walk one operand chain RIGHT from the operator at `op` (exclusive;
/// same chain grammar as `acct_right`) and return its unit family.
fn unit_right(toks: &[Tok], op: usize) -> Option<&'static str> {
    let mut j = op + 1;
    while j < toks.len() {
        let Some(tok) = toks.get(j) else { return None };
        match tok.text.as_str() {
            open @ ("(" | "[") => {
                let close = if open == "(" { ")" } else { "]" };
                let mut depth = 1usize;
                j += 1;
                while j < toks.len() && depth > 0 {
                    let u = txt(toks, j);
                    if u == open {
                        depth += 1;
                    } else if u == close {
                        depth -= 1;
                    }
                    j += 1;
                }
                if depth > 0 {
                    return None;
                }
            }
            "." | "::" => j += 1,
            t => match tok.kind {
                Kind::Id if !KEYWORDS.contains(&t) => {
                    match unit_class(t) {
                        Some("*") => return None,
                        Some(f) => return Some(f),
                        None => {}
                    }
                    j += 1;
                }
                Kind::Num | Kind::Fnum => j += 1,
                _ => return None,
            },
        }
    }
    None
}

/// Scan one file. `relpath` is relative to `rust/src` with `/`
/// separators; the module is its first path component (or "root").
pub fn scan_file(relpath: &str, src: &str) -> (String, Vec<Find>) {
    let module = match relpath.split_once('/') {
        Some((m, _)) => m.to_string(),
        None => "root".to_string(),
    };
    let file = relpath.rsplit('/').next().unwrap_or(relpath);
    let stem = file.strip_suffix(".rs").unwrap_or(file);
    let (toks, allows) = tokenize(src);
    let excluded = test_regions(&toks);
    let in_test = |line: usize| {
        excluded.iter().any(|&(a, b)| (a..=b).contains(&line))
    };

    let mut finds: Vec<Find> = Vec::new();
    let det = DET_MODULES.contains(&module.as_str());
    let acct = A1_FILES.contains(&stem) || module == "rl";
    let q1 = module != "fp8";
    let q2 = Q2_MODULES.contains(&module.as_str());
    let uni = U1_MODULES.contains(&module.as_str());
    let spans = fn_spans(&toks);
    let marks: Vec<BTreeSet<String>> =
        spans.iter().map(|s| quant_marks(&toks, s)).collect();
    for i in 0..toks.len() {
        let Some(tok) = toks.get(i) else { break };
        let (k, t, line) = (tok.kind, tok.text.as_str(), tok.line);
        if in_test(line) {
            continue;
        }
        let (prev_kind, prev) = match i.checked_sub(1) {
            Some(p) => toks
                .get(p)
                .map_or((Kind::Punct, ""), |x| (x.kind, x.text.as_str())),
            None => (Kind::Punct, ""),
        };
        let nxt = txt(&toks, i + 1);
        let mut hit = |rule: &'static str, what: String| {
            let allowed = allows.contains(&(line, rule))
                || (line > 0 && allows.contains(&(line - 1, rule)));
            finds.push(Find { rule, line, what, allowed });
        };
        if det && k == Kind::Id && D1_IDENTS.contains(&t) {
            hit("D1", t.to_string());
        }
        if k == Kind::Id && t == "partial_cmp" {
            hit("D2", "partial_cmp".to_string());
        }
        if k == Kind::Punct
            && (t == "==" || t == "!=")
            && (floaty(&toks, i, -1) || floaty(&toks, i, 1))
        {
            hit("D2", format!("float {t}"));
        }
        if k == Kind::Id
            && (t == "unwrap" || t == "expect")
            && prev == "."
            && nxt == "("
        {
            hit("P1", format!(".{t}()"));
        }
        if k == Kind::Id && PANIC_MACROS.contains(&t) && nxt == "!" {
            hit("P1", format!("{t}!"));
        }
        if k == Kind::Punct && t == "[" {
            let after_ident =
                prev_kind == Kind::Id && !KEYWORDS.contains(&prev);
            if after_ident || matches!(prev, ")" | "]" | "?") {
                hit("P1", "indexing".to_string());
            }
        }
        if k == Kind::Id
            && C1_METHODS.contains(&t)
            && prev == "."
            && nxt == "("
        {
            let j = match_paren(&toks, i + 1);
            if txt(&toks, j + 1) == "."
                && txt(&toks, j + 2) == "ok"
                && txt(&toks, j + 3) == "("
            {
                hit("C1", format!(".{t}(..).ok()"));
            } else {
                let mut s = i;
                while s > 0 && !matches!(txt(&toks, s - 1), ";" | "{" | "}")
                {
                    s -= 1;
                }
                if txt(&toks, s) == "let"
                    && txt(&toks, s + 1) == "_"
                    && txt(&toks, s + 2) == "="
                {
                    hit("C1", format!("let _ = {t}"));
                }
            }
        }
        if acct && k == Kind::Punct && (t == "+" || t == "-") && nxt == "=" {
            if let Some(id) = acct_lhs(&toks, i) {
                hit("A1", format!("unchecked {t}= on {id}"));
            }
        }
        if acct && k == Kind::Punct && t == "-" && nxt != "=" && nxt != ">" {
            let binary = prev_kind == Kind::Num
                || prev_kind == Kind::Fnum
                || matches!(prev, ")" | "]")
                || (prev_kind == Kind::Id && !KEYWORDS.contains(&prev));
            if binary {
                if let Some(id) =
                    acct_left(&toks, i).or_else(|| acct_right(&toks, i))
                {
                    hit("A1", format!("unchecked - on {id}"));
                }
            }
        }
        if k == Kind::Id
            && (t == "send" || t == "try_send")
            && prev == "."
            && nxt == "("
            && txt(&toks, i + 2) == "ToWorker"
            && txt(&toks, i + 3) == "::"
        {
            hit("C2", format!(".{t}(ToWorker::..)"));
        }
        if q1 && k == Kind::Id && Q1_TYPES.contains(&t) {
            let lit = nxt == "{"
                && !matches!(
                    prev,
                    ">" | "impl" | "struct" | "enum" | "dyn" | "for"
                );
            let newc = nxt == "::" && txt(&toks, i + 2) == "new";
            if lit || newc {
                hit("Q1", format!("construct {t}"));
            }
        }
        if q1
            && k == Kind::Id
            && Q1_FIELDS.contains(&t)
            && prev == "."
            && nxt != "("
        {
            let marked = enclosing_fn(&spans, i)
                .and_then(|s| marks.get(s))
                .is_some_and(|m| quant_receiver(&toks, i, m));
            if marked {
                hit("Q1", format!(".{t} read"));
            }
        }
        if q2 && k == Kind::Id && (Q2_IDENTS.contains(&t) || t == "ScaleSet")
        {
            let fenced = enclosing_fn(&spans, i)
                .and_then(|s| spans.get(s))
                .is_some_and(|s| Q2_FNS.contains(&txt(&toks, s.name)));
            if !fenced {
                if Q2_IDENTS.contains(&t) {
                    hit("Q2", format!("raw {t}"));
                } else {
                    let lit = nxt == "{"
                        && !matches!(
                            prev,
                            ">" | "impl" | "struct" | "enum" | "dyn" | "for"
                        );
                    let newc = nxt == "::" && txt(&toks, i + 2) == "new";
                    if lit || newc {
                        hit(
                            "Q2",
                            "ScaleSet built outside install path"
                                .to_string(),
                        );
                    }
                }
            }
        }
        if uni && k == Kind::Punct && (t == "+" || t == "-") && nxt == "=" {
            if let (Some(l), Some(r)) =
                (unit_lhs(&toks, i), unit_right(&toks, i + 1))
            {
                if l != r {
                    hit("U1", format!("{l} {t}= {r}"));
                }
            }
        }
        if uni
            && k == Kind::Punct
            && (t == "+" || t == "-")
            && nxt != "="
            && nxt != ">"
        {
            let binary = prev_kind == Kind::Num
                || prev_kind == Kind::Fnum
                || matches!(prev, ")" | "]")
                || (prev_kind == Kind::Id && !KEYWORDS.contains(&prev));
            if binary {
                if let (Some(l), Some(r)) =
                    (unit_left(&toks, i), unit_right(&toks, i))
                {
                    if l != r {
                        hit("U1", format!("{l} {t} {r}"));
                    }
                }
            }
        }
    }
    (module, finds)
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries = fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    let mut subdirs = Vec::new();
    for e in &entries {
        let p = e.path();
        if p.is_dir() {
            subdirs.push(p);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    for d in subdirs {
        rs_files(&d, out)?;
    }
    Ok(())
}

/// Extract the variants of `enum <name>` from Rust source, line-based:
/// the header is a trimmed line `enum <name>` (optionally behind
/// `pub`/`pub(crate)`); a variant is a leading uppercase identifier on
/// a depth-1 line of the body. Comment-only and attribute lines are
/// skipped. Returns `(variant, 1-based line)` in source order, or
/// `None` when the enum is not found.
fn enum_variants(src: &str, name: &str) -> Option<Vec<(String, usize)>> {
    let lines: Vec<&str> = src.split('\n').collect();
    let mut header = None;
    for (idx, raw) in lines.iter().enumerate() {
        let mut t = raw.trim();
        for p in ["pub(crate) ", "pub "] {
            if let Some(rest) = t.strip_prefix(p) {
                t = rest;
            }
        }
        if let Some(rest) = t.strip_prefix("enum ") {
            if let Some(after) = rest.strip_prefix(name) {
                let c = after.chars().next();
                if matches!(c, None | Some(' ') | Some('{') | Some('<')) {
                    header = Some(idx);
                    break;
                }
            }
        }
    }
    let header = header?;
    let mut vars = Vec::new();
    let mut depth = 0i64;
    let mut open = false;
    for (idx, raw) in lines.iter().enumerate().skip(header) {
        let t = raw.trim();
        if t.starts_with("//") {
            continue;
        }
        if open
            && depth == 1
            && !t.starts_with("#[")
            && t.chars().next().is_some_and(|c| c.is_ascii_uppercase())
        {
            let v: String = t
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            vars.push((v, idx + 1));
        }
        for c in raw.chars() {
            if c == '{' {
                depth += 1;
                open = true;
            } else if c == '}' {
                depth -= 1;
            }
        }
        if open && depth <= 0 {
            break;
        }
    }
    Some(vars)
}

/// Extract `("Enum", "Variant")` pairs from the vocabulary file: a
/// pair is the first two quoted identifiers on a trimmed line starting
/// with `("` — the lexical contract vocab.rs documents.
fn vocab_pairs(src: &str) -> Vec<(String, String, usize)> {
    let mut out = Vec::new();
    for (idx, raw) in src.split('\n').enumerate() {
        let t = raw.trim();
        if !t.starts_with("(\"") {
            continue;
        }
        let mut parts: Vec<String> = Vec::new();
        let mut rest = t;
        while parts.len() < 2 {
            let Some(start) = rest.find('"') else { break };
            let after = &rest[start + 1..];
            let Some(end) = after.find('"') else { break };
            parts.push(after[..end].to_string());
            rest = &after[end + 1..];
        }
        if let [e, v] = parts.as_slice() {
            out.push((e.clone(), v.clone(), idx + 1));
        }
    }
    out
}

/// Module bucket for an M1 finding (vocab findings land in "model").
fn m1_module(rel: &str) -> String {
    if rel.starts_with("tools/") {
        return "model".to_string();
    }
    match rel.split_once('/') {
        Some((m, _)) => m.to_string(),
        None => "root".to_string(),
    }
}

/// Rule M1 — model drift. Cross-checks the `tools/model` protocol
/// vocabulary against the implementation enums in both directions;
/// findings carry no allow escape. Ordering is fixed: per-source
/// missing variants (M1_SOURCES order, variants in line order), then
/// stale vocabulary pairs in vocab.rs line order.
pub fn scan_model_vocab(root: &Path) -> Vec<Detail> {
    let mut details = Vec::new();
    let mut vpath = root.to_path_buf();
    for seg in M1_VOCAB.split('/') {
        vpath = vpath.join(seg);
    }
    let mut vocab: Vec<(String, String, usize)> = Vec::new();
    let mut have_vocab = false;
    match fs::read_to_string(&vpath) {
        Ok(src) => {
            have_vocab = true;
            vocab = vocab_pairs(&src);
        }
        Err(_) => details.push(Detail {
            rule: "M1",
            rel: M1_VOCAB.to_string(),
            line: 1,
            what: "vocabulary file unreadable — the model's protocol \
                   vocabulary cannot be cross-checked"
                .to_string(),
            allowed: false,
        }),
    }
    let mut used = vec![false; vocab.len()];
    for (file, enums) in M1_SOURCES {
        let mut path = root.join("rust").join("src");
        for seg in file.split('/') {
            path = path.join(seg);
        }
        let src = match fs::read_to_string(&path) {
            Ok(s) => s,
            Err(_) => {
                details.push(Detail {
                    rule: "M1",
                    rel: file.to_string(),
                    line: 1,
                    what: format!(
                        "{file} unreadable — M1 source of truth missing"
                    ),
                    allowed: false,
                });
                continue;
            }
        };
        for name in enums {
            let Some(vars) = enum_variants(&src, name) else {
                details.push(Detail {
                    rule: "M1",
                    rel: file.to_string(),
                    line: 1,
                    what: format!("enum {name} not found in {file}"),
                    allowed: false,
                });
                continue;
            };
            for (variant, line) in vars {
                let mut hit = false;
                for (vi, (e, v, _)) in vocab.iter().enumerate() {
                    if e == name && *v == variant {
                        used[vi] = true;
                        hit = true;
                    }
                }
                if have_vocab && !hit {
                    details.push(Detail {
                        rule: "M1",
                        rel: file.to_string(),
                        line,
                        what: format!(
                            "{name}::{variant} missing from the \
                             tools/model vocabulary — update vocab.rs \
                             and the model"
                        ),
                        allowed: false,
                    });
                }
            }
        }
    }
    for (vi, (e, v, line)) in vocab.iter().enumerate() {
        if !used[vi] {
            details.push(Detail {
                rule: "M1",
                rel: M1_VOCAB.to_string(),
                line: *line,
                what: format!(
                    "stale vocabulary pair {e}::{v} — no such variant \
                     in the implementation"
                ),
                allowed: false,
            });
        }
    }
    details
}

/// Scan every `.rs` file under `<root>/rust/src`.
pub fn scan_tree(root: &Path) -> io::Result<(usize, Counts, Vec<Detail>)> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    rs_files(&src_root, &mut files)?;
    let mut counts = Counts::new();
    let mut details = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(path)?;
        let (module, finds) = scan_file(&rel, &src);
        for f in finds {
            let e = counts.entry((f.rule, module.clone())).or_insert((0, 0));
            if f.allowed {
                e.1 += 1;
            } else {
                e.0 += 1;
            }
            details.push(Detail {
                rule: f.rule,
                rel: rel.clone(),
                line: f.line,
                what: f.what,
                allowed: f.allowed,
            });
        }
    }
    // rule M1 runs over the whole repo, not the rust/src walk
    for d in scan_model_vocab(root) {
        let e = counts
            .entry((d.rule, m1_module(&d.rel)))
            .or_insert((0, 0));
        e.0 += 1;
        details.push(d);
    }
    Ok((files.len(), counts, details))
}

/// Render the committed baseline format: one `<rule> <module> <count>`
/// line per nonzero violation count, sorted, plus a header.
pub fn render_baseline(counts: &Counts) -> String {
    let mut out =
        String::from("# pallas-lint baseline: <rule> <module> <count>\n");
    for ((rule, module), (v, _a)) in counts {
        if *v > 0 {
            out.push_str(&format!("{rule} {module} {v}\n"));
        }
    }
    out
}

/// Parse a baseline file back to (rule, module) -> count. Unparseable
/// lines are ignored (a missing entry ratchets to zero, the strict
/// direction).
pub fn parse_baseline(text: &str) -> BTreeMap<(String, String), usize> {
    let mut base = BTreeMap::new();
    for ln in text.split('\n') {
        let ln = ln.trim();
        if ln.is_empty() || ln.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = ln.split_whitespace().collect();
        if let [rule, module, count] = parts.as_slice() {
            if let Ok(v) = count.parse::<usize>() {
                base.insert((rule.to_string(), module.to_string()), v);
            }
        }
    }
    base
}

/// Full CLI run: scan, report, then either write the baseline or
/// enforce floors + ratchet. Returns Ok(true) when the tree passes.
pub fn run(root: &Path, write: bool, verbose: bool) -> io::Result<bool> {
    let (nfiles, counts, details) = scan_tree(root)?;
    println!("pallas-lint: scanned {nfiles} files");
    for ((rule, module), (v, a)) in &counts {
        println!("  {rule} {module:<12} violations={v} allowed={a}");
    }
    if verbose {
        for d in &details {
            let tag = if d.allowed { " (allowed)" } else { "" };
            println!("    {} {}:{} {}{}", d.rule, d.rel, d.line, d.what, tag);
        }
    }
    let bpath = root.join("lint-baseline.txt");
    if write {
        fs::write(&bpath, render_baseline(&counts))?;
        println!("wrote {}", bpath.display());
        return Ok(true);
    }
    let mut ok = true;
    // hard floors, baseline-proof
    for ((rule, module), (v, _a)) in &counts {
        if *v == 0 {
            continue;
        }
        if matches!(
            *rule,
            "D1" | "D2" | "C1" | "A1" | "C2" | "Q1" | "Q2" | "U1" | "M1"
        ) {
            println!("FLOOR: {rule} must be 0 everywhere, {module} has {v}");
            ok = false;
        }
        if *rule == "P1" && CORE_MODULES.contains(&module.as_str()) {
            println!("FLOOR: P1 must be 0 in {module}, found {v}");
            ok = false;
        }
    }
    if bpath.exists() {
        let base = parse_baseline(&fs::read_to_string(&bpath)?);
        for ((rule, module), (v, _a)) in &counts {
            let key = (rule.to_string(), module.clone());
            let b = base.get(&key).copied().unwrap_or(0);
            if *v > b {
                println!("RATCHET: {rule} {module} rose {b} -> {v}");
                ok = false;
            }
        }
    }
    println!("{}", if ok { "OK" } else { "FAIL" });
    Ok(ok)
}
