pub fn index(v: &[u32]) -> u32 {
    v[0]
}

pub fn unwrapped(o: Option<u32>) -> u32 {
    o.unwrap()
}

pub fn expected(o: Option<u32>) -> u32 {
    o.expect("present")
}

pub fn boom() {
    panic!("no");
}

pub fn safe(v: &[u32]) -> u32 {
    v.first().copied().unwrap_or(0)
}

pub fn allowed(v: &[u32]) -> u32 {
    v[1] // lint: allow(P1): length checked by the caller
}

#[cfg(test)]
mod tests {
    #[test]
    fn indexing_in_tests_is_fine() {
        let v = [1, 2, 3];
        assert_eq!(v[0], 1);
        let o: Option<u32> = Some(4);
        assert_eq!(o.unwrap(), 4);
    }
}
