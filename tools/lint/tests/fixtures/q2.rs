//! Q2 fixture: KV-scale freshness — raw plumbing vs the fenced path.

pub struct Raw {
    kscale: f32, // flagged: raw scale field outside the install path
    pub epoch: u64,
}

pub struct Holder {
    scales: ScaleSet,
}

fn plumb(engine: &mut Engine, k: f32) {
    let fresh = ScaleSet::new(k, k, engine.epoch()); // flagged
    engine.kscale = k; // flagged: raw scale write
    engine.set(fresh.kscale()); // flagged: raw ident even as a call
}

fn install_kv_scales(engine: &mut Engine, kscale: f32, vscale: f32) {
    engine.scales = ScaleSet::new(kscale, vscale, engine.next_epoch());
}

fn kv_scales(engine: &Engine) -> (f32, f32) {
    engine.scales.read(engine.epoch())
}

fn audited(engine: &Engine) -> f32 {
    // lint: allow(Q2): calibration probe reads the raw scale
    engine.vscale
}

fn identity_is_fine() -> ScaleSet {
    ScaleSet::identity()
}
