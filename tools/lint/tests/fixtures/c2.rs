//! C2 fixture: raw `ToWorker` sends vs the audited wrapper — the
//! lint-level half of the chaos-worker proof (a worker-bound control
//! message smuggled around `WorkerLink` bypasses fence FIFO ordering).

pub fn raw_bypass(tx: &Sender<ToWorker>, m: Ordered) -> Result<(), ()> {
    tx.send(ToWorker::Ordered(m)).map_err(|_| ())
}

pub fn raw_try(tx: &Sender<ToWorker>, c: Ctl) -> bool {
    tx.try_send(ToWorker::Ctl(c)).is_ok()
}

pub fn audited(tx: &Sender<ToWorker>, c: Ctl) {
    // lint: allow(C2): fixture stand-in for WorkerLink's audited send
    if tx.send(ToWorker::Ctl(c)).is_err() {
        drop(tx);
    }
}

pub fn unrelated(tx: &Sender<u64>) {
    if tx.send(7).is_err() {
        drop(tx);
    }
}

pub fn discarded_wrapper(w: &WorkerLink, c: Ctl) {
    let _ = w.send_ctl(c);
}
