use std::collections::HashMap;

pub fn build() -> HashMap<u32, u32> {
    HashMap::new()
}

pub fn allowed() -> usize {
    // lint: allow(D1): seeded map used only for a size estimate
    HashMap::<u32, u32>::new().len()
}
