//! M1 fixture: a drifted copy of the pool protocol enums. `Fence`
//! grew a `Drain` variant the model vocabulary does not know about.

enum Ctl {
    Abort(u64),
    Discard(u64),
    Stats,
    Shutdown,
}

enum ToWorker {
    Ordered(Ordered),
    Ctl(Ctl),
}

enum Ordered {
    Submit(u64, u64),
    Fence(Fence),
}

enum Fence {
    Weights(u64),
    KvScales(f32, f32, u64),
    Drain,
}

enum Event {
    Done(usize, u64),
    Aborted(usize, u64),
    Failed(usize, u64, String),
    Fence(usize, u64),
}
