//! M1 fixture: the fence state machine, faithful to the vocabulary.

pub enum FenceState {
    Running,
    Draining { target: u64 },
    Installed { epoch: u64 },
}
