//! M1 fixture vocabulary: complete except for `Fence::Drain` (missing
//! here, present in the fixture pool.rs) and with one stale pair
//! (`Ctl::Retired` names no real variant).

pub const PROTOCOL_VOCAB: &[(&str, &str)] = &[
    ("Ctl", "Abort"),
    ("Ctl", "Discard"),
    ("Ctl", "Stats"),
    ("Ctl", "Shutdown"),
    ("Ctl", "Retired"),
    ("ToWorker", "Ordered"),
    ("ToWorker", "Ctl"),
    ("Ordered", "Submit"),
    ("Ordered", "Fence"),
    ("Fence", "Weights"),
    ("Fence", "KvScales"),
    ("Event", "Done"),
    ("Event", "Aborted"),
    ("Event", "Failed"),
    ("Event", "Fence"),
    ("FenceState", "Running"),
    ("FenceState", "Draining"),
    ("FenceState", "Installed"),
];
