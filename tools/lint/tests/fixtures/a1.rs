//! A1 fixture: accounting arithmetic. Scanned as `rollout/pool.rs`
//! (in scope), `rl/batch.rs` (in scope via module), and
//! `rollout/request.rs` (out of scope — no findings).

pub struct Acct {
    tokens: u64,
    blocks: usize,
    budget: i64,
}

pub fn total(a: &Acct) -> usize {
    a.blocks
}

pub fn churn(a: &mut Acct, n: u64) {
    a.tokens += n;
    a.tokens -= n;
}

pub fn deltas(a: &Acct) -> usize {
    let spare = a.blocks - 1;
    let used = total(a) - a.blocks;
    spare + used
}

pub fn safe(a: &mut Acct, n: u64) {
    a.tokens = a.tokens.saturating_add(n);
    let _hole = a.blocks.saturating_sub(1);
    let refund: i64 = -1;
    a.budget = a.budget.saturating_add(refund);
}

pub fn audited(a: &mut Acct) {
    // lint: allow(A1): fixture-audited exact subtraction
    a.budget -= 1;
}

pub fn arms(a: &Acct, mut n: u64) -> u64 {
    // the scrutinee's accounting ident must not leak into the arm's LHS
    match a.tokens {
        0 => n += 1,
        _ => {}
    }
    n
}

pub fn plain_counter(c: &mut u64, n: u64) {
    *c += n;
}
