//! U1 fixture: unit-family mixing without an explicit conversion.

fn mixed(n_tokens: usize, free_blocks: usize) -> usize {
    n_tokens + free_blocks // flagged: tokens + blocks
}

fn drift(budget_bytes: usize, used_blocks: usize) -> usize {
    budget_bytes - used_blocks // flagged: bytes - blocks
}

fn creep(seq_tokens: &mut usize, epoch: u64) {
    *seq_tokens += epoch as usize; // flagged: tokens += epoch
}

fn audited(prompt_tokens: usize, kv_blocks: usize) -> usize {
    // lint: allow(U1): fixture-audited intentional mix
    prompt_tokens + kv_blocks
}

fn converted(seq_tokens: usize, geo: &Geometry) -> usize {
    seq_tokens + geo.block_tokens // conversion factor exempts the chain
}

fn same_family(free_blocks: usize, used_blocks: usize) -> usize {
    free_blocks + used_blocks
}

fn literal(seq_tokens: usize) -> usize {
    seq_tokens + 1
}
