//! Q1 fixture: quantized-payload provenance outside `fp8/`.

use crate::fp8::{quantize_blockwise, QuantizedTensor};

fn peek(q: &QuantizedTensor) -> usize {
    let raw = q.codes; // flagged: field read through a typed param
    raw.len()
}

fn copy_scales(t: &Tensor) -> Vec<f32> {
    let copied = quantize_blockwise(t);
    copied.scales // flagged: binding tainted by the ctor
}

fn chained(t: &Tensor) -> usize {
    quantize_blockwise(t).codes.len() // flagged: ctor-call receiver
}

fn forge(rows: usize) -> QuantizedTensor {
    QuantizedTensor { rows } // flagged: construction outside fp8
}

fn audited(q: &QuantizedTensor) -> usize {
    // lint: allow(Q1): parity harness compares raw codes
    q.codes.len()
}

fn sanctioned(d: &QuantizedTensor, cfg: &Config) -> Vec<f32> {
    let out = d.scales(); // accessor call, not a field read
    let n = cfg.codes; // unmarked receiver: not a quantized payload
    let _ = n;
    out
}
