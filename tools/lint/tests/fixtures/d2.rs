pub fn cmp(a: f32, b: f32) -> bool {
    a.partial_cmp(&b).is_some()
}

pub fn nan_eq(x: f32) -> bool {
    x == f32::NAN
}

pub fn lit(x: f64) -> bool {
    x != 0.5
}

pub fn int_ok(x: i64) -> bool {
    x == 5
}

pub fn allowed(x: f32) -> bool {
    // lint: allow(D2): exact sentinel comparison
    x == f32::INFINITY
}
