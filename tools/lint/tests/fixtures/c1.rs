use std::sync::mpsc::{Sender, SyncSender};

pub fn drop_ack(tx: &Sender<u32>) {
    let _ = tx.send(1);
}

pub fn swallow(tx: &Sender<u32>) {
    tx.send(2).ok();
}

pub fn swallow_try(tx: &SyncSender<u32>) {
    tx.try_send(3).ok();
}

pub fn propagated(tx: &Sender<u32>) -> Result<(), String> {
    tx.send(4).map_err(|e| e.to_string())
}

pub fn allowed(tx: &Sender<u32>) {
    // lint: allow(C1): teardown path, receiver may be gone
    let _ = tx.send(5);
}
