//! pallas-lint test suite: per-rule fixtures (positive / negative /
//! allow), tokenizer line accounting, `#[cfg(test)]` exclusion, the
//! baseline round-trip, and the committed-baseline cross-check that
//! keeps `lint-baseline.txt` honest.

use std::path::Path;

use pallas_lint::*;

/// (violations, allowed) for one rule over a scan_file result.
fn tally(finds: &[Find], rule: &str) -> (usize, usize) {
    let mut v = (0usize, 0usize);
    for f in finds.iter().filter(|f| f.rule == rule) {
        if f.allowed {
            v.1 += 1;
        } else {
            v.0 += 1;
        }
    }
    v
}

#[test]
fn d1_flags_nondeterministic_idents_in_det_modules() {
    let src = include_str!("fixtures/d1.rs");
    let (module, finds) = scan_file("rollout/d1.rs", src);
    assert_eq!(module, "rollout");
    assert_eq!(tally(&finds, "D1"), (3, 1));
    // the allowed site is the one under the marker, not the `use`
    let allowed: Vec<usize> = finds
        .iter()
        .filter(|f| f.rule == "D1" && f.allowed)
        .map(|f| f.line)
        .collect();
    assert_eq!(allowed, vec![9]);
}

#[test]
fn d1_is_scoped_to_det_modules() {
    let src = include_str!("fixtures/d1.rs");
    let (module, finds) = scan_file("util/d1.rs", src);
    assert_eq!(module, "util");
    assert_eq!(tally(&finds, "D1"), (0, 0));
}

#[test]
fn d2_flags_partial_cmp_and_float_eq() {
    let src = include_str!("fixtures/d2.rs");
    let (_m, finds) = scan_file("rl/d2.rs", src);
    assert_eq!(tally(&finds, "D2"), (3, 1));
    let whats: Vec<&str> = finds
        .iter()
        .filter(|f| !f.allowed)
        .map(|f| f.what.as_str())
        .collect();
    assert_eq!(whats, vec!["partial_cmp", "float ==", "float !="]);
}

#[test]
fn d2_ignores_integer_comparisons() {
    let src = "fn f(x: i64) -> bool { x == 5 }\n";
    let (_m, finds) = scan_file("rl/x.rs", src);
    assert_eq!(tally(&finds, "D2"), (0, 0));
}

#[test]
fn p1_flags_panics_and_indexing_outside_tests() {
    let src = include_str!("fixtures/p1.rs");
    let (_m, finds) = scan_file("rl/p1.rs", src);
    assert_eq!(tally(&finds, "P1"), (4, 1));
    // the #[cfg(test)] mod at the bottom contributes nothing
    assert!(finds.iter().all(|f| f.line < 25));
}

#[test]
fn p1_same_line_allow_marker_applies() {
    let src = include_str!("fixtures/p1.rs");
    let (_m, finds) = scan_file("rl/p1.rs", src);
    let allowed: Vec<(usize, &str)> = finds
        .iter()
        .filter(|f| f.allowed)
        .map(|f| (f.line, f.what.as_str()))
        .collect();
    assert_eq!(allowed, vec![(22, "indexing")]);
}

#[test]
fn c1_flags_discarded_sends_only() {
    let src = include_str!("fixtures/c1.rs");
    let (_m, finds) = scan_file("sync/c1.rs", src);
    assert_eq!(tally(&finds, "C1"), (3, 1));
    let whats: Vec<&str> = finds
        .iter()
        .filter(|f| !f.allowed)
        .map(|f| f.what.as_str())
        .collect();
    assert_eq!(
        whats,
        vec!["let _ = send", ".send(..).ok()", ".try_send(..).ok()"]
    );
}

#[test]
fn a1_flags_unchecked_accounting_arithmetic_in_scope() {
    let src = include_str!("fixtures/a1.rs");
    let (_m, finds) = scan_file("rollout/pool.rs", src);
    assert_eq!(tally(&finds, "A1"), (4, 1));
    let whats: Vec<&str> = finds
        .iter()
        .filter(|f| f.rule == "A1" && !f.allowed)
        .map(|f| f.what.as_str())
        .collect();
    assert_eq!(
        whats,
        vec![
            "unchecked += on tokens",
            "unchecked -= on tokens",
            "unchecked - on blocks",
            "unchecked - on blocks",
        ]
    );
}

#[test]
fn a1_is_scoped_to_accounting_files_and_the_rl_module() {
    let src = include_str!("fixtures/a1.rs");
    // same source, non-accounting file stem: silent
    let (_m, finds) = scan_file("rollout/request.rs", src);
    assert_eq!(tally(&finds, "A1"), (0, 0));
    // the rl module is in scope as a whole, any stem
    let (_m, finds) = scan_file("rl/batch.rs", src);
    assert_eq!(tally(&finds, "A1"), (4, 1));
}

#[test]
fn c2_flags_raw_toworker_sends_and_c1_covers_the_wrappers() {
    let src = include_str!("fixtures/c2.rs");
    let (_m, finds) = scan_file("rollout/chaos.rs", src);
    assert_eq!(tally(&finds, "C2"), (2, 1));
    // a discarded `send_ctl` is still a discarded send (C1)
    assert_eq!(tally(&finds, "C1"), (1, 0));
    let whats: Vec<&str> = finds
        .iter()
        .filter(|f| f.rule == "C2" && !f.allowed)
        .map(|f| f.what.as_str())
        .collect();
    assert_eq!(
        whats,
        vec![".send(ToWorker::..)", ".try_send(ToWorker::..)"]
    );
}

#[test]
fn q1_flags_payload_reads_and_construction_outside_fp8() {
    let src = include_str!("fixtures/q1.rs");
    let (_m, finds) = scan_file("rollout/q1.rs", src);
    assert_eq!(tally(&finds, "Q1"), (4, 1));
    let whats: Vec<&str> = finds
        .iter()
        .filter(|f| f.rule == "Q1" && !f.allowed)
        .map(|f| f.what.as_str())
        .collect();
    assert_eq!(
        whats,
        vec![
            ".codes read",
            ".scales read",
            ".codes read",
            "construct QuantizedTensor",
        ]
    );
}

#[test]
fn q1_is_silent_inside_fp8() {
    let src = include_str!("fixtures/q1.rs");
    let (module, finds) = scan_file("fp8/q1.rs", src);
    assert_eq!(module, "fp8");
    assert_eq!(tally(&finds, "Q1"), (0, 0));
}

#[test]
fn q2_flags_raw_scale_plumbing_outside_the_install_path() {
    let src = include_str!("fixtures/q2.rs");
    let (_m, finds) = scan_file("rollout/q2.rs", src);
    assert_eq!(tally(&finds, "Q2"), (4, 1));
    let whats: Vec<&str> = finds
        .iter()
        .filter(|f| f.rule == "Q2" && !f.allowed)
        .map(|f| f.what.as_str())
        .collect();
    assert_eq!(
        whats,
        vec![
            "raw kscale",
            "ScaleSet built outside install path",
            "raw kscale",
            "raw kscale",
        ]
    );
    // the fenced fns and ScaleSet::identity() contribute nothing
    assert!(finds
        .iter()
        .filter(|f| f.rule == "Q2" && !f.allowed)
        .all(|f| f.line < 17));
}

#[test]
fn q2_is_scoped_to_the_scale_plumbing_modules() {
    let src = include_str!("fixtures/q2.rs");
    let (_m, finds) = scan_file("runtime/q2.rs", src);
    assert_eq!(tally(&finds, "Q2"), (0, 0));
}

#[test]
fn u1_flags_cross_family_arithmetic_only() {
    let src = include_str!("fixtures/u1.rs");
    let (_m, finds) = scan_file("rollout/u1.rs", src);
    assert_eq!(tally(&finds, "U1"), (3, 1));
    let whats: Vec<&str> = finds
        .iter()
        .filter(|f| f.rule == "U1" && !f.allowed)
        .map(|f| f.what.as_str())
        .collect();
    assert_eq!(
        whats,
        vec!["tokens + blocks", "bytes - blocks", "tokens += epoch"]
    );
}

#[test]
fn u1_conversion_names_exempt_the_chain_and_scope_holds() {
    let src = include_str!("fixtures/u1.rs");
    // `geo.block_tokens` (two families in one name) exempts its chain,
    // same-family and literal arithmetic never flag: all covered by
    // the exact tally above; here the module scoping.
    let (_m, finds) = scan_file("runtime/u1.rs", src);
    assert_eq!(tally(&finds, "U1"), (0, 0));
}

#[test]
fn fn_spans_cover_params_and_nested_fns() {
    let src = "fn outer(q: &QuantizedTensor) -> [u8; 4] {\n    fn inner(n: usize) -> usize { n }\n    [0; 4]\n}\ntrait T { fn decl(&self) -> usize; }\n";
    let (toks, _allows) = tokenize(src);
    let spans = fn_spans(&toks);
    // outer + inner; the bodyless trait decl contributes no span
    assert_eq!(spans.len(), 2);
    let names: Vec<&str> =
        spans.iter().map(|s| txt_at(&toks, s.name)).collect();
    assert_eq!(names, vec!["outer", "inner"]);
}

fn txt_at(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map_or("", |t| t.text.as_str())
}

#[test]
fn string_line_continuations_keep_line_numbers_aligned() {
    // `"a\` + newline + ` b"` is one string with an escaped newline;
    // a tokenizer that skips it without counting mis-anchors every
    // later finding (and thereby every allow marker) by one line.
    let src =
        "fn f(v: &[u32]) -> u32 {\n    let _s = \"a\\\n b\";\n    v[0]\n}\n";
    let (_m, finds) = scan_file("rl/probe.rs", src);
    let lines: Vec<(usize, &str)> = finds
        .iter()
        .map(|f| (f.line, f.what.as_str()))
        .collect();
    assert_eq!(lines, vec![(4, "indexing")]);
}

#[test]
fn cfg_test_items_are_excluded() {
    let src =
        "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let v = [1];\n        assert_eq!(v[0], 1);\n        Option::<u32>::None.unwrap();\n    }\n}\n";
    let (_m, finds) = scan_file("rl/t.rs", src);
    assert!(finds.is_empty(), "got {finds:?}");
}

#[test]
fn raw_strings_and_comments_hide_tokens() {
    let src =
        "fn f() -> &'static str {\n    // v[0] and x.unwrap() in a comment\n    /* panic!(\"nope\") */\n    r#\"let _ = tx.send(1); v[0]\"#\n}\n";
    let (_m, finds) = scan_file("rl/s.rs", src);
    assert!(finds.is_empty(), "got {finds:?}");
}

#[test]
fn baseline_round_trips() {
    let mut counts = Counts::new();
    counts.insert(("P1", "runtime".to_string()), (107, 0));
    counts.insert(("P1", "util".to_string()), (8, 2));
    counts.insert(("D2", "fp8".to_string()), (0, 3));
    let text = render_baseline(&counts);
    let base = parse_baseline(&text);
    // zero-violation rows are elided; nonzero rows survive exactly
    assert_eq!(base.len(), 2);
    assert_eq!(
        base.get(&("P1".to_string(), "runtime".to_string())),
        Some(&107)
    );
    assert_eq!(
        base.get(&("P1".to_string(), "util".to_string())),
        Some(&8)
    );
    assert!(text.starts_with("# pallas-lint baseline:"));
}

#[test]
fn m1_detects_model_drift_in_both_directions() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("m1");
    let details = scan_model_vocab(&root);
    assert_eq!(details.len(), 2, "got {details:?}");
    assert_eq!(details[0].rule, "M1");
    assert_eq!(details[0].rel, "rollout/pool.rs");
    assert!(
        details[0].what.contains("Fence::Drain missing"),
        "got {:?}",
        details[0].what
    );
    assert_eq!(details[1].rel, "tools/model/src/vocab.rs");
    assert!(
        details[1]
            .what
            .contains("stale vocabulary pair Ctl::Retired"),
        "got {:?}",
        details[1].what
    );
    // M1 has no allow escape
    assert!(details.iter().all(|d| !d.allowed));
}

#[test]
fn m1_is_clean_on_the_committed_tree() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let details = scan_model_vocab(&root);
    assert!(details.is_empty(), "model vocabulary drift: {details:?}");
}

#[test]
fn committed_baseline_matches_fresh_scan() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let (nfiles, counts, _details) =
        scan_tree(&root).expect("scan rust/src");
    assert!(nfiles > 0, "scan found no files");
    let fresh = render_baseline(&counts);
    let committed =
        std::fs::read_to_string(root.join("lint-baseline.txt"))
            .expect("read lint-baseline.txt");
    assert_eq!(
        fresh, committed,
        "lint-baseline.txt is stale: regenerate with \
         `cargo run -p pallas-lint -- --write-baseline`"
    );
}

#[test]
fn floors_hold_on_the_committed_tree() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let (_n, counts, _d) = scan_tree(&root).expect("scan rust/src");
    for ((rule, module), (v, _a)) in &counts {
        if matches!(
            *rule,
            "D1" | "D2" | "C1" | "A1" | "C2" | "Q1" | "Q2" | "U1"
        ) {
            assert_eq!(
                *v, 0,
                "{rule} must be 0 everywhere, {module} has {v}"
            );
        }
        if *rule == "P1" && CORE_MODULES.contains(&module.as_str()) {
            assert_eq!(*v, 0, "P1 must be 0 in {module}, found {v}");
        }
    }
}
