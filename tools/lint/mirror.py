#!/usr/bin/env python3
"""Reference mirror of pallas-lint's scan (see tools/lint/src/main.rs).

The Rust binary is authoritative; this mirror exists so the baseline can
be regenerated and cross-checked in environments without a Rust
toolchain.  Rule semantics, tokenizer behavior, and report format are
kept line-for-line equivalent to the Rust implementation — any change to
one MUST be ported to the other (the lint crate's `mirror_agrees` test
does not exist; agreement is enforced by the committed-baseline test in
tools/lint/tests plus this script in CI-less environments).

Usage: mirror.py [--root DIR] [--write-baseline] [--verbose]
"""

import os
import re
import sys

RULES = ("D1", "D2", "P1", "C1", "A1", "C2", "Q1", "Q2", "U1", "M1")

# Modules whose behavior must be bit-deterministic (rule D1).
DET_MODULES = ("rollout", "sync", "coordinator", "testkit", "fp8")
# Modules where the P1 count must be zero (hard floor, baseline-proof).
CORE_MODULES = (
    "rollout", "sync", "coordinator", "rl", "perfmodel", "root", "fp8",
)
# File stems whose arithmetic is accounting-critical (rule A1); the
# `rl` module is in scope as a whole alongside these.
A1_FILES = ("kvcache", "pool", "router", "scheduler")
# Modules where raw KV-scale plumbing is in scope for rule Q2.
Q2_MODULES = ("rollout", "sync", "coordinator")
# Modules where unit-family mixing must be zero (rule U1 hard floor).
U1_MODULES = ("fp8", "rollout", "sync")

D1_IDENTS = ("HashMap", "HashSet", "Instant", "SystemTime", "thread_rng")
FLOAT_CONSTS = ("INFINITY", "NEG_INFINITY", "NAN")
PANIC_MACROS = ("panic", "unreachable", "todo", "unimplemented")
C1_METHODS = ("send", "try_send", "send_ctl", "send_ordered")
# Sealed quantized-payload types (rule Q1).
Q1_TYPES = ("QuantizedTensor", "Nvfp4Tensor")
# Their payload fields; reads outside `fp8/` are flagged.
Q1_FIELDS = ("codes", "packed", "scales")
# Quantizing ctor fns whose results taint a binding as quantized.
Q1_CTORS = ("quantize_blockwise", "quantize_default", "quantize_nvfp4")
# The epoch-fenced install path: the only fns allowed to touch raw
# scales or build a `ScaleSet` (rule Q2).
Q2_FNS = ("install_kv_scales", "kv_scales", "sync_kv_scales")
Q2_IDENTS = ("kscale", "vscale")
# Type constructors stepped over when resolving a param's type.
TYPE_WRAPPERS = ("Arc", "Box", "Option", "Rc", "Vec")
# Identifier segments naming a unit family (rule U1); an identifier
# spanning two families (`block_tokens`) is a conversion factor.
UNIT_FAMILIES = (
    ("blocks", ("block", "blocks")),
    ("bytes", ("byte", "bytes")),
    ("epoch", ("epoch", "epochs")),
    ("tokens", ("token", "tokens")),
)
# Identifier segments that mark an accounting quantity (rule A1).
ACCT_WORDS = (
    "block", "blocks", "budget", "budgets", "load", "loads", "reserve",
    "reserved", "reserves", "token", "tokens",
)
KEYWORDS = (
    "as", "box", "break", "const", "continue", "dyn", "else", "enum",
    "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod",
    "move", "mut", "pub", "ref", "return", "static", "struct", "trait",
    "type", "unsafe", "use", "where", "while", "yield",
)

ALLOW_RE = re.compile(
    r"//\s*lint:\s*allow\((D1|D2|P1|C1|A1|C2|Q1|Q2|U1|M1)\)"
)

# Rule M1 sources of truth: (file under rust/src, enums pinned).
M1_SOURCES = (
    ("rollout/pool.rs", ("Ctl", "ToWorker", "Ordered", "Fence", "Event")),
    ("testkit/hb.rs", ("FenceState",)),
)
# The model-side vocabulary file rule M1 cross-checks (repo-relative).
M1_VOCAB = "tools/model/src/vocab.rs"
RAW_STR_RE = re.compile(r'(b?r)(#*)"')


def tokenize(src):
    """Return (tokens, allows). tokens: list of (kind, text, line) with
    kind in {id, num, fnum, p}; comments/strings/chars stripped.
    allows: set of (line, rule) from `// lint: allow(R): ...` comments.
    """
    allows = set()
    for ln, line in enumerate(src.split("\n"), 1):
        for m in ALLOW_RE.finditer(line):
            allows.add((ln, m.group(1)))

    toks = []
    i, n, line = 0, len(src), 1
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "/":
            while i < n and src[i] != "\n":
                i += 1
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "*":
            depth, i = 1, i + 2
            while i < n and depth > 0:
                if src.startswith("/*", i):
                    depth += 1
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    i += 2
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            continue
        m = RAW_STR_RE.match(src, i) if c in "rb" else None
        if m:
            close = '"' + "#" * len(m.group(2))
            j = src.find(close, m.end())
            j = n if j < 0 else j + len(close)
            line += src.count("\n", i, j)
            i = j
            continue
        if c == '"' or (c == "b" and i + 1 < n and src[i + 1] == '"'):
            i += 2 if c == "b" else 1
            while i < n:
                if src[i] == "\\":
                    # count line continuations / escaped newlines
                    if i + 1 < n and src[i + 1] == "\n":
                        line += 1
                    i += 2
                elif src[i] == '"':
                    i += 1
                    break
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            continue
        if c == "'" or (c == "b" and i + 1 < n and src[i + 1] == "'"):
            j = i + (2 if c == "b" else 1)
            if j < n and src[j] == "\\":
                j += 2
                while j < n and src[j] != "'":
                    j += 1
                i = j + 1
                continue
            if j + 1 < n and src[j] != "'" and src[j + 1] == "'":
                i = j + 2
                continue
            # lifetime: consume the quote + identifier
            i += 1
            while i < n and (src[i].isalnum() or src[i] == "_"):
                i += 1
            continue
        if c.isalpha() or c == "_":
            j = i
            while j < n and (src[j].isalnum() or src[j] == "_"):
                j += 1
            toks.append(("id", src[i:j], line))
            i = j
            continue
        if c.isdigit():
            j, isf = i, False
            if src.startswith("0x", i) or src.startswith("0b", i):
                j = i + 2
                while j < n and (src[j].isalnum() or src[j] == "_"):
                    j += 1
            else:
                while j < n and (src[j].isdigit() or src[j] == "_"):
                    j += 1
                if (
                    j + 1 < n
                    and src[j] == "."
                    and src[j + 1].isdigit()
                ):
                    isf, j = True, j + 1
                    while j < n and (
                        src[j].isdigit() or src[j] == "_"
                    ):
                        j += 1
                if (
                    j < n
                    and src[j] in "eE"
                    and (
                        (j + 1 < n and src[j + 1].isdigit())
                        or (
                            j + 2 < n
                            and src[j + 1] in "+-"
                            and src[j + 2].isdigit()
                        )
                    )
                ):
                    isf, j = True, j + 1
                    if src[j] in "+-":
                        j += 1
                    while j < n and src[j].isdigit():
                        j += 1
                sfx = j
                while sfx < n and (src[sfx].isalnum() or src[sfx] == "_"):
                    sfx += 1
                if src[j:sfx] in ("f32", "f64"):
                    isf = True
                j = sfx
            toks.append(("fnum" if isf else "num", src[i:j], line))
            i = j
            continue
        two = src[i : i + 2]
        if two in ("::", "==", "!="):
            toks.append(("p", two, line))
            i += 2
        else:
            toks.append(("p", c, line))
            i += 1
    return toks, allows


def test_regions(toks):
    """Line ranges covered by `#[cfg(test)]` items (attribute included)."""
    out = []
    i = 0
    while i < len(toks):
        pat = [t[1] for t in toks[i : i + 7]]
        if pat == ["#", "[", "cfg", "(", "test", ")", "]"]:
            start_line = toks[i][2]
            j = i + 7
            # skip further attributes on the same item
            while (
                j + 1 < len(toks)
                and toks[j][1] == "#"
                and toks[j + 1][1] == "["
            ):
                depth, j = 1, j + 2
                while j < len(toks) and depth > 0:
                    if toks[j][1] == "[":
                        depth += 1
                    elif toks[j][1] == "]":
                        depth -= 1
                    j += 1
            # find the item body's opening brace (or a terminating `;`)
            while j < len(toks) and toks[j][1] not in ("{", ";"):
                j += 1
            if j < len(toks) and toks[j][1] == "{":
                depth, j = 1, j + 1
                while j < len(toks) and depth > 0:
                    if toks[j][1] == "{":
                        depth += 1
                    elif toks[j][1] == "}":
                        depth -= 1
                    j += 1
            end_line = toks[j - 1][2] if j > 0 else start_line
            out.append((start_line, end_line))
            i = j
        else:
            i += 1
    return out


def floaty(toks, i, direction):
    """Is the operand next to a comparison at toks[i] float-typed by
    lexical evidence (float literal or an INFINITY/NEG_INFINITY/NAN
    path)?"""
    j = i + direction
    if j < 0 or j >= len(toks):
        return False
    if direction == 1 and toks[j][1] == "-":
        j += 1
        if j >= len(toks):
            return False
    k, t, _ = toks[j]
    if k == "fnum":
        return True
    if k == "id" and t in FLOAT_CONSTS:
        return True
    # forward: `f32::INFINITY` — a path whose tail is a float const
    if direction == 1 and k == "id":
        if (
            j + 2 < len(toks)
            and toks[j + 1][1] == "::"
            and toks[j + 2][1] in FLOAT_CONSTS
        ):
            return True
    return False


def match_paren(toks, i):
    depth = 0
    while i < len(toks):
        if toks[i][1] == "(":
            depth += 1
        elif toks[i][1] == ")":
            depth -= 1
            if depth == 0:
                return i
        i += 1
    return len(toks)


def is_acct(ident):
    """Accounting-flavored identifier: any `_`-separated segment names
    a resource quantity (rule A1)."""
    return any(s in ACCT_WORDS for s in ident.split("_"))


def acct_lhs(toks, op):
    """A compound `+=`/`-=`'s left-hand side: walk back from the
    operator to the statement boundary and return the first accounting
    identifier. Stops at `=`/`,` too, so `match` arms (`=>` lexes as
    `=`,`>`) don't leak scrutinee identifiers into the LHS."""
    j = op
    while j > 0:
        j -= 1
        k, t, _ = toks[j]
        if t in (";", "{", "}", "=", ","):
            return None
        if k == "id" and t not in KEYWORDS and is_acct(t):
            return t
    return None


def acct_left(toks, op):
    """Walk one operand chain LEFT from the operator at `op`
    (exclusive): identifiers, `.`/`::` separators, and matched
    `()`/`[]` groups. Returns the first accounting identifier found in
    the chain."""
    j = op
    while j > 0:
        j -= 1
        k, t, _ = toks[j]
        if t in (")", "]"):
            close, opener = t, "(" if t == ")" else "["
            depth = 1
            while j > 0 and depth > 0:
                j -= 1
                u = toks[j][1]
                if u == close:
                    depth += 1
                elif u == opener:
                    depth -= 1
            if depth > 0:
                return None
        elif t in (".", "::"):
            pass
        elif k == "id" and t not in KEYWORDS:
            if is_acct(t):
                return t
        elif k in ("num", "fnum"):
            pass
        else:
            return None
    return None


def acct_right(toks, op):
    """Walk one operand chain RIGHT from the operator at `op`
    (exclusive); same chain grammar as `acct_left`."""
    j = op + 1
    while j < len(toks):
        k, t, _ = toks[j]
        if t in ("(", "["):
            opener, close = t, ")" if t == "(" else "]"
            depth = 1
            j += 1
            while j < len(toks) and depth > 0:
                u = toks[j][1]
                if u == opener:
                    depth += 1
                elif u == close:
                    depth -= 1
                j += 1
            if depth > 0:
                return None
        elif t in (".", "::"):
            j += 1
        elif k == "id" and t not in KEYWORDS:
            if is_acct(t):
                return t
            j += 1
        elif k in ("num", "fnum"):
            j += 1
        else:
            return None
    return None


def fn_spans(toks):
    """All fn bodies in token space, as (sig, name, body_lo, body_hi)
    tuples of token indices: the `fn` keyword, the fn's name, the
    body's opening brace, one past its close. Nested fns get their own
    spans (the walk resumes just past each body's opening brace).
    Paren AND bracket depth are tracked while looking for the body
    brace so `-> [u8; 4]` return types don't read as bodyless trait
    decls."""
    out = []
    i = 0
    while i < len(toks):
        named = i + 1 < len(toks) and toks[i + 1][0] == "id"
        if toks[i][1] != "fn" or not named:
            i += 1
            continue
        name = i + 1
        j = name + 1
        depth = 0
        opn = None
        while j < len(toks):
            t = toks[j][1]
            if t in ("(", "["):
                depth += 1
            elif t in (")", "]"):
                depth -= 1
            elif t == "{" and depth == 0:
                opn = j
                break
            elif t == ";" and depth == 0:
                break
            j += 1
        if opn is None:
            i = max(j, i + 1)
            continue
        d, k = 1, opn + 1
        while k < len(toks) and d > 0:
            if toks[k][1] == "{":
                d += 1
            elif toks[k][1] == "}":
                d -= 1
            k += 1
        out.append((i, name, opn, k))
        i = opn + 1
    return out


def enclosing_fn(spans, i):
    """Index (into `spans`) of the innermost fn whose extent —
    signature included, so params count — covers token `i`."""
    best = None
    for s, (sig, _name, _lo, hi) in enumerate(spans):
        if sig < i < hi:
            if best is None or spans[best][0] < sig:
                best = s
    return best


def quant_marks(toks, span):
    """Fn-scoped dataflow (rule Q1): identifiers that lexically hold a
    quantized payload — params typed with a Q1 type (behind `&`/`mut`/
    wrapper generics), plus `let`/`for` bindings whose initializer
    mentions a Q1 type, a quantizing ctor, or an already-marked name
    (one forward pass; chains through re-bindings in source order)."""
    sig, _name, body_lo, body_hi = span
    marks = set()
    for i in range(sig, body_lo):
        k, t, _ = toks[i]
        if k != "id" or t not in Q1_TYPES:
            continue
        j = i
        while j > sig:
            p = toks[j - 1][1]
            if p in ("&", "mut", "<", "(", "[") or p in TYPE_WRAPPERS:
                j -= 1
            else:
                break
        if j >= 2 and toks[j - 1][1] == ":":
            nk, nt, _ = toks[j - 2]
            if nk == "id" and nt not in KEYWORDS:
                marks.add(nt)
    i = body_lo
    while i < body_hi:
        kw = toks[i][1] if i < len(toks) else ""
        if kw not in ("let", "for"):
            i += 1
            continue
        j = i + 1
        if kw == "let" and j < len(toks) and toks[j][1] == "mut":
            j += 1
        if (
            j >= len(toks)
            or toks[j][0] != "id"
            or toks[j][1] in KEYWORDS
        ):
            i = j
            continue
        name = toks[j][1]
        stop = ";" if kw == "let" else "{"
        k = j + 1
        tainted = False
        while k < body_hi and (k >= len(toks) or toks[k][1] != stop):
            if k < len(toks):
                uk, ut, _ = toks[k]
                if uk == "id" and (
                    ut in Q1_TYPES or ut in Q1_CTORS or ut in marks
                ):
                    tainted = True
            k += 1
        if tainted:
            marks.add(name)
        i = k
    return marks


def quant_receiver(toks, i, marks):
    """Is the receiver of the `.field` read at token `i` (the field
    ident; `i-1` is the `.`) a marked binding, or a direct call of a
    quantizing ctor / marked callable?"""
    p = i - 2
    if p < 0:
        return False
    rk, rt, _ = toks[p]
    if rt in (")", "]"):
        close, opener = rt, "(" if rt == ")" else "["
        j = p
        depth = 1
        while j > 0 and depth > 0:
            j -= 1
            u = toks[j][1]
            if u == close:
                depth += 1
            elif u == opener:
                depth -= 1
        if depth > 0 or j == 0:
            return False
        ck, ct, _ = toks[j - 1]
        return ck == "id" and (ct in Q1_CTORS or ct in marks)
    return rk == "id" and rt in marks


def unit_class(ident):
    """Unit family of an identifier, by `_`-segment (rule U1): None if
    no family word appears, the family if exactly one does, and the
    `"*"` conversion sentinel — which exempts the whole operand chain
    — when two families meet in one name (`block_tokens`,
    `bytes_per_token`)."""
    found = None
    for seg in ident.split("_"):
        for fam, words in UNIT_FAMILIES:
            if seg in words:
                if found is not None and found != fam:
                    return "*"
                found = fam
    return found


def unit_lhs(toks, op):
    """A compound `+=`/`-=`'s left-hand unit family: walk back from
    the operator to the statement boundary (same boundaries as
    `acct_lhs`) and classify the first unit-flavored identifier. A
    conversion name exempts the statement."""
    j = op
    while j > 0:
        j -= 1
        k, t, _ = toks[j]
        if t in (";", "{", "}", "=", ","):
            return None
        if k == "id" and t not in KEYWORDS:
            fam = unit_class(t)
            if fam == "*":
                return None
            if fam is not None:
                return fam
    return None


def unit_left(toks, op):
    """Walk one operand chain LEFT from the operator at `op`
    (exclusive; same chain grammar as `acct_left`) and return its
    unit family."""
    j = op
    while j > 0:
        j -= 1
        k, t, _ = toks[j]
        if t in (")", "]"):
            close, opener = t, "(" if t == ")" else "["
            depth = 1
            while j > 0 and depth > 0:
                j -= 1
                u = toks[j][1]
                if u == close:
                    depth += 1
                elif u == opener:
                    depth -= 1
            if depth > 0:
                return None
        elif t in (".", "::"):
            pass
        elif k == "id" and t not in KEYWORDS:
            fam = unit_class(t)
            if fam == "*":
                return None
            if fam is not None:
                return fam
        elif k in ("num", "fnum"):
            pass
        else:
            return None
    return None


def unit_right(toks, op):
    """Walk one operand chain RIGHT from the operator at `op`
    (exclusive; same chain grammar as `acct_right`) and return its
    unit family."""
    j = op + 1
    while j < len(toks):
        k, t, _ = toks[j]
        if t in ("(", "["):
            opener, close = t, ")" if t == "(" else "]"
            depth = 1
            j += 1
            while j < len(toks) and depth > 0:
                u = toks[j][1]
                if u == opener:
                    depth += 1
                elif u == close:
                    depth -= 1
                j += 1
            if depth > 0:
                return None
        elif t in (".", "::"):
            j += 1
        elif k == "id" and t not in KEYWORDS:
            fam = unit_class(t)
            if fam == "*":
                return None
            if fam is not None:
                return fam
            j += 1
        elif k in ("num", "fnum"):
            j += 1
        else:
            return None
    return None


def scan_file(relpath, src):
    """Return list of (rule, line, what, allowed)."""
    module = relpath.split("/")[0] if "/" in relpath else "root"
    fname = relpath.rsplit("/", 1)[-1]
    stem = fname[:-3] if fname.endswith(".rs") else fname
    toks, allows = tokenize(src)
    excluded = test_regions(toks)

    def in_test(line):
        return any(a <= line <= b for a, b in excluded)

    finds = []

    def hit(rule, line, what):
        allowed = (line, rule) in allows or (line - 1, rule) in allows
        finds.append((rule, line, what, allowed))

    det = module in DET_MODULES
    acct = stem in A1_FILES or module == "rl"
    q1 = module != "fp8"
    q2 = module in Q2_MODULES
    uni = module in U1_MODULES
    spans = fn_spans(toks)
    marks = [quant_marks(toks, s) for s in spans]
    for i, (k, t, line) in enumerate(toks):
        if in_test(line):
            continue
        prev = toks[i - 1] if i > 0 else ("p", "", 0)
        nxt = toks[i + 1] if i + 1 < len(toks) else ("p", "", 0)
        if det and k == "id" and t in D1_IDENTS:
            hit("D1", line, t)
        if k == "id" and t == "partial_cmp":
            hit("D2", line, "partial_cmp")
        if k == "p" and t in ("==", "!="):
            if floaty(toks, i, -1) or floaty(toks, i, 1):
                hit("D2", line, "float " + t)
        if (
            k == "id"
            and t in ("unwrap", "expect")
            and prev[1] == "."
            and nxt[1] == "("
        ):
            hit("P1", line, "." + t + "()")
        if k == "id" and t in PANIC_MACROS and nxt[1] == "!":
            hit("P1", line, t + "!")
        if k == "p" and t == "[":
            if (prev[0] == "id" and prev[1] not in KEYWORDS) or prev[
                1
            ] in (")", "]", "?"):
                hit("P1", line, "indexing")
        if (
            k == "id"
            and t in C1_METHODS
            and prev[1] == "."
            and nxt[1] == "("
        ):
            j = match_paren(toks, i + 1)
            tail = [x[1] for x in toks[j + 1 : j + 4]]
            if tail[:3] == [".", "ok", "("]:
                hit("C1", line, "." + t + "(..).ok()")
            else:
                b = i
                while b > 0 and toks[b - 1][1] not in (";", "{", "}"):
                    b -= 1
                head = [x[1] for x in toks[b : b + 3]]
                if head == ["let", "_", "="]:
                    hit("C1", line, "let _ = " + t)
        if acct and k == "p" and t in ("+", "-") and nxt[1] == "=":
            lhs = acct_lhs(toks, i)
            if lhs is not None:
                hit("A1", line, "unchecked " + t + "= on " + lhs)
        if (
            acct
            and k == "p"
            and t == "-"
            and nxt[1] != "="
            and nxt[1] != ">"
        ):
            binary = (
                prev[0] in ("num", "fnum")
                or prev[1] in (")", "]")
                or (prev[0] == "id" and prev[1] not in KEYWORDS)
            )
            if binary:
                ident = acct_left(toks, i) or acct_right(toks, i)
                if ident is not None:
                    hit("A1", line, "unchecked - on " + ident)
        if (
            k == "id"
            and t in ("send", "try_send")
            and prev[1] == "."
            and nxt[1] == "("
            and i + 3 < len(toks)
            and toks[i + 2][1] == "ToWorker"
            and toks[i + 3][1] == "::"
        ):
            hit("C2", line, "." + t + "(ToWorker::..)")
        if q1 and k == "id" and t in Q1_TYPES:
            lit = nxt[1] == "{" and prev[1] not in (
                ">", "impl", "struct", "enum", "dyn", "for",
            )
            newc = (
                nxt[1] == "::"
                and i + 2 < len(toks)
                and toks[i + 2][1] == "new"
            )
            if lit or newc:
                hit("Q1", line, "construct " + t)
        if (
            q1
            and k == "id"
            and t in Q1_FIELDS
            and prev[1] == "."
            and nxt[1] != "("
        ):
            s = enclosing_fn(spans, i)
            if s is not None and quant_receiver(toks, i, marks[s]):
                hit("Q1", line, "." + t + " read")
        if q2 and k == "id" and (t in Q2_IDENTS or t == "ScaleSet"):
            s = enclosing_fn(spans, i)
            fenced = s is not None and toks[spans[s][1]][1] in Q2_FNS
            if not fenced:
                if t in Q2_IDENTS:
                    hit("Q2", line, "raw " + t)
                else:
                    lit = nxt[1] == "{" and prev[1] not in (
                        ">", "impl", "struct", "enum", "dyn", "for",
                    )
                    newc = (
                        nxt[1] == "::"
                        and i + 2 < len(toks)
                        and toks[i + 2][1] == "new"
                    )
                    if lit or newc:
                        hit(
                            "Q2",
                            line,
                            "ScaleSet built outside install path",
                        )
        if uni and k == "p" and t in ("+", "-") and nxt[1] == "=":
            l_fam = unit_lhs(toks, i)
            r_fam = unit_right(toks, i + 1)
            if l_fam is not None and r_fam is not None and l_fam != r_fam:
                hit("U1", line, f"{l_fam} {t}= {r_fam}")
        if (
            uni
            and k == "p"
            and t in ("+", "-")
            and nxt[1] != "="
            and nxt[1] != ">"
        ):
            binary = (
                prev[0] in ("num", "fnum")
                or prev[1] in (")", "]")
                or (prev[0] == "id" and prev[1] not in KEYWORDS)
            )
            if binary:
                l_fam = unit_left(toks, i)
                r_fam = unit_right(toks, i)
                if (
                    l_fam is not None
                    and r_fam is not None
                    and l_fam != r_fam
                ):
                    hit("U1", line, f"{l_fam} {t} {r_fam}")
    return module, finds


def enum_variants(src, name):
    """Variants of `enum <name>` as [(variant, 1-based line)], or None
    when the enum is not found. Line-based: header is a trimmed line
    `enum <name>` (optionally behind pub/pub(crate)); a variant is a
    leading uppercase identifier on a depth-1 body line; comment-only
    and attribute lines are skipped.
    """
    lines = src.split("\n")
    header = None
    for idx, raw in enumerate(lines):
        t = raw.strip()
        for p in ("pub(crate) ", "pub "):
            if t.startswith(p):
                t = t[len(p):]
        if t.startswith("enum ") and t[5:].startswith(name):
            after = t[5 + len(name):]
            if after == "" or after[0] in (" ", "{", "<"):
                header = idx
                break
    if header is None:
        return None
    vars_, depth, open_ = [], 0, False
    for idx in range(header, len(lines)):
        raw = lines[idx]
        t = raw.strip()
        if t.startswith("//"):
            continue
        if (
            open_
            and depth == 1
            and not t.startswith("#[")
            and t[:1].isascii()
            and t[:1].isupper()
        ):
            v = ""
            for c in t:
                if c.isascii() and (c.isalnum() or c == "_"):
                    v += c
                else:
                    break
            vars_.append((v, idx + 1))
        for c in raw:
            if c == "{":
                depth += 1
                open_ = True
            elif c == "}":
                depth -= 1
        if open_ and depth <= 0:
            break
    return vars_


def vocab_pairs(src):
    """('Enum', 'Variant', line) triples: the first two quoted
    identifiers on each trimmed line starting with `("` — the lexical
    contract vocab.rs documents.
    """
    out = []
    for idx, raw in enumerate(src.split("\n")):
        t = raw.strip()
        if not t.startswith('("'):
            continue
        parts, rest = [], t
        while len(parts) < 2:
            start = rest.find('"')
            if start < 0:
                break
            after = rest[start + 1:]
            end = after.find('"')
            if end < 0:
                break
            parts.append(after[:end])
            rest = after[end + 1:]
        if len(parts) == 2:
            out.append((parts[0], parts[1], idx + 1))
    return out


def m1_module(rel):
    if rel.startswith("tools/"):
        return "model"
    return rel.split("/", 1)[0] if "/" in rel else "root"


def scan_model_vocab(root):
    """Rule M1 — model drift. Cross-checks the tools/model protocol
    vocabulary against the implementation enums in both directions;
    findings carry no allow escape. Ordering is fixed: per-source
    missing variants (M1_SOURCES order, variants in line order), then
    stale vocabulary pairs in vocab.rs line order.
    """
    details = []
    vpath = os.path.join(root, *M1_VOCAB.split("/"))
    vocab, have_vocab = [], False
    try:
        with open(vpath, encoding="utf-8") as fh:
            vocab = vocab_pairs(fh.read())
        have_vocab = True
    except OSError:
        details.append((
            "M1",
            M1_VOCAB,
            1,
            "vocabulary file unreadable — the model's protocol "
            "vocabulary cannot be cross-checked",
            False,
        ))
    used = [False] * len(vocab)
    for file, enums in M1_SOURCES:
        path = os.path.join(root, "rust", "src", *file.split("/"))
        try:
            with open(path, encoding="utf-8") as fh:
                src = fh.read()
        except OSError:
            details.append((
                "M1",
                file,
                1,
                f"{file} unreadable — M1 source of truth missing",
                False,
            ))
            continue
        for name in enums:
            vars_ = enum_variants(src, name)
            if vars_ is None:
                details.append((
                    "M1",
                    file,
                    1,
                    f"enum {name} not found in {file}",
                    False,
                ))
                continue
            for variant, line in vars_:
                hit = False
                for vi, (e, v, _ln) in enumerate(vocab):
                    if e == name and v == variant:
                        used[vi] = True
                        hit = True
                if have_vocab and not hit:
                    details.append((
                        "M1",
                        file,
                        line,
                        f"{name}::{variant} missing from the "
                        "tools/model vocabulary — update vocab.rs "
                        "and the model",
                        False,
                    ))
    for vi, (e, v, line) in enumerate(vocab):
        if not used[vi]:
            details.append((
                "M1",
                M1_VOCAB,
                line,
                f"stale vocabulary pair {e}::{v} — no such variant "
                "in the implementation",
                False,
            ))
    return details


def scan_tree(root):
    src_root = os.path.join(root, "rust", "src")
    counts = {}  # (rule, module) -> [violations, allowed]
    details = []
    nfiles = 0
    for dirpath, dirs, files in sorted(os.walk(src_root)):
        dirs.sort()
        for f in sorted(files):
            if not f.endswith(".rs"):
                continue
            nfiles += 1
            path = os.path.join(dirpath, f)
            rel = os.path.relpath(path, src_root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as fh:
                module, finds = scan_file(rel, fh.read())
            for rule, line, what, allowed in finds:
                key = (rule, module)
                counts.setdefault(key, [0, 0])
                counts[key][1 if allowed else 0] += 1
                details.append((rule, rel, line, what, allowed))
    # rule M1 runs over the whole repo, not the rust/src walk
    for rule, rel, line, what, allowed in scan_model_vocab(root):
        key = (rule, m1_module(rel))
        counts.setdefault(key, [0, 0])
        counts[key][0] += 1
        details.append((rule, rel, line, what, allowed))
    return nfiles, counts, details


def render_baseline(counts):
    lines = ["# pallas-lint baseline: <rule> <module> <count>"]
    for (rule, module) in sorted(counts):
        v = counts[(rule, module)][0]
        if v > 0:
            lines.append(f"{rule} {module} {v}")
    return "\n".join(lines) + "\n"


def parse_baseline(text):
    base = {}
    for ln in text.split("\n"):
        ln = ln.strip()
        if not ln or ln.startswith("#"):
            continue
        parts = ln.split()
        if len(parts) == 3:
            base[(parts[0], parts[1])] = int(parts[2])
    return base


def main(argv):
    root = "."
    write, verbose = False, False
    it = iter(argv)
    for a in it:
        if a == "--root":
            root = next(it)
        elif a == "--write-baseline":
            write = True
        elif a == "--verbose":
            verbose = True
    nfiles, counts, details = scan_tree(root)
    print(f"pallas-lint(mirror): scanned {nfiles} files")
    for (rule, module) in sorted(counts):
        v, a = counts[(rule, module)]
        print(f"  {rule} {module:<12} violations={v} allowed={a}")
    if verbose:
        for rule, rel, line, what, allowed in details:
            tag = " (allowed)" if allowed else ""
            print(f"    {rule} {rel}:{line} {what}{tag}")
    bpath = os.path.join(root, "lint-baseline.txt")
    if write:
        with open(bpath, "w", encoding="utf-8") as fh:
            fh.write(render_baseline(counts))
        print(f"wrote {bpath}")
        return 0
    ok = True
    # hard floors, baseline-proof
    for (rule, module), (v, _a) in sorted(counts.items()):
        if v == 0:
            continue
        if rule in ("D1", "D2", "C1", "A1", "C2", "Q1", "Q2", "U1", "M1"):
            print(f"FLOOR: {rule} must be 0 everywhere, {module} has {v}")
            ok = False
        if rule == "P1" and module in CORE_MODULES:
            print(f"FLOOR: P1 must be 0 in {module}, found {v}")
            ok = False
    if os.path.exists(bpath):
        base = parse_baseline(open(bpath, encoding="utf-8").read())
        for (rule, module), (v, _a) in sorted(counts.items()):
            b = base.get((rule, module), 0)
            if v > b:
                print(
                    f"RATCHET: {rule} {module} rose {b} -> {v}"
                )
                ok = False
    print("OK" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
