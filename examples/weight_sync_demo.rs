//! Weight-synchronization walkthrough — paper Fig 1's three phases plus
//! the Fig 7 calibration-strategy comparison, on real artifacts.
//!
//! Shows, step by step:
//!   1. initialization (engine loads FP8-variant artifacts),
//!   2. weight-sync (blockwise E4M3 quantization of the trainer's master
//!      weights; footprint + error report),
//!   3. QKV scale recalibration under BOTH strategies (inference-side on
//!      rollout prompts vs trainer-side on training-batch rows) and how
//!      close their scales land,
//!   4. inference with the synchronized weights.
//!
//! Run: `cargo run --release --example weight_sync_demo`

use std::sync::Arc;

use fp8_rl::fp8::ScaleFormat;
use fp8_rl::rl::trainer::{Trainer, TrainerConfig};
use fp8_rl::rollout::{EngineConfig, HloEngine, Request, SamplingParams};
use fp8_rl::runtime::Runtime;
use fp8_rl::sync::{CalibStrategy, Calibrator, WeightSync, WeightSyncConfig};
use fp8_rl::util::error::Result;

fn main() -> Result<()> {
    let rt = Arc::new(Runtime::new("artifacts")?);
    let spec = rt.manifest.model("dense")?.clone();
    let trainer =
        Trainer::new(rt.clone(), TrainerConfig::new("dense", "bf16"))?;

    // --- phase 1: initialization ---
    println!("[1] init: loading FP8 decode/prefill artifacts");
    let mut engine =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "fullfp8"))?;

    // --- phase 2: weight synchronization ---
    for scale_fmt in [ScaleFormat::Fp32, ScaleFormat::Ue8m0] {
        let sync = WeightSync::new(WeightSyncConfig {
            scale_fmt,
            ..WeightSyncConfig::fp8()
        });
        let (weights, rep) = sync.run(&spec, trainer.params())?;
        println!(
            "[2] sync ({scale_fmt:?} scales): {} quantized | \
             {:.2} MB -> {:.2} MB | max quant err {:.5} | {:.1} ms",
            rep.n_quantized,
            rep.bytes_bf16.get() as f64 / 1e6,
            rep.bytes_fp8.get() as f64 / 1e6,
            rep.max_quant_err,
            rep.elapsed_s * 1e3,
        );
        if scale_fmt == ScaleFormat::Fp32 {
            engine.install_weights(&weights)?;
        }
    }

    // --- phase 3: QKV scale recalibration, both strategies ---
    let rollout_prompts: Vec<Vec<i32>> =
        (0..8).map(|i| vec![12, i, 10, 9 - i, 11]).collect();
    let train_rows: Vec<Vec<i32>> = (0..8)
        .map(|i| vec![12, i, 10, 9 - i, 11, (9 + 0) as i32 % 10, 13])
        .collect();
    for (strategy, rows) in [
        (CalibStrategy::InferenceSide, &rollout_prompts),
        (CalibStrategy::TrainerSide, &train_rows),
    ] {
        let calib = Calibrator::new(rt.clone(), "dense", strategy)?;
        let (ks, vs) = calib.recalibrate(trainer.params(), rows, 14)?;
        println!(
            "[3] {strategy:?}: kscale={ks:.5} vscale={vs:.5} \
             (data: {} rows)",
            rows.len()
        );
        if strategy == CalibStrategy::InferenceSide {
            engine.install_kv_scales(ks, vs);
        }
    }

    // --- phase 4: inference with synchronized weights + scales ---
    let done = engine.generate(vec![Request {
        id: 0,
        prompt: vec![12, 4, 10, 3, 11],
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: 4,
            ..Default::default()
        },
    }])?;
    println!(
        "[4] inference under synced FP8 weights: {:?} -> {:?}",
        done[0].prompt, done[0].tokens
    );
    println!("weight_sync_demo OK");
    Ok(())
}
