//! Rollout serving example: a router in front of HLO engines serving a
//! batched request stream under KV pressure, reporting latency /
//! throughput / preemption — the vLLM-style serving shape of the stack.
//!
//! The engine runs with a deliberately small KV budget so the paged
//! allocator preempts (recompute-style) and the BF16-vs-FP8-KV capacity
//! difference is visible with *real* compute, not the cost model.
//!
//! Run: `cargo run --release --example rollout_server [-- --requests 64]`

use std::sync::Arc;
use std::time::Instant;

use fp8_rl::rollout::{
    EngineConfig, HloEngine, Request, RoutePolicy, Router, SamplingParams,
};
use fp8_rl::runtime::Runtime;
use fp8_rl::util::cli::Args;
use fp8_rl::util::error::Result;
use fp8_rl::util::rng::Pcg64;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n_requests = args.usize_or("requests", 48)?;
    let rt = Arc::new(Runtime::new(args.str_or("artifacts", "artifacts"))?);

    for variant in ["bf16", "kvfp8"] {
        // a KV budget tight enough to preempt under BF16 storage:
        // ~14 max-length sequences at bf16 (28 at fp8)
        let mut cfg = EngineConfig::new("dense", variant);
        let bytes_per_token_bf16 = 2 * 4 * 2 * 32 * 2; // 2*L*Hkv*Dh*2B
        cfg.kv_budget_bytes = Some(14 * 64 * bytes_per_token_bf16);
        let mut engine = HloEngine::new(rt.clone(), cfg)?;

        // two logical engines behind a least-loaded router (the second
        // is simulated by round-tripping ids; one process, one core)
        let mut router = Router::new(RoutePolicy::LeastLoaded, 2);
        let mut rng = Pcg64::new(7);
        let mut requests = Vec::new();
        for i in 0..n_requests {
            let a = rng.below(10) as i32;
            let b = rng.below(10) as i32;
            let req = Request {
                id: i as u64,
                prompt: vec![12, a, 10, b, 11],
                params: SamplingParams {
                    max_new_tokens: 40, // long responses stress the cache
                    ..Default::default()
                },
            };
            let _engine_idx = router.route(&req);
            requests.push(req);
        }

        let t0 = Instant::now();
        let done = engine.generate(requests)?;
        let dt = t0.elapsed().as_secs_f64();
        let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
        let preempted: u32 = done.iter().map(|c| c.preemptions).sum();
        println!(
            "[{variant:6}] {} reqs, {tokens} tokens in {dt:.1}s \
             ({:.1} tok/s) | engine preemptions={} | router loads={:?}",
            done.len(),
            tokens as f64 / dt,
            preempted,
            router.loads(),
        );
    }
    println!("rollout_server OK (FP8 KV doubles the same-budget capacity)");
    Ok(())
}
