//! Rollout serving example: a streaming engine pool behind the router,
//! serving requests AS THEY ARRIVE — submit one request at a time,
//! collect completions the moment any replica finishes one, and push a
//! weight-epoch fence through mid-stream without ever stopping the
//! pool. This is the vLLM-style continuous-admission serving shape of
//! the stack: no batch barriers, per-request latency, live queue-depth
//! routing.
//!
//! Every engine runs with a deliberately small KV budget so the paged
//! allocator preempts (recompute-style) and the BF16-vs-FP8-KV
//! capacity difference is visible with *real* compute, not the cost
//! model.
//!
//! Run: `cargo run --release --example rollout_server \
//!       [-- --requests 64 --replicas 4]`

use std::time::Instant;

use fp8_rl::rollout::{
    runtime_factory, Completed, EngineConfig, EnginePool, PoolConfig,
    Request, RoutePolicy, SamplingParams,
};
use fp8_rl::util::cli::Args;
use fp8_rl::util::error::{anyhow, Result};
use fp8_rl::util::rng::Pcg64;
use fp8_rl::util::units::Bytes;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n_requests = args.usize_or("requests", 48)?;
    let n_replicas = args.usize_or("replicas", 4)?;
    let factory = runtime_factory(args.str_or("artifacts", "artifacts"));

    for variant in ["bf16", "kvfp8"] {
        // a KV budget tight enough to preempt under BF16 storage:
        // ~14 max-length sequences at bf16 (28 at fp8) per replica
        let mut cfg = EngineConfig::new("dense", variant);
        let bytes_per_token_bf16 = 2 * 4 * 2 * 32 * 2; // 2*L*Hkv*Dh*2B
        cfg.kv_budget_bytes =
            Some(Bytes::new(14 * 64 * bytes_per_token_bf16));
        // --prefix-sharing: duplicate prompts share KV copy-on-write
        // and route to a home replica (outputs bit-identical)
        cfg.prefix_sharing = args.bool("prefix-sharing");
        let policy = if cfg.prefix_sharing {
            RoutePolicy::PrefixAffinity
        } else {
            RoutePolicy::LeastLoaded
        };
        let mut pool = EnginePool::new(
            PoolConfig {
                n_replicas,
                policy,
                engine: cfg,
            },
            factory.clone(),
        )?;

        let mut rng = Pcg64::new(7);
        let mut done = Vec::new();
        let t0 = Instant::now();
        for i in 0..n_requests {
            // the arrival stream: requests trickle in one at a time and
            // are admitted into replicas that are already mid-decode
            pool.submit(Request {
                id: i as u64,
                prompt: vec![
                    12,
                    rng.below(10) as i32,
                    10,
                    rng.below(10) as i32,
                    11,
                ],
                params: SamplingParams {
                    max_new_tokens: 40, // long responses stress the cache
                    ..Default::default()
                },
            })?;
            // halfway through the arrivals, a recalibration lands as an
            // epoch fence: in-flight sequences finish under the old
            // scales, later arrivals use the new ones — the pool never
            // stops serving
            if i + 1 == n_requests / 2 {
                let epoch = pool.sync_kv_scales(1.1, 0.9)?;
                println!(
                    "[{variant:6}] mid-stream KV-scale fence -> \
                     epoch {epoch} ({} requests in flight)",
                    pool.n_outstanding()
                );
            }
            // completions stream back while we are still submitting
            while let Some(c) = pool.poll() {
                done.push(finished(c)?);
            }
        }
        // run the stream dry (next_resolved returns None only once
        // nothing is outstanding AND the ready queue is empty, and it
        // surfaces fence failures instead of swallowing them)
        while let Some(c) = pool.next_resolved()? {
            done.push(finished(c)?);
        }
        let dt = t0.elapsed().as_secs_f64();

        let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
        let preempted: u32 = done.iter().map(|c| c.preemptions).sum();
        let old_epoch =
            done.iter().filter(|c| c.epoch == 0).count();
        let new_epoch = done.len() - old_epoch;
        let per: Vec<u64> = pool
            .per_replica_stats()?
            .iter()
            .map(|s| s.tokens_generated)
            .collect();
        println!(
            "[{variant:6}] {} reqs, {tokens} tokens in {dt:.1}s \
             ({:.1} tok/s aggregate over {n_replicas} replicas, \
             streaming admission) | preemptions={preempted} | \
             epochs: {old_epoch} old / {new_epoch} new | \
             per-replica tokens={per:?}",
            done.len(),
            tokens as f64 / dt,
        );
        assert!(
            pool.loads().iter().all(|&l| l == 0),
            "router load must drain once the stream is dry: {:?}",
            pool.loads()
        );
    }
    println!(
        "rollout_server OK (continuous admission keeps every replica \
         busy; FP8 KV doubles the same-budget capacity; epoch fences \
         swap scales without stopping the pool)"
    );
    Ok(())
}

/// Unwrap a streamed resolution into its completion (this example
/// never aborts, so only `Done` is expected).
fn finished(
    c: Completed,
) -> Result<fp8_rl::rollout::Completion> {
    match c {
        Completed::Done(c) => Ok(c),
        Completed::Aborted(id) => {
            Err(anyhow!("request {id} unexpectedly aborted"))
        }
        Completed::Failed(id, msg) => {
            Err(anyhow!("request {id} failed: {msg}"))
        }
    }
}
