//! Rollout serving example: a thread-per-replica engine pool behind
//! the router serving a batched request stream under KV pressure,
//! reporting latency / throughput / preemption — the vLLM-style
//! serving shape of the stack, now actually multicore (each replica
//! owns its own runtime + engine on its own OS thread).
//!
//! Every engine runs with a deliberately small KV budget so the paged
//! allocator preempts (recompute-style) and the BF16-vs-FP8-KV
//! capacity difference is visible with *real* compute, not the cost
//! model.
//!
//! Run: `cargo run --release --example rollout_server \
//!       [-- --requests 64 --replicas 4]`

use std::time::Instant;

use fp8_rl::rollout::{
    runtime_factory, EngineConfig, EnginePool, PoolConfig, Request,
    RoutePolicy, SamplingParams,
};
use fp8_rl::util::cli::Args;
use fp8_rl::util::error::Result;
use fp8_rl::util::rng::Pcg64;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n_requests = args.usize_or("requests", 48)?;
    let n_replicas = args.usize_or("replicas", 4)?;
    let factory = runtime_factory(args.str_or("artifacts", "artifacts"));

    for variant in ["bf16", "kvfp8"] {
        // a KV budget tight enough to preempt under BF16 storage:
        // ~14 max-length sequences at bf16 (28 at fp8) per replica
        let mut cfg = EngineConfig::new("dense", variant);
        let bytes_per_token_bf16 = 2 * 4 * 2 * 32 * 2; // 2*L*Hkv*Dh*2B
        cfg.kv_budget_bytes = Some(14 * 64 * bytes_per_token_bf16);
        let mut pool = EnginePool::new(
            PoolConfig {
                n_replicas,
                policy: RoutePolicy::LeastLoaded,
                engine: cfg,
            },
            factory.clone(),
        )?;

        let mut rng = Pcg64::new(7);
        let requests: Vec<Request> = (0..n_requests)
            .map(|i| Request {
                id: i as u64,
                prompt: vec![
                    12,
                    rng.below(10) as i32,
                    10,
                    rng.below(10) as i32,
                    11,
                ],
                params: SamplingParams {
                    max_new_tokens: 40, // long responses stress the cache
                    ..Default::default()
                },
            })
            .collect();

        let t0 = Instant::now();
        let done = pool.generate(requests)?;
        let dt = t0.elapsed().as_secs_f64();
        let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
        let preempted: u32 = done.iter().map(|c| c.preemptions).sum();
        let per: Vec<u64> = pool
            .per_replica_stats()?
            .iter()
            .map(|s| s.tokens_generated)
            .collect();
        println!(
            "[{variant:6}] {} reqs, {tokens} tokens in {dt:.1}s \
             ({:.1} tok/s aggregate over {n_replicas} replicas) | \
             preemptions={preempted} | per-replica tokens={per:?}",
            done.len(),
            tokens as f64 / dt,
        );
        assert!(
            pool.loads().iter().all(|&l| l == 0),
            "router load must drain after the batch: {:?}",
            pool.loads()
        );
    }
    println!(
        "rollout_server OK (FP8 KV doubles the same-budget capacity; \
         replicas scale tokens/s)"
    );
    Ok(())
}
