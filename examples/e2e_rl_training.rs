//! End-to-end RL training driver — the full three-layer stack on a real
//! workload (the mandated e2e validation example):
//!
//!   Rust coordinator -> FP8 weight sync -> HLO rollout engine (Pallas
//!   W8A8 + blocked attention inside the decode artifact) -> DAPO train
//!   step artifact (jax.grad + Adam) -> repeat.
//!
//! Trains the tiny Qwen3-style policy on one-digit addition with FP8
//! rollout + token-level TIS and logs the full curve set (reward,
//! validation accuracy, response length, mismatch KL) to
//! results/e2e_example.csv. ~3-4 s/step on one CPU core.
//!
//! Run: `make artifacts && cargo run --release --example e2e_rl_training
//!       [-- --steps 50 --rollout fp8lin --train-variant bf16
//!           --replicas 2 --pipeline 1]`
//!
//! `--pipeline D` switches to the cross-step pipelined driver
//! (DESIGN.md §6): the next D steps' rollout waves decode inside the
//! streaming pool while the current step trains, with TIS/MIS
//! correcting the one-step-stale behavior policy exactly like it
//! corrects precision mismatch (the staleness window defaults to the
//! schedule's lag).

use std::sync::Arc;

use fp8_rl::coordinator::{ExperimentConfig, RlLoop};
use fp8_rl::runtime::Runtime;
use fp8_rl::util::cli::Args;
use fp8_rl::util::error::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.usize_or("steps", 50)?;
    let rollout = args.str_or("rollout", "fp8lin");
    let train_v = args.str_or("train-variant", "bf16");

    let mut cfg = ExperimentConfig::new(
        "e2e_example",
        args.str_or("arch", "dense"),
        rollout,
        train_v,
    );
    cfg.steps = steps;
    cfg.lr = 1e-3;
    cfg.max_digits = 1;
    cfg.max_sum = Some(9);
    cfg.samples_per_prompt = 8;
    cfg.prompts_per_step = 8;
    cfg.max_new_tokens = 6;
    cfg.rollout_replicas = args.usize_or("replicas", 1)?;
    cfg.pipeline_depth = args.usize_or("pipeline", 0)?;
    // --prefix-sharing: GRPO groups share prompt KV copy-on-write and
    // skip redundant prefill (bit-identical outputs; DESIGN.md §10)
    cfg.prefix_sharing = args.bool("prefix-sharing");
    if cfg.pipeline_depth > 0 {
        // pipelining rides the streaming pool; the staleness window
        // defaults to exactly the schedule's lag
        cfg.rollout_streaming = true;
        cfg.max_epoch_staleness =
            cfg.pipeline_depth as u64 * cfg.epochs_per_step();
    }

    println!(
        "e2e RL: arch={} rollout={} train={} steps={} replicas={} \
         pipeline={} prefix_sharing={}",
        cfg.arch,
        cfg.rollout_variant,
        cfg.train_variant,
        cfg.steps,
        cfg.rollout_replicas,
        cfg.pipeline_depth,
        cfg.prefix_sharing
    );
    let rt = Arc::new(Runtime::new(args.str_or("artifacts", "artifacts"))?);
    let mut rl = RlLoop::new(rt, cfg)?;
    for step in 0..steps {
        let rec = rl.step(step)?;
        println!(
            "step {step:3}: reward={:.3} acc={:.3} len={:.1} \
             kl={:.2e} ent={:.2} [{:.1}s rollout, {:.1}s train, \
             {:.1}s overlapped, staleness {:.1}]",
            rec.get("reward"),
            rec.get("val_accuracy"),
            rec.get("response_len"),
            rec.get("mismatch_kl"),
            rec.get("entropy"),
            rec.get("rollout_s"),
            rec.get("train_s"),
            rec.get("pipeline_overlap_s"),
            rec.get("staleness_mean"),
        );
        rl.recorder.push(rec);
    }
    rl.recorder.write_csv("results/e2e_example.csv")?;
    println!(
        "final: reward(tail10)={:.3} accuracy(tail10)={:.3} \
         -> results/e2e_example.csv",
        rl.recorder.tail_mean("reward", 10),
        rl.recorder.tail_mean("val_accuracy", 10),
    );
    Ok(())
}
