//! Quickstart: load the AOT artifacts, run the FP8 weight-sync pipeline
//! once, generate a few completions under BF16 and FP8 rollout, and
//! print the measured train/inference mismatch — the paper's eq. (2)
//! ingredients, end to end, in ~40 lines of user code.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::sync::Arc;

use fp8_rl::rl::trainer::{Trainer, TrainerConfig};
use fp8_rl::rollout::{EngineConfig, HloEngine, Request, SamplingParams};
use fp8_rl::runtime::Runtime;
use fp8_rl::sync::{WeightSync, WeightSyncConfig};
use fp8_rl::util::error::Result;

fn main() -> Result<()> {
    let rt = Arc::new(Runtime::new("artifacts")?);
    let spec = rt.manifest.model("dense")?.clone();

    // the trainer owns the master weights
    let trainer = Trainer::new(rt.clone(), TrainerConfig::new("dense", "bf16"))?;

    // --- weight synchronization (paper Fig 1) ---
    let sync = WeightSync::new(WeightSyncConfig::fp8());
    let (fp8_weights, report) = sync.run(&spec, trainer.params())?;
    println!(
        "weight sync: {} tensors quantized, {} passthrough, \
         {:.1} MB (bf16) -> {:.1} MB (fp8 codes+scales), max err {:.4}",
        report.n_quantized,
        report.n_passthrough,
        report.bytes_bf16.get() as f64 / 1e6,
        report.bytes_fp8.get() as f64 / 1e6,
        report.max_quant_err,
    );

    // --- generate the same prompts under BF16 and FP8 rollout ---
    let prompts: Vec<Vec<i32>> = vec![
        vec![12, 2, 10, 3, 11], // BOS 2 + 3 =
        vec![12, 7, 10, 1, 11], // BOS 7 + 1 =
    ];
    let mut outs = Vec::new();
    for variant in ["bf16", "fp8lin"] {
        let mut engine =
            HloEngine::new(rt.clone(), EngineConfig::new("dense", variant))?;
        if variant == "fp8lin" {
            engine.install_weights(&fp8_weights)?;
        }
        let reqs: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request {
                id: i as u64,
                prompt: p.clone(),
                params: SamplingParams {
                    temperature: 0.0, // greedy so the runs are comparable
                    max_new_tokens: 5,
                    ..Default::default()
                },
            })
            .collect();
        let done = engine.generate(reqs)?;
        for c in &done {
            // greedy behavior logprobs are the point-mass 0, so show
            // the full-vocab diagnostic — that is where the BF16-vs-FP8
            // policy difference is visible
            println!(
                "[{variant}] prompt {:?} -> {:?} (logp {:?})",
                c.prompt,
                c.tokens,
                c.logprobs_full
                    .iter()
                    .map(|l| (l * 100.0).round() / 100.0)
                    .collect::<Vec<_>>()
            );
        }
        outs.push(done);
    }

    // --- mismatch: same sampled tokens, two policies ---
    let (bf16_out, fp8_out) = (&outs[0], &outs[1]);
    for (a, b) in bf16_out.iter().zip(fp8_out.iter()) {
        let same = a.tokens == b.tokens;
        println!(
            "prompt {:?}: greedy outputs {} under FP8 rollout",
            a.prompt,
            if same { "MATCH" } else { "DIVERGE" }
        );
    }
    println!("quickstart OK");
    Ok(())
}
