//! Streaming-pool determinism property suite (hermetic: synthetic
//! manifest + RefBackend in every thread).
//!
//! The contract under test — the streaming tentpole's headline
//! invariant: for ANY admission interleaving of submit / poll /
//! weight-sync / abort events, an N-replica streaming pool's
//! completions (tokens, behavior logprobs, full-vocab logprobs, epoch
//! tags, finish reasons) are bit-equal to a sequential single-engine
//! reference that processes the same event order one request at a
//! time, and the router's live load accounting drains to zero.
//!
//! Interleavings come from `testkit::interleave`: each case is fully
//! reproducible from the single `u64` seed printed on failure. Every
//! case contains at least one weight-sync epoch boundary (the spec
//! pins `n_syncs >= 1`), so the epoch-fence argument — in-flight
//! sequences finish under the old weights, later submissions use the
//! new ones, tags match — is exercised 256+ times.
//!
//! Every case additionally runs with a happens-before recorder
//! attached (`testkit::hb`): once the session is quiescent, the full
//! event log is replayed through the fence-protocol conformance
//! checker, so all 256+ interleavings double as protocol-conformance
//! witnesses (inert under `--no-default-features`).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use fp8_rl::rollout::{
    hermetic_runtime_factory, Completed, Completion, EngineConfig,
    EnginePool, HloEngine, PoolConfig, Request, RoutePolicy,
    SamplingParams,
};
use fp8_rl::runtime::{HostArray, Runtime};
use fp8_rl::sync::{WeightSync, WeightSyncConfig};
use fp8_rl::testkit::hb::{HbHandle, HbRecorder};
use fp8_rl::testkit::interleave::{
    run, InterleaveSpec, InterleaveTarget,
};
use fp8_rl::util::rng::Pcg64;

const CASES: u64 = 256;

/// Perturbed-then-FP8-quantized weights standing in for trainer step
/// `j` (quantized once; the SAME `Arc` list is installed into every
/// pool replica and the reference engine).
fn synced_weights(rt: &Runtime, j: usize) -> Arc<Vec<HostArray>> {
    let spec = rt.manifest.model("dense").unwrap().clone();
    let init = rt.manifest.load_initial_params("dense").unwrap();
    let scale = 1.0 + 0.01 * (j as f32 + 1.0);
    let params: Vec<HostArray> = init
        .into_iter()
        .zip(&spec.params)
        .map(|(mut v, p)| {
            for x in v.iter_mut() {
                *x *= scale;
            }
            HostArray::f32(p.shape.clone(), v)
        })
        .collect();
    let sync = WeightSync::new(WeightSyncConfig::fp8());
    let (w, _) = sync.run_shared(&spec, &params).unwrap();
    w
}

/// A request set exercising every sampler path (plain / top-k / top-p /
/// greedy) with seed-varied prompts and lengths.
fn gen_requests(rng: &mut Pcg64, n: usize) -> Vec<Request> {
    // GRPO-style duplicates: some requests reuse the previous prompt so
    // prefix sharing (when enabled) actually finds shareable prefixes
    let mut last: Option<Vec<i32>> = None;
    (0..n)
        .map(|i| {
            let params = match i % 4 {
                0 => SamplingParams {
                    temperature: 1.0,
                    max_new_tokens: 2 + rng.below(3) as usize,
                    ..Default::default()
                },
                1 => SamplingParams {
                    temperature: 1.0,
                    top_k: 5,
                    max_new_tokens: 2 + rng.below(3) as usize,
                    ..Default::default()
                },
                2 => SamplingParams {
                    temperature: 1.0,
                    top_p: 0.9,
                    max_new_tokens: 2 + rng.below(3) as usize,
                    ..Default::default()
                },
                _ => SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: 3,
                    ..Default::default()
                },
            };
            let mut prompt = vec![12, rng.below(10) as i32, 10];
            for _ in 0..rng.below(3) {
                prompt.push(rng.below(10) as i32);
            }
            prompt.push(11);
            if i % 2 == 1 && rng.below(2) == 0 {
                if let Some(prev) = &last {
                    prompt = prev.clone();
                }
            }
            last = Some(prompt.clone());
            Request {
                id: 1 + i as u64,
                prompt,
                params,
            }
        })
        .collect()
}

/// The streaming session: drives a live `EnginePool` and records how
/// every ticket resolved.
struct StreamSession {
    pool: EnginePool,
    requests: Vec<Request>,
    syncs: Vec<Arc<Vec<HostArray>>>,
    completions: BTreeMap<u64, Completion>,
    aborted: BTreeSet<u64>,
    submitted: BTreeSet<u64>,
}

impl StreamSession {
    fn record(&mut self, c: Completed) -> Result<(), String> {
        match c {
            Completed::Done(c) => {
                if self.completions.insert(c.id, c).is_some() {
                    return Err("ticket resolved twice (done)".into());
                }
            }
            Completed::Aborted(id) => {
                if !self.aborted.insert(id) {
                    return Err(format!(
                        "ticket {id} resolved twice (aborted)"
                    ));
                }
            }
            Completed::Failed(id, msg) => {
                return Err(format!("ticket {id} failed: {msg}"));
            }
        }
        Ok(())
    }

    /// Block until every outstanding ticket resolves.
    fn finish(&mut self) -> Result<(), String> {
        while let Some(c) =
            self.pool.next_resolved().map_err(|e| e.to_string())?
        {
            self.record(c)?;
        }
        Ok(())
    }
}

impl InterleaveTarget for StreamSession {
    type Err = String;

    fn submit(&mut self, i: usize) -> Result<(), String> {
        let req = self.requests[i].clone();
        self.submitted.insert(req.id);
        self.pool
            .submit(req)
            .map(|_| ())
            .map_err(|e| e.to_string())
    }

    fn sync(&mut self, j: usize) -> Result<(), String> {
        self.pool
            .sync_weights(self.syncs[j].clone())
            .map(|_| ())
            .map_err(|e| e.to_string())
    }

    fn poll(&mut self) -> Result<(), String> {
        while let Some(c) = self.pool.poll() {
            self.record(c)?;
        }
        Ok(())
    }

    fn abort(&mut self, i: usize) -> Result<(), String> {
        self.pool
            .abort(self.requests[i].id)
            .map_err(|e| e.to_string())
    }
}

/// The sequential reference: one engine, one request at a time, in
/// plan order — installs land exactly at their fence position, so
/// request k's weights are determined by how many syncs precede its
/// submit, which is precisely what the pool's epoch fence promises.
struct SeqReference {
    engine: HloEngine,
    requests: Vec<Request>,
    syncs: Vec<Arc<Vec<HostArray>>>,
    completions: BTreeMap<u64, Completion>,
}

impl InterleaveTarget for SeqReference {
    type Err = String;

    fn submit(&mut self, i: usize) -> Result<(), String> {
        let done = self
            .engine
            .generate(vec![self.requests[i].clone()])
            .map_err(|e| e.to_string())?;
        for c in done {
            self.completions.insert(c.id, c);
        }
        Ok(())
    }

    fn sync(&mut self, j: usize) -> Result<(), String> {
        self.engine
            .install_weights(&self.syncs[j])
            .map_err(|e| e.to_string())
    }

    fn poll(&mut self) -> Result<(), String> {
        Ok(())
    }

    fn abort(&mut self, _i: usize) -> Result<(), String> {
        // the reference generates everything; comparison is restricted
        // to the tickets the stream actually completed
        Ok(())
    }
}

fn case(seed: u64) -> Result<(), String> {
    let mut rng = Pcg64::new(seed ^ 0xD15E_A5E0);
    let n_requests = 3 + rng.below(4) as usize; // 3..6
    let spec = InterleaveSpec {
        n_requests,
        n_syncs: 1 + rng.below(2) as usize, // >= 1 epoch boundary
        n_aborts: rng.below(2) as usize,
        n_polls: 3,
    };
    let plan = spec.plan(rng.next_u64());
    plan.check_well_formed(&spec);
    let replicas = 2 + (seed % 3) as usize; // 2..4
    let policy = if seed % 2 == 0 {
        RoutePolicy::RoundRobin
    } else {
        RoutePolicy::LeastLoaded
    };
    let requests = gen_requests(&mut rng, n_requests);
    let rt = Runtime::hermetic();
    let syncs: Vec<Arc<Vec<HostArray>>> =
        (0..spec.n_syncs).map(|j| synced_weights(&rt, j)).collect();

    // half the cases run with prefix sharing ON: the bit-equality claim
    // must hold across the knob (the reference below stays UNSHARED, so
    // any sharing-induced divergence in tokens/logprobs fails the case)
    let mut engine_cfg = EngineConfig::new("dense", "bf16");
    engine_cfg.prefix_sharing = seed % 2 == 0;
    let pool = EnginePool::new_traced(
        PoolConfig {
            n_replicas: replicas,
            policy,
            engine: engine_cfg,
        },
        hermetic_runtime_factory(),
        HbHandle::traced(HbRecorder::new(replicas)),
    )
    .map_err(|e| e.to_string())?;
    let mut stream = StreamSession {
        pool,
        requests: requests.clone(),
        syncs: syncs.clone(),
        completions: BTreeMap::new(),
        aborted: BTreeSet::new(),
        submitted: BTreeSet::new(),
    };
    run(&plan, &mut stream)?;
    stream.finish()?;

    // --- session accounting: every ticket resolved exactly once and
    // the router's live loads drained to zero ---
    if stream.pool.n_outstanding() != 0 {
        return Err(format!(
            "{} tickets left outstanding",
            stream.pool.n_outstanding()
        ));
    }
    if !stream.pool.loads().iter().all(|&l| l == 0) {
        return Err(format!(
            "router loads did not drain: {:?}",
            stream.pool.loads()
        ));
    }
    let done_ids: BTreeSet<u64> =
        stream.completions.keys().copied().collect();
    if !done_ids.is_disjoint(&stream.aborted) {
        return Err("a ticket resolved both done and aborted".into());
    }
    let resolved: BTreeSet<u64> =
        done_ids.union(&stream.aborted).copied().collect();
    if resolved != stream.submitted {
        return Err(format!(
            "resolved {:?} != submitted {:?}",
            resolved, stream.submitted
        ));
    }

    // --- fence-protocol conformance: replay the recorded hb log
    // through the checker now that the session is quiescent ---
    stream
        .pool
        .hb_verify()
        .map_err(|e| format!("hb conformance: {e}"))?;

    // --- the bit-equality claim against the sequential reference ---
    let mut reference = SeqReference {
        engine: HloEngine::new(
            Arc::new(Runtime::hermetic()),
            EngineConfig::new("dense", "bf16"),
        )
        .map_err(|e| e.to_string())?,
        requests,
        syncs,
        completions: BTreeMap::new(),
    };
    run(&plan, &mut reference)?;
    for (id, c) in &stream.completions {
        let r = reference
            .completions
            .get(id)
            .ok_or(format!("reference never completed request {id}"))?;
        if c.tokens != r.tokens {
            return Err(format!("tokens diverge for request {id}"));
        }
        if c.logprobs != r.logprobs {
            return Err(format!(
                "behavior logprobs diverge for request {id}"
            ));
        }
        if c.logprobs_full != r.logprobs_full {
            return Err(format!(
                "full-vocab logprobs diverge for request {id}"
            ));
        }
        if c.epoch != r.epoch {
            return Err(format!(
                "epoch tag diverges for request {id}: stream {} vs \
                 reference {} — a completion spanned a weight install",
                c.epoch, r.epoch
            ));
        }
        if c.finish != r.finish {
            return Err(format!("finish reason diverges for request {id}"));
        }
    }
    Ok(())
}

#[test]
fn streaming_pool_matches_sequential_reference_over_256_interleavings() {
    for seed in 0..CASES {
        if let Err(msg) = case(seed) {
            panic!(
                "streaming-vs-reference property failed \
                 (replay with seed {seed}): {msg}"
            );
        }
    }
}
