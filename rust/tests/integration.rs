//! Integration tests over the runtime + engine + trainer + sync stack.
//!
//! These run HERMETICALLY on the RefBackend (synthetic manifest, seeded
//! weights): no Python, no `make artifacts`, no native libraries. The
//! same suite exercises the exact code paths the PJRT backend drives —
//! engine continuous batching, chunked-vs-wave prefill, weight sync,
//! KV-scale calibration, DAPO training and the full RL loop — so what
//! used to be permanently-skipped coverage is now always on.

use std::sync::Arc;

use fp8_rl::coordinator::{ExperimentConfig, RlLoop};
use fp8_rl::rl::dapo::{score, Sample, TrainBatch};
use fp8_rl::rl::task::{make_problem, Task, TaskConfig};
use fp8_rl::rl::trainer::{Trainer, TrainerConfig};
use fp8_rl::rollout::{
    EngineConfig, FinishReason, HloEngine, Request, SamplingParams,
};
use fp8_rl::runtime::Runtime;
use fp8_rl::util::units::Bytes;
use fp8_rl::sync::{
    CalibStrategy, Calibrator, WeightSync, WeightSyncConfig,
};

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::hermetic())
}

/// Requests with ids (and prompts) drawn from `lo..hi`.
fn requests_range(
    lo: u64,
    hi: u64,
    max_new: usize,
    temp: f32,
) -> Vec<Request> {
    (lo..hi)
        .map(|i| Request {
            id: i,
            prompt: vec![
                12,
                (i % 10) as i32,
                10,
                ((i + 3) % 10) as i32,
                11,
            ],
            params: SamplingParams {
                temperature: temp,
                max_new_tokens: max_new,
                ..Default::default()
            },
        })
        .collect()
}

fn requests(n: u64, max_new: usize, temp: f32) -> Vec<Request> {
    requests_range(0, n, max_new, temp)
}

#[test]
fn manifest_loads_and_is_consistent() {
    let rt = runtime();
    let m = &rt.manifest;
    assert!(m.entrypoints.len() >= 30);
    for arch in ["dense", "moe"] {
        let spec = m.model(arch).unwrap();
        assert!(spec.total_weights() > 10_000);
        let params = m.load_initial_params(arch).unwrap();
        assert_eq!(params.len(), spec.params.len());
        // every kind exists for every arch
        for kind in ["prefill", "decode", "train", "logprobs", "calibrate"]
        {
            assert!(
                m.entrypoints
                    .values()
                    .any(|e| e.arch == arch && e.kind == kind),
                "{arch} missing {kind}"
            );
        }
    }
}

#[test]
fn engine_greedy_is_deterministic() {
    let rt = runtime();
    let mut e1 =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "bf16"))
            .unwrap();
    let mut e2 =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "bf16"))
            .unwrap();
    let a = e1.generate(requests(4, 6, 0.0)).unwrap();
    let b = e2.generate(requests(4, 6, 0.0)).unwrap();
    assert_eq!(a.len(), 4);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.tokens, y.tokens, "greedy decode must be stable");
        assert_eq!(x.logprobs, y.logprobs);
        // greedy behavior logprobs are the point-mass 0; the full-vocab
        // diagnostic carries the numeric signal
        assert_eq!(x.logprobs_full, y.logprobs_full);
    }
}

#[test]
fn prefill_wave_matches_chunked_prefill() {
    // the batched-prefill fast path and the chunked (decode-path)
    // prefill must produce the same greedy continuation. b_rollout is 8
    // in the synthetic manifest, so an 11-request batch takes the wave
    // for the first 8 and admits the last 3 through the chunked path as
    // slots free up.
    let rt = runtime();
    let mut wave_engine =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "bf16"))
            .unwrap();
    let wave = wave_engine.generate(requests_range(8, 11, 5, 0.0)).unwrap();

    let mut mixed_engine =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "bf16"))
            .unwrap();
    let mixed = mixed_engine.generate(requests_range(0, 11, 5, 0.0)).unwrap();
    assert_eq!(mixed.len(), 11);
    assert!(
        mixed_engine.stats.prefill_waves >= 1,
        "first 8 should go through the wave"
    );
    for c in &wave {
        let m = mixed.iter().find(|x| x.id == c.id).unwrap();
        assert_eq!(
            c.tokens, m.tokens,
            "req {}: wave {:?} vs chunked {:?}",
            c.id, c.tokens, m.tokens
        );
    }
}

#[test]
fn engine_stall_fails_fast_with_diagnostic() {
    // regression: a head-of-line request that can never fit used to
    // spin 200k no-op iterations before erroring; it must now fail
    // immediately and name the stuck request + its block requirement
    let rt = runtime();
    let mut cfg = EngineConfig::new("dense", "bf16");
    // exactly one 16-token block: a 16-token prompt (+1 growth) needs 2
    cfg.kv_budget_bytes = Some(Bytes::new(4096));
    let mut engine = HloEngine::new(rt, cfg).unwrap();
    let req = Request {
        id: 7,
        prompt: vec![1; 16],
        params: SamplingParams::default(),
    };
    let t0 = std::time::Instant::now();
    let err = engine.generate(vec![req]).unwrap_err().to_string();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(5),
        "stall detection must be immediate"
    );
    assert!(err.contains("request 7"), "{err}");
    assert!(err.contains("can never be admitted"), "{err}");
    assert!(err.contains("2 KV blocks"), "{err}");
}

#[test]
fn engine_self_preempt_thrash_fails_fast() {
    // regression: a request whose prompt fits but whose
    // prompt+generation footprint exceeds TOTAL capacity used to admit,
    // grow, self-preempt and restart forever (until the 200k guard);
    // it must now error after a bounded number of recompute attempts
    let rt = runtime();
    let mut cfg = EngineConfig::new("dense", "bf16");
    cfg.kv_budget_bytes = Some(Bytes::new(4096)); // 1 block = 16 tokens
    let mut engine = HloEngine::new(rt, cfg).unwrap();
    let req = Request {
        id: 9,
        prompt: vec![12, 2, 10, 3, 11],
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: 32,
            eos: -1, // never terminates early
            ..Default::default()
        },
    };
    let err = engine.generate(vec![req]).unwrap_err().to_string();
    assert!(err.contains("request 9"), "{err}");
    assert!(err.contains("self-preempted"), "{err}");
    assert!(
        engine.stats.decode_steps < 1000,
        "thrash not bounded: {} steps",
        engine.stats.decode_steps
    );
}

#[test]
fn engine_preemption_accounting() {
    // a KV budget tight enough that two growing sequences fight over
    // the last block: the newest is preempted (recompute) and both
    // still finish, with the eviction counted on the victim
    let rt = runtime();
    let mut cfg = EngineConfig::new("dense", "bf16");
    cfg.kv_budget_bytes = Some(Bytes::new(3 * 4096)); // 3 blocks = 48 tokens
    let mut engine = HloEngine::new(rt, cfg).unwrap();
    let reqs: Vec<Request> = (0..2)
        .map(|i| Request {
            id: i,
            prompt: vec![12, i as i32, 10, 3, 11],
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 32,
                eos: -1, // never matches: force long generations
                ..Default::default()
            },
        })
        .collect();
    let done = engine.generate(reqs).unwrap();
    assert_eq!(done.len(), 2);
    for c in &done {
        assert_eq!(c.finish, FinishReason::MaxTokens);
        assert_eq!(c.tokens.len(), 32);
        assert_eq!(c.logprobs.len(), 32);
    }
    assert!(
        engine.stats.preemptions >= 1,
        "expected preemption under a 3-block budget"
    );
    let victim = done.iter().find(|c| c.preemptions > 0);
    assert!(victim.is_some(), "some completion must record evictions");
}

#[test]
fn tokens_generated_counts_only_delivered_tokens() {
    // regression: tokens later discarded by recompute preemption used
    // to stay in `tokens_generated` and then be counted AGAIN when
    // re-generated, inflating the throughput figures the experiments
    // read. Force one preemption and check the counter equals the sum
    // of the delivered completion lengths exactly.
    let rt = runtime();
    let mut cfg = EngineConfig::new("dense", "bf16");
    cfg.kv_budget_bytes = Some(Bytes::new(3 * 4096)); // 3 blocks = 48 tokens
    let mut engine = HloEngine::new(rt, cfg).unwrap();
    let reqs: Vec<Request> = (0..2)
        .map(|i| Request {
            id: i,
            prompt: vec![12, i as i32, 10, 3, 11],
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 32,
                eos: -1, // never matches: force long generations
                ..Default::default()
            },
        })
        .collect();
    let done = engine.generate(reqs).unwrap();
    assert!(engine.stats.preemptions >= 1, "scenario must preempt");
    let delivered: usize = done.iter().map(|c| c.tokens.len()).sum();
    assert_eq!(
        engine.stats.tokens_generated, delivered as u64,
        "tokens_generated must count only delivered tokens"
    );
    assert!(
        engine.stats.tokens_discarded > 0,
        "preempted work must show up as discarded"
    );
}

#[test]
fn generate_error_drains_scheduler_state() {
    // regression: when `generate` bailed on an unadmittable request,
    // the other submitted requests stayed queued in the scheduler, so
    // the NEXT generate call silently re-ran ghost requests — or
    // stalled forever on the same stuck head-of-line request
    let rt = runtime();
    let mut cfg = EngineConfig::new("dense", "bf16");
    cfg.kv_budget_bytes = Some(Bytes::new(4096)); // 1 block of 16 tokens
    let mut engine = HloEngine::new(rt, cfg).unwrap();
    let stuck = Request {
        id: 1,
        // 16-token prompt + growth reserve needs 2 blocks: never fits
        prompt: vec![1; 16],
        params: SamplingParams::default(),
    };
    let companion = Request {
        id: 2,
        prompt: vec![12, 2, 10, 3, 11],
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: 4,
            ..Default::default()
        },
    };
    assert!(engine.generate(vec![stuck, companion]).is_err());
    // the failed call must leave nothing behind: this call must see
    // exactly its own request, not ghost re-runs of the stall batch
    let fresh = Request {
        id: 3,
        prompt: vec![12, 4, 10, 5, 11],
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: 4,
            ..Default::default()
        },
    };
    let done = engine.generate(vec![fresh]).unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 3);
}

#[test]
fn decode_keeps_kv_cache_device_resident() {
    // the device-resident threading contract: per-decode-step host
    // traffic is the (B,1) token/pos uploads plus the (B,V) logits
    // download — independent of (and far below) the KV cache size
    let rt = runtime();
    let mut engine =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "bf16"))
            .unwrap();
    let reqs: Vec<Request> = (0..4)
        .map(|i| Request {
            id: i,
            prompt: vec![12, i as i32, 10, 3, 11],
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 8,
                eos: -1, // keep every slot decoding for the full run
                ..Default::default()
            },
        })
        .collect();
    let done = engine.generate(reqs).unwrap();
    assert_eq!(done.len(), 4);
    assert!(engine.stats.decode_steps > 0);
    let m = rt.manifest.model("dense").unwrap();
    let c = &rt.manifest.constants;
    let cache_bytes = 2 // k and v
        * m.cfg("n_layers")
        * c.b_rollout
        * m.cfg("n_kv_heads")
        * m.cfg("max_seq")
        * m.cfg("d_head")
        * 4;
    let step = engine.stats.host_bytes_last_step as usize;
    let step_bound =
        c.b_rollout * m.cfg("vocab") * 4 + 2 * c.b_rollout * 4;
    assert!(
        step <= step_bound,
        "decode step moved {step} host bytes, want <= {step_bound} \
         (O(B·V) logits + O(B) tokens/pos)"
    );
    assert!(
        step < cache_bytes,
        "per-step host traffic {step} must be far below the dense \
         cache size {cache_bytes}"
    );
}

#[test]
fn fp8_rollout_diverges_but_tis_sees_it() {
    // the paper's core mechanism: pi_fp8 != pi_theta, measured by the
    // trainer's logprobs on the engine's sampled tokens
    let rt = runtime();
    let mut engine =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "fp8lin"))
            .unwrap();
    let trainer =
        Trainer::new(rt.clone(), TrainerConfig::new("dense", "bf16"))
            .unwrap();
    let spec = rt.manifest.model("dense").unwrap().clone();
    let sync = WeightSync::new(WeightSyncConfig::fp8());
    let (w, rep) = sync.run(&spec, trainer.params()).unwrap();
    assert!(rep.n_quantized > 0);
    assert!(rep.bytes_fp8 < rep.bytes_bf16);
    engine.install_weights(&w).unwrap();

    let done = engine.generate(requests(8, 6, 1.0)).unwrap();
    let problem = make_problem(2, 3);
    let mut samples: Vec<Sample> = done
        .into_iter()
        .map(|completion| Sample {
            problem: problem.clone(),
            completion,
            reward: 0.0,
            group: 0,
        })
        .collect();
    score(&mut samples);
    let c = rt.manifest.constants.clone();
    let batch =
        TrainBatch::assemble(&samples, c.b_train, c.t_train, 1e-4, true);
    let mut trainer = trainer;
    let metrics = trainer.train_step(&batch).unwrap();
    let kl = metrics.get("kl_k3");
    assert!(kl.is_finite());
    assert!(kl >= 0.0, "k3 estimator is non-negative, got {kl}");
    // FP8 rollout vs f32 trainer must show *some* mismatch
    assert!(kl > 1e-8, "fp8 mismatch KL suspiciously zero: {kl}");
    // TIS weights are clipped at C=2
    assert!(metrics.get("tis_mean") <= 2.0 + 1e-5);
}

#[test]
fn train_step_learns_on_fixed_batch() {
    // repeating the same advantage-weighted batch must increase the
    // selected tokens' likelihood
    let rt = runtime();
    let mut trainer = Trainer::new(
        rt.clone(),
        TrainerConfig {
            lr: 1e-2,
            ..TrainerConfig::new("dense", "bf16")
        },
    )
    .unwrap();
    let problem = make_problem(2, 3);
    let c = rt.manifest.constants.clone();
    let completion = fp8_rl::rollout::Completion {
        id: 0,
        prompt: problem.prompt.clone(),
        tokens: problem.answer.clone(),
        logprobs: vec![-1.0; problem.answer.len()],
        logprobs_full: vec![-1.0; problem.answer.len()],
        finish: FinishReason::Eos,
        preemptions: 0,
        epoch: 0,
    };
    let bad = fp8_rl::rollout::Completion {
        tokens: vec![9, 9, 13],
        logprobs: vec![-1.0; 3],
        ..completion.clone()
    };
    let samples = vec![
        Sample {
            problem: problem.clone(),
            completion,
            reward: 1.0,
            group: 0,
        },
        Sample {
            problem: problem.clone(),
            completion: bad,
            reward: 0.0,
            group: 0,
        },
    ];
    let batch =
        TrainBatch::assemble(&samples, c.b_train, c.t_train, 1e-4, false);
    let (lp0, _) = trainer.eval_logprobs(&batch.tokens).unwrap();
    for _ in 0..8 {
        let m = trainer.train_step(&batch).unwrap();
        assert!(m.get("loss").is_finite());
        assert!(m.get("grad_norm") > 0.0);
    }
    assert_eq!(trainer.step_count(), 8.0);
    let (lp1, _) = trainer.eval_logprobs(&batch.tokens).unwrap();
    let plen = problem.prompt.len();
    let before: f32 =
        (0..problem.answer.len()).map(|k| lp0[plen - 1 + k]).sum();
    let after: f32 =
        (0..problem.answer.len()).map(|k| lp1[plen - 1 + k]).sum();
    assert!(
        after > before,
        "good answer logprob should rise: {before} -> {after}"
    );
}

#[test]
fn calibration_strategies_roughly_agree() {
    // both Fig-7 strategies calibrate against the same policy; on
    // similar data their scales should land within 2x of each other
    let rt = runtime();
    let trainer =
        Trainer::new(rt.clone(), TrainerConfig::new("dense", "bf16"))
            .unwrap();
    let inf_rows: Vec<Vec<i32>> =
        (0..8).map(|i| vec![12, i, 10, 9 - i, 11]).collect();
    let trn_rows: Vec<Vec<i32>> =
        (0..8).map(|i| vec![12, 9 - i, 10, i, 11, i, 13]).collect();
    let inf = Calibrator::new(
        rt.clone(),
        "dense",
        CalibStrategy::InferenceSide,
    )
    .unwrap();
    let trn =
        Calibrator::new(rt.clone(), "dense", CalibStrategy::TrainerSide)
            .unwrap();
    let (k1, v1) =
        inf.recalibrate(trainer.params(), &inf_rows, 14).unwrap();
    let (k2, v2) =
        trn.recalibrate(trainer.params(), &trn_rows, 14).unwrap();
    assert!(k1 > 0.0 && v1 > 0.0);
    assert!((k1 / k2) < 2.0 && (k2 / k1) < 2.0);
    assert!((v1 / v2) < 2.0 && (v2 / v1) < 2.0);
}

#[test]
fn kv_scales_affect_fp8_kv_decode_only() {
    // installing absurd KV scales must change fp8-kv generation (the
    // scales are live) — and restoring them must restore the output
    let rt = runtime();
    let mut engine =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "kvfp8"))
            .unwrap();
    let good = engine.generate(requests(2, 6, 0.0)).unwrap();
    engine.install_kv_scales(1e-6, 1e-6); // catastrophic clipping
    let bad = engine.generate(requests(2, 6, 0.0)).unwrap();
    engine.install_kv_scales(1.0, 1.0);
    let restored = engine.generate(requests(2, 6, 0.0)).unwrap();
    for (a, b) in good.iter().zip(&restored) {
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.logprobs, b.logprobs);
        assert_eq!(a.logprobs_full, b.logprobs_full);
    }
    let changed = good.iter().zip(&bad).any(|(a, b)| {
        a.tokens != b.tokens || a.logprobs_full != b.logprobs_full
    });
    assert!(changed, "kv scales appear dead");
}

#[test]
fn rl_loop_end_to_end_hermetic() {
    // the acceptance path: RlLoop::step drives engine generate ->
    // weight-sync quantize/install -> KV-scale recalibration ->
    // train_step, fully offline on the RefBackend
    let rt = runtime();
    let mut cfg =
        ExperimentConfig::new("hermetic_e2e", "dense", "fullfp8", "bf16");
    cfg.steps = 2;
    cfg.prompts_per_step = 4;
    cfg.samples_per_prompt = 4; // 16 rows == b_train
    cfg.max_digits = 1;
    cfg.max_sum = Some(9);
    cfg.max_new_tokens = 4;
    cfg.validate_every = 1;
    let mut rl = RlLoop::new(rt, cfg).unwrap();
    for step in 0..2 {
        let rec = rl.step(step).unwrap();
        // metric extraction
        let reward = rec.get("reward");
        assert!((0.0..=1.0).contains(&reward), "reward {reward}");
        assert!(rec.get("response_len") > 0.0, "no completions assembled");
        let kl = rec.get("mismatch_kl");
        assert!(kl.is_finite() && kl >= 0.0, "mismatch_kl {kl}");
        assert!(rec.get("loss").is_finite());
        assert!(rec.get("entropy").is_finite());
        let acc = rec.get("val_accuracy");
        assert!((0.0..=1.0).contains(&acc), "val_accuracy {acc}");
        // preemption accounting is extracted every step (zero under an
        // unconstrained KV budget)
        assert_eq!(rec.get("preemptions"), 0.0);
        rl.recorder.push(rec);
    }
    let stats = rl.engine_stats().unwrap();
    assert!(stats.tokens_generated > 0);
    assert!(stats.prefill_waves >= 1);
    assert!(stats.decode_steps >= 1);
    assert_eq!(rl.recorder.steps.len(), 2);
    assert!(rl.recorder.tail_mean("reward", 2).is_finite());
}

#[test]
fn rl_loop_on_engine_pool_matches_single_engine() {
    // the serving topology is a pure throughput knob: the SAME
    // experiment run on 1 in-process engine and on a 2-replica
    // thread-per-replica pool must produce identical training metrics
    // (bit-identical rollouts -> identical batches -> identical step)
    let mk_cfg = |name: &str, replicas: usize| {
        let mut cfg =
            ExperimentConfig::new(name, "dense", "fullfp8", "bf16");
        cfg.steps = 2;
        cfg.prompts_per_step = 4;
        cfg.samples_per_prompt = 4; // 16 rows == b_train
        cfg.max_digits = 1;
        cfg.max_sum = Some(9);
        cfg.max_new_tokens = 4;
        cfg.validate_every = 1;
        cfg.rollout_replicas = replicas;
        cfg
    };
    let mut single = RlLoop::new(runtime(), mk_cfg("pool_ref", 1)).unwrap();
    let mut pooled = RlLoop::new(runtime(), mk_cfg("pool_2x", 2)).unwrap();
    // continuous streaming admission + epoch-fenced sync: the SAME
    // metrics again — streaming is a latency/throughput knob only
    let mut streaming = {
        let mut cfg = mk_cfg("pool_stream", 2);
        cfg.rollout_streaming = true;
        RlLoop::new(runtime(), cfg).unwrap()
    };
    for step in 0..2 {
        let a = single.step(step).unwrap();
        let b = pooled.step(step).unwrap();
        let c = streaming.step(step).unwrap();
        assert_eq!(b.get("rollout_replicas"), 2.0);
        assert_eq!(c.get("rollout_streaming"), 1.0);
        // fullfp8 installs weights AND kv scales each step: 2 epochs
        // per step, identically across topologies
        assert_eq!(a.get("rollout_epoch"), (2 * (step + 1)) as f64);
        assert_eq!(b.get("rollout_epoch"), a.get("rollout_epoch"));
        assert_eq!(c.get("rollout_epoch"), a.get("rollout_epoch"));
        for key in [
            "reward",
            "response_len",
            "loss",
            "mismatch_kl",
            "entropy",
            "tis_mean",
            "val_accuracy",
            "rollout_tokens",
        ] {
            let (x, y, z) =
                (a.get(key), b.get(key), c.get(key));
            assert!(
                x == y || (x.is_nan() && y.is_nan()),
                "step {step} {key}: single {x} vs pool {y}"
            );
            assert!(
                x == z || (x.is_nan() && z.is_nan()),
                "step {step} {key}: single {x} vs streaming {z}"
            );
        }
    }
    let s = single.engine_stats().unwrap();
    let p = pooled.engine_stats().unwrap();
    let t = streaming.engine_stats().unwrap();
    assert_eq!(s.tokens_generated, p.tokens_generated);
    assert_eq!(s.tokens_generated, t.tokens_generated);
}

#[test]
fn rl_loop_pipelined_trains_with_bounded_staleness() {
    // the cross-step pipelining acceptance path: pipeline_depth=1 with
    // max_epoch_staleness=1 trains end to end, every batch's
    // completion epochs sit inside the allowed window, and the TIS
    // denominators are attributable to exactly the epoch the tokens
    // were sampled under (the trainer's behavior_epoch_min/max
    // provenance metrics pin it per step)
    let mut cfg = ExperimentConfig::new(
        "pipelined_e2e",
        "dense",
        "fp8lin", // weights-only sync: exactly 1 epoch per step
        "bf16",
    );
    cfg.steps = 4;
    cfg.prompts_per_step = 4;
    cfg.samples_per_prompt = 4; // 16 rows == b_train
    cfg.max_digits = 1;
    cfg.max_sum = Some(9);
    cfg.max_new_tokens = 4;
    cfg.validate_every = 1;
    cfg.rollout_replicas = 2;
    cfg.rollout_streaming = true;
    cfg.pipeline_depth = 1;
    cfg.max_epoch_staleness = 1;
    let mut rl = RlLoop::new(runtime(), cfg).unwrap();
    for step in 0..4 {
        let rec = rl.step(step).unwrap();
        assert_eq!(rec.get("pipeline_depth"), 1.0);
        assert!(rec.get("pipeline_overlap_s") >= 0.0);
        // one weight fence per step: the synced epoch is step+1
        assert_eq!(rec.get("rollout_epoch"), (step + 1) as f64);
        // step 0 consumes the prologue wave (submitted after step 0's
        // own fence: staleness 0); every later step trains on the wave
        // submitted one step — one epoch — earlier
        let want_stale = if step == 0 { 0.0 } else { 1.0 };
        assert_eq!(
            rec.get("staleness_mean"),
            want_stale,
            "step {step}: wrong staleness"
        );
        // per-epoch-correct TIS denominators: every row of the batch
        // came from ONE behavior epoch, exactly `staleness` behind the
        // synced epoch and inside the allowed window
        let emin = rec.get("behavior_epoch_min");
        let emax = rec.get("behavior_epoch_max");
        assert_eq!(
            emin, emax,
            "step {step}: one wave must mean one behavior epoch"
        );
        assert_eq!(emax, rec.get("rollout_epoch") - want_stale);
        // training actually ran on the stale-but-corrected batch
        let reward = rec.get("reward");
        assert!((0.0..=1.0).contains(&reward), "reward {reward}");
        assert!(rec.get("response_len") > 0.0);
        assert!(rec.get("loss").is_finite());
        assert!(rec.get("rollout_tokens") > 0.0);
        let acc = rec.get("val_accuracy");
        assert!((0.0..=1.0).contains(&acc), "val_accuracy {acc}");
        rl.recorder.push(rec);
    }
    let stats = rl.engine_stats().unwrap();
    assert!(stats.tokens_generated > 0);
    assert_eq!(rl.recorder.steps.len(), 4);
}

#[test]
fn pipelining_requires_streaming_and_a_wide_enough_window() {
    // misconfigurations must fail at construction with a diagnostic,
    // not at step d+1 with a confusing epoch error
    let mut cfg =
        ExperimentConfig::new("pipe_bad1", "dense", "bf16", "bf16");
    cfg.pipeline_depth = 1;
    cfg.max_epoch_staleness = 1;
    let err = match RlLoop::new(runtime(), cfg) {
        Ok(_) => panic!("pipelining without streaming must be rejected"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("rollout_streaming"), "{err}");

    let mut cfg =
        ExperimentConfig::new("pipe_bad2", "dense", "fullfp8", "bf16");
    cfg.rollout_streaming = true;
    cfg.pipeline_depth = 1;
    // fullfp8 bumps TWO epochs per step (weights + kv scales): a
    // window of 1 would reject every steady-state batch
    cfg.max_epoch_staleness = 1;
    let err = match RlLoop::new(runtime(), cfg) {
        Ok(_) => panic!("a too-narrow staleness window must be rejected"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("max_epoch_staleness"), "{err}");
}

#[test]
fn widening_the_staleness_window_alone_changes_nothing() {
    // the determinism anchor, window edition: at pipeline_depth 0 the
    // bounded-staleness check is a pure relaxation — a wider window
    // over the same sequential schedule must leave every metric
    // bit-identical (staleness stays 0; nothing is ever stale)
    let mk = |name: &str, staleness: u64| {
        let mut cfg =
            ExperimentConfig::new(name, "dense", "fullfp8", "bf16");
        cfg.steps = 2;
        cfg.prompts_per_step = 4;
        cfg.samples_per_prompt = 4;
        cfg.max_digits = 1;
        cfg.max_sum = Some(9);
        cfg.max_new_tokens = 4;
        cfg.validate_every = 1;
        cfg.rollout_streaming = true;
        cfg.max_epoch_staleness = staleness;
        cfg
    };
    let mut tight = RlLoop::new(runtime(), mk("win_0", 0)).unwrap();
    let mut wide = RlLoop::new(runtime(), mk("win_3", 3)).unwrap();
    for step in 0..2 {
        let a = tight.step(step).unwrap();
        let b = wide.step(step).unwrap();
        assert_eq!(a.get("staleness_mean"), 0.0);
        assert_eq!(b.get("staleness_mean"), 0.0);
        for key in [
            "reward",
            "response_len",
            "loss",
            "mismatch_kl",
            "entropy",
            "tis_mean",
            "val_accuracy",
            "rollout_tokens",
            "rollout_epoch",
        ] {
            let (x, y) = (a.get(key), b.get(key));
            assert!(
                x == y || (x.is_nan() && y.is_nan()),
                "step {step} {key}: tight {x} vs wide {y}"
            );
        }
    }
}

#[test]
fn trainer_side_calibration_first_step_falls_back_to_prompts() {
    // step 0 has no training rows yet: TrainerSide calibration must
    // fall back to the upcoming prompts (and step 1 then calibrates
    // on the recorded training batch) — both branches execute here
    let rt = runtime();
    let mut cfg = ExperimentConfig::new(
        "trainer_side_fallback",
        "dense",
        "kvfp8",
        "bf16",
    );
    cfg.calib = CalibStrategy::TrainerSide;
    cfg.steps = 2;
    cfg.prompts_per_step = 4;
    cfg.samples_per_prompt = 4;
    cfg.max_digits = 1;
    cfg.max_sum = Some(9);
    cfg.max_new_tokens = 4;
    cfg.validate_every = 1;
    let mut rl = RlLoop::new(rt, cfg).unwrap();
    for step in 0..2 {
        let rec = rl.step(step).unwrap();
        assert!(rec.get("loss").is_finite(), "step {step}");
        assert!(rec.get("mismatch_kl").is_finite(), "step {step}");
        // kvfp8 installs weights AND recalibrated scales every step
        assert_eq!(rec.get("rollout_epoch"), (2 * (step + 1)) as f64);
    }
}

#[test]
fn rl_loop_runs_moe_arch_too() {
    let rt = runtime();
    let mut cfg =
        ExperimentConfig::new("hermetic_moe", "moe", "fp8lin", "bf16");
    cfg.steps = 1;
    cfg.prompts_per_step = 4;
    cfg.samples_per_prompt = 4;
    cfg.max_digits = 1;
    cfg.max_sum = Some(9);
    cfg.max_new_tokens = 3;
    cfg.validate_every = 1;
    let mut rl = RlLoop::new(rt, cfg).unwrap();
    let rec = rl.step(0).unwrap();
    assert!(rec.get("loss").is_finite());
    assert!(rec.get("mismatch_kl").is_finite());
}

#[test]
fn task_end_to_end_reward_shapes() {
    let mut task = Task::new(TaskConfig {
        max_digits: 1,
        max_sum: Some(9),
        n_validation: 16,
        seed: 5,
    });
    for _ in 0..50 {
        let p = task.sample();
        assert!(p.a + p.b <= 9);
        assert_eq!(Task::reward(&p, &p.answer), 1.0);
        assert!(
            Task::reward(&p, &[((p.a + p.b + 1) % 10) as i32, 13]) < 0.5
        );
    }
}

#[test]
fn config_file_roundtrip() {
    // the JSON config system (no artifacts needed)
    let dir = std::env::temp_dir().join("fp8rl_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");
    std::fs::write(
        &path,
        r#"{"name": "x", "arch": "moe", "rollout_variant": "fp8lin",
            "tis_c": 3.5, "mis": true, "steps": 7, "max_sum": 9,
            "scale_fmt": "ue8m0", "calib": "trainer"}"#,
    )
    .unwrap();
    let cfg = fp8_rl::coordinator::ExperimentConfig::from_json_file(
        path.to_str().unwrap(),
    )
    .unwrap();
    assert_eq!(cfg.arch, "moe");
    assert_eq!(cfg.rollout_variant, "fp8lin");
    assert_eq!(cfg.tis_c, 3.5);
    assert!(cfg.mis);
    assert_eq!(cfg.steps, 7);
    assert_eq!(cfg.max_sum, Some(9));
    assert_eq!(cfg.scale_fmt, fp8_rl::fp8::ScaleFormat::Ue8m0);
    assert_eq!(cfg.calib, fp8_rl::sync::CalibStrategy::TrainerSide);
    std::fs::remove_dir_all(dir).ok();
}
