//! Integration tests over the runtime + engine + trainer + sync stack.
//! These need `make artifacts`; they are skipped (with a note) if the
//! artifacts directory is missing so unit CI can run without Python.
//!
//! Heavyweight by unit-test standards (each compiles XLA executables) —
//! they share one global Runtime to compile each artifact exactly once.

use std::cell::RefCell;
use std::sync::Arc;

use fp8_rl::rl::dapo::{score, Sample, TrainBatch};
use fp8_rl::rl::task::{make_problem, Task, TaskConfig};
use fp8_rl::rl::trainer::{Trainer, TrainerConfig};
use fp8_rl::rollout::{
    EngineConfig, HloEngine, Request, SamplingParams,
};
use fp8_rl::runtime::Runtime;
use fp8_rl::sync::{
    CalibStrategy, Calibrator, WeightSync, WeightSyncConfig,
};

// xla's PjRtClient is Rc-based (!Send), so the shared Runtime lives in
// TLS. Run `cargo test -- --test-threads=1` (the Makefile does) so all
// tests share one compile cache.
thread_local! {
    static RT: RefCell<Option<Option<Arc<Runtime>>>> =
        const { RefCell::new(None) };
}

fn runtime() -> Option<Arc<Runtime>> {
    RT.with(|cell| {
        cell.borrow_mut()
            .get_or_insert_with(|| {
                if !std::path::Path::new("artifacts/manifest.json")
                    .exists()
                {
                    eprintln!(
                        "integration tests skipped: run `make artifacts`"
                    );
                    return None;
                }
                Some(Arc::new(Runtime::new("artifacts").unwrap()))
            })
            .clone()
    })
}

fn requests(n: u64, max_new: usize, temp: f32) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i,
            prompt: vec![12, (i % 10) as i32, 10, ((i + 3) % 10) as i32, 11],
            params: SamplingParams {
                temperature: temp,
                max_new_tokens: max_new,
                ..Default::default()
            },
        })
        .collect()
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    assert!(m.entrypoints.len() >= 30);
    for arch in ["dense", "moe"] {
        let spec = m.model(arch).unwrap();
        assert!(spec.total_weights() > 100_000);
        let params = m.load_initial_params(arch).unwrap();
        assert_eq!(params.len(), spec.params.len());
        // every kind exists for every arch
        for kind in ["prefill", "decode", "train", "logprobs", "calibrate"] {
            assert!(
                m.entrypoints
                    .values()
                    .any(|e| e.arch == arch && e.kind == kind),
                "{arch} missing {kind}"
            );
        }
    }
}

#[test]
fn engine_greedy_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let mut e1 =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "bf16"))
            .unwrap();
    let mut e2 =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "bf16"))
            .unwrap();
    let a = e1.generate(requests(4, 6, 0.0)).unwrap();
    let b = e2.generate(requests(4, 6, 0.0)).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.tokens, y.tokens, "greedy decode must be stable");
    }
}

#[test]
fn prefill_wave_matches_decode_prefill() {
    // the batched-prefill fast path and the chunked (decode-path)
    // prefill must produce the same greedy continuation
    let Some(rt) = runtime() else { return };
    let mut engine =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "bf16"))
            .unwrap();
    // wave path: submit while engine is empty
    let wave = engine.generate(requests(3, 5, 0.0)).unwrap();
    // chunked path: occupy a slot first so the wave fast path is skipped
    // for the later arrivals (they admit via decode-prefill)
    let mut mixed_reqs = requests(3, 5, 0.0);
    mixed_reqs.insert(
        0,
        Request {
            id: 99,
            prompt: vec![12, 1, 10, 1, 11],
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 12,
                ..Default::default()
            },
        },
    );
    let mixed = engine.generate(mixed_reqs).unwrap();
    for c in &wave {
        let m = mixed.iter().find(|x| x.id == c.id).unwrap();
        assert_eq!(
            c.tokens, m.tokens,
            "req {}: wave {:?} vs chunked {:?}",
            c.id, c.tokens, m.tokens
        );
    }
}

#[test]
fn fp8_rollout_diverges_but_tis_sees_it() {
    // the paper's core mechanism: pi_fp8 != pi_theta, measured by the
    // trainer's logprobs on the engine's sampled tokens
    let Some(rt) = runtime() else { return };
    let mut engine =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "fp8lin"))
            .unwrap();
    let trainer =
        Trainer::new(rt.clone(), TrainerConfig::new("dense", "bf16"))
            .unwrap();
    let spec = rt.manifest.model("dense").unwrap().clone();
    let sync = WeightSync::new(WeightSyncConfig::fp8());
    let (w, rep) = sync.run(&spec, trainer.params()).unwrap();
    assert!(rep.n_quantized > 0);
    assert!(rep.bytes_fp8 < rep.bytes_bf16);
    engine.install_weights(&w).unwrap();

    let done = engine.generate(requests(8, 6, 1.0)).unwrap();
    let problem = make_problem(2, 3);
    let mut samples: Vec<Sample> = done
        .into_iter()
        .map(|completion| Sample {
            problem: problem.clone(),
            completion,
            reward: 0.0,
            group: 0,
        })
        .collect();
    score(&mut samples);
    let c = rt.manifest.constants.clone();
    let batch =
        TrainBatch::assemble(&samples, c.b_train, c.t_train, 1e-4, true);
    let mut trainer = trainer;
    let metrics = trainer.train_step(&batch).unwrap();
    let kl = metrics.get("kl_k3");
    assert!(kl.is_finite());
    assert!(kl >= 0.0, "k3 estimator is non-negative, got {kl}");
    // FP8 rollout vs f32 trainer must show *some* mismatch
    assert!(kl > 1e-8, "fp8 mismatch KL suspiciously zero: {kl}");
    // TIS weights are clipped at C=2
    assert!(metrics.get("tis_mean") <= 2.0 + 1e-5);
}

#[test]
fn train_step_learns_on_fixed_batch() {
    // repeating the same advantage-weighted batch must increase the
    // selected tokens' likelihood => loss (negative objective) decreases
    let Some(rt) = runtime() else { return };
    let mut trainer =
        Trainer::new(rt.clone(), TrainerConfig::new("dense", "bf16"))
            .unwrap();
    let problem = make_problem(2, 3);
    let c = rt.manifest.constants.clone();
    // a hand-built "good" sample: the correct answer, positive advantage
    let completion = fp8_rl::rollout::Completion {
        id: 0,
        prompt: problem.prompt.clone(),
        tokens: problem.answer.clone(),
        logprobs: vec![-1.0; problem.answer.len()],
        finish: fp8_rl::rollout::FinishReason::Eos,
        preemptions: 0,
    };
    let bad = fp8_rl::rollout::Completion {
        tokens: vec![9, 9, 13],
        logprobs: vec![-1.0; 3],
        ..completion.clone()
    };
    let samples = vec![
        Sample {
            problem: problem.clone(),
            completion,
            reward: 1.0,
            group: 0,
        },
        Sample {
            problem: problem.clone(),
            completion: bad,
            reward: 0.0,
            group: 0,
        },
    ];
    let batch =
        TrainBatch::assemble(&samples, c.b_train, c.t_train, 1e-4, false);
    let (lp0, _) = trainer.eval_logprobs(&batch.tokens).unwrap();
    for _ in 0..8 {
        trainer.train_step(&batch).unwrap();
    }
    let (lp1, _) = trainer.eval_logprobs(&batch.tokens).unwrap();
    // the good row's response tokens must have gained probability
    let plen = problem.prompt.len();
    let t = c.t_train;
    let before: f32 =
        (0..problem.answer.len()).map(|k| lp0[plen - 1 + k]).sum();
    let after: f32 =
        (0..problem.answer.len()).map(|k| lp1[plen - 1 + k]).sum();
    assert!(
        after > before,
        "good answer logprob should rise: {before} -> {after} (T={t})"
    );
}

#[test]
fn calibration_strategies_roughly_agree() {
    // both Fig-7 strategies calibrate against the same policy; on
    // similar data their scales should land within 2x of each other
    let Some(rt) = runtime() else { return };
    let trainer =
        Trainer::new(rt.clone(), TrainerConfig::new("dense", "bf16"))
            .unwrap();
    let rows: Vec<Vec<i32>> =
        (0..8).map(|i| vec![12, i, 10, (9 - i), 11]).collect();
    let inf = Calibrator::new(
        rt.clone(),
        "dense",
        CalibStrategy::InferenceSide,
    )
    .unwrap();
    let trn =
        Calibrator::new(rt.clone(), "dense", CalibStrategy::TrainerSide)
            .unwrap();
    let (k1, v1) = inf.recalibrate(trainer.params(), &rows, 14).unwrap();
    let (k2, v2) = trn.recalibrate(trainer.params(), &rows, 14).unwrap();
    assert!(k1 > 0.0 && v1 > 0.0);
    assert!((k1 / k2) < 2.0 && (k2 / k1) < 2.0);
    assert!((v1 / v2) < 2.0 && (v2 / v1) < 2.0);
}

#[test]
fn kv_scales_affect_fp8_kv_decode_only() {
    // installing absurd KV scales must change fp8-kv generation (the
    // scales are live) — and a sane recalibration must restore sanity
    let Some(rt) = runtime() else { return };
    let mut engine =
        HloEngine::new(rt.clone(), EngineConfig::new("dense", "kvfp8"))
            .unwrap();
    let good = engine.generate(requests(2, 6, 0.0)).unwrap();
    engine.install_kv_scales(1e-6, 1e-6); // catastrophic clipping
    let bad = engine.generate(requests(2, 6, 0.0)).unwrap();
    engine.install_kv_scales(1.0, 1.0);
    let restored = engine.generate(requests(2, 6, 0.0)).unwrap();
    // restored == first run (scales were 1.0 by default)
    for (a, b) in good.iter().zip(&restored) {
        assert_eq!(a.tokens, b.tokens);
    }
    // catastrophic scales change *something*
    let changed = good
        .iter()
        .zip(&bad)
        .any(|(a, b)| a.tokens != b.tokens);
    assert!(changed, "kv scales appear dead");
}

#[test]
fn task_end_to_end_reward_shapes() {
    let mut task = Task::new(TaskConfig {
        max_digits: 1,
        max_sum: Some(9),
        n_validation: 16,
        seed: 5,
    });
    for _ in 0..50 {
        let p = task.sample();
        assert!(p.a + p.b <= 9);
        assert_eq!(Task::reward(&p, &p.answer), 1.0);
        assert!(Task::reward(&p, &[((p.a + p.b + 1) % 10) as i32, 13]) < 0.5);
    }
}

#[test]
fn config_file_roundtrip() {
    // the JSON config system (no artifacts needed)
    let dir = std::env::temp_dir().join("fp8rl_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");
    std::fs::write(
        &path,
        r#"{"name": "x", "arch": "moe", "rollout_variant": "fp8lin",
            "tis_c": 3.5, "mis": true, "steps": 7, "max_sum": 9,
            "scale_fmt": "ue8m0", "calib": "trainer"}"#,
    )
    .unwrap();
    let cfg = fp8_rl::coordinator::ExperimentConfig::from_json_file(
        path.to_str().unwrap(),
    )
    .unwrap();
    assert_eq!(cfg.arch, "moe");
    assert_eq!(cfg.rollout_variant, "fp8lin");
    assert_eq!(cfg.tis_c, 3.5);
    assert!(cfg.mis);
    assert_eq!(cfg.steps, 7);
    assert_eq!(cfg.max_sum, Some(9));
    assert_eq!(cfg.scale_fmt, fp8_rl::fp8::ScaleFormat::Ue8m0);
    assert_eq!(
        cfg.calib,
        fp8_rl::sync::CalibStrategy::TrainerSide
    );
    std::fs::remove_dir_all(dir).ok();
}
