//! Prefix-sharing integration tests (hermetic: synthetic manifest +
//! RefBackend).
//!
//! The contract: turning `prefix_sharing` on is a pure cost
//! optimization. A GRPO-style group of G completions over one prompt
//! pays (approximately) one prefill and shares its prompt KV blocks
//! copy-on-write — and every completion's tokens, behavior logprobs,
//! full-vocab logprobs, and finish reason are BIT-IDENTICAL to the
//! unshared run. Sampling uses a per-request RNG stream
//! (`slot_rng(req_id)`), so skipping prefill steps cannot shift any
//! random draw; KV rows are pure functions of (token prefix, weights,
//! scales), so aliasing a device-resident row is exact.

use std::collections::BTreeMap;
use std::sync::Arc;

use fp8_rl::rollout::{
    Completion, EngineConfig, HloEngine, Request, SamplingParams,
};
use fp8_rl::runtime::Runtime;

/// 2 groups x 16 members each; members of a group share a 5-token
/// prompt. `max_new_tokens` is staggered inside each group so members
/// finish on different steps and readmission flows through the chunked
/// (row-aliasing) path rather than a fresh wave.
fn grouped_requests() -> Vec<Request> {
    let mut reqs = Vec::new();
    let mut id = 1u64;
    for g in 0..2i32 {
        let prompt = vec![12, g, 10, g, 11];
        for m in 0..16usize {
            reqs.push(Request {
                id,
                prompt: prompt.clone(),
                params: SamplingParams {
                    temperature: 1.0,
                    max_new_tokens: 6 + m % 3,
                    ..Default::default()
                },
            });
            id += 1;
        }
    }
    reqs
}

struct RunOut {
    completions: BTreeMap<u64, Completion>,
    prefill_tokens_saved: u64,
    kv_bytes_shared: u64,
}

fn run_grouped(variant: &str, sharing: bool) -> RunOut {
    let mut cfg = EngineConfig::new("dense", variant);
    cfg.prefix_sharing = sharing;
    let mut engine =
        HloEngine::new(Arc::new(Runtime::hermetic()), cfg).unwrap();
    let done = engine.generate(grouped_requests()).unwrap();
    RunOut {
        completions: done.into_iter().map(|c| (c.id, c)).collect(),
        prefill_tokens_saved: engine.stats.prefill_tokens_saved,
        kv_bytes_shared: engine.stats.kv_bytes_shared,
    }
}

fn assert_bit_identical(
    shared: &BTreeMap<u64, Completion>,
    plain: &BTreeMap<u64, Completion>,
    what: &str,
) {
    assert_eq!(
        shared.len(),
        plain.len(),
        "{what}: completion counts diverge"
    );
    for (id, s) in shared {
        let p = plain.get(id).unwrap_or_else(|| {
            panic!("{what}: unshared run never completed request {id}")
        });
        assert_eq!(s.tokens, p.tokens, "{what}: tokens diverge, id {id}");
        assert_eq!(
            s.logprobs, p.logprobs,
            "{what}: behavior logprobs diverge, id {id}"
        );
        assert_eq!(
            s.logprobs_full, p.logprobs_full,
            "{what}: full-vocab logprobs diverge, id {id}"
        );
        assert_eq!(
            s.finish, p.finish,
            "{what}: finish reason diverges, id {id}"
        );
    }
}

#[test]
fn grouped_generate_bit_identical_and_cheaper() {
    for variant in ["bf16", "kvfp8"] {
        let shared = run_grouped(variant, true);
        let plain = run_grouped(variant, false);
        assert_bit_identical(
            &shared.completions,
            &plain.completions,
            variant,
        );
        // the group structure must actually be exploited...
        assert!(
            shared.prefill_tokens_saved > 0,
            "{variant}: sharing saved no prefill tokens"
        );
        assert!(
            shared.kv_bytes_shared > 0,
            "{variant}: sharing shared no KV bytes"
        );
        // ...and the knob must be inert when off
        assert_eq!(plain.prefill_tokens_saved, 0, "{variant}");
        assert_eq!(plain.kv_bytes_shared, 0, "{variant}");
    }
}

#[test]
fn step_schedule_aliases_resident_prefix_deterministically() {
    // a fully deterministic admission schedule so the saved-token count
    // is exact: r1 prefills via the wave path (row 0 holds its full
    // prompt), then r2..r4 with the SAME prompt admit into empty rows
    // and alias row 0's device-resident KV, each skipping plen-1 = 4
    // prefill tokens
    let prompt = vec![12, 3, 10, 7, 11];
    let req = |id: u64| Request {
        id,
        prompt: prompt.clone(),
        params: SamplingParams {
            temperature: 1.0,
            max_new_tokens: 4,
            ..Default::default()
        },
    };
    let run = |sharing: bool| {
        let mut cfg = EngineConfig::new("dense", "kvfp8");
        cfg.prefix_sharing = sharing;
        let mut engine =
            HloEngine::new(Arc::new(Runtime::hermetic()), cfg)
                .unwrap();
        let mut done = Vec::new();
        engine.enqueue(req(1)).unwrap();
        engine.step(&mut done).unwrap(); // wave: r1 full prefill
        for id in 2..=4 {
            engine.enqueue(req(id)).unwrap();
        }
        // r1 is still mid-decode, so r2..r4 take the chunked admission
        // path while row 0's prefix record is resident
        while !engine.is_idle() {
            engine.step(&mut done).unwrap();
        }
        let by_id: BTreeMap<u64, Completion> =
            done.into_iter().map(|c| (c.id, c)).collect();
        (by_id, engine.stats.prefill_tokens_saved)
    };
    let (shared, saved_on) = run(true);
    let (plain, saved_off) = run(false);
    assert_bit_identical(&shared, &plain, "step-schedule");
    assert_eq!(shared.len(), 4);
    assert_eq!(
        saved_on,
        3 * (prompt.len() as u64 - 1),
        "r2..r4 must each skip plen-1 prefill tokens"
    );
    assert_eq!(saved_off, 0);
}
