//! Quantize -> dequantize round-trip error bounds, per FP8 format, plus
//! the Q2-freshness non-vacuity check: a stale `ScaleSet` handle really
//! does trip its epoch `debug_assert` (so the lint rule guards a check
//! that fires, not a no-op).
//!
//! The bound used throughout: the quantizers pick a per-block scale
//! `s >= amax / fmt.max`, so nothing saturates and every scaled value
//! `v = x / s` round-trips under round-to-nearest with
//!
//!   |v - qdq(v)| <= ulp(v)/2 <= |v| * 2^-(mbits+1)   (normal range)
//!   |v - qdq(v)| <= min_subnormal / 2                (below it)
//!
//! Multiplying back by `s` and doubling each term for slack (binade
//! edges, UE8M0's power-of-two scale inflation) gives the per-element
//! bound checked here:
//!
//!   |x - y| <= |x| * 2^-mbits + s * fmt.min_subnormal

use std::sync::Arc;

use fp8_rl::fp8::{
    qdq_act_tilewise, quantize_blockwise, Fp8Format, ScaleFormat, Tensor,
    E4M3, E5M2, MIN_AMAX,
};
use fp8_rl::rollout::{EngineConfig, HloEngine};
use fp8_rl::runtime::Runtime;
use fp8_rl::util::rng::Pcg64;
use fp8_rl::util::units::ScaleEpoch;

fn random_tensor(
    rng: &mut Pcg64,
    rows: usize,
    cols: usize,
    spread: f32,
) -> Tensor {
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| rng.normal() as f32 * spread)
        .collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

/// Per-element round-trip bound for weight-blockwise quantization,
/// swept over shapes, block geometries, magnitudes and scale formats.
fn check_blockwise(fmt: Fp8Format, name: &str) {
    let mut rng = Pcg64::new(0x5eed + fmt.mbits as u64);
    let rel = (2.0f32).powi(-(fmt.mbits as i32));
    let cases: &[(usize, usize, usize, usize)] =
        &[(16, 16, 4, 4), (33, 7, 8, 3), (5, 128, 1, 16), (64, 64, 128, 128)];
    for &(rows, cols, bm, bn) in cases {
        for sf in [ScaleFormat::Fp32, ScaleFormat::Ue8m0] {
            for &spread in &[1e-3f32, 1.0, 37.5] {
                let t = random_tensor(&mut rng, rows, cols, spread);
                let q = quantize_blockwise(&t, (bm, bn), fmt, sf).unwrap();
                let d = q.dequantize();
                assert_eq!(d.shape, t.shape);
                let nbc = cols.div_ceil(bn);
                for r in 0..rows {
                    for c in 0..cols {
                        let x = t.data[r * cols + c];
                        let y = d.data[r * cols + c];
                        let s = q.scales()[(r / bm) * nbc + c / bn];
                        let bound = x.abs() * rel + s * fmt.min_subnormal;
                        assert!(
                            (x - y).abs() <= bound,
                            "{name} {rows}x{cols} block {bm}x{bn} \
                             {sf:?} elem ({r},{c}): |{x} - {y}| = {} \
                             exceeds {bound} (scale {s})",
                            (x - y).abs()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn blockwise_roundtrip_bound_e4m3() {
    check_blockwise(E4M3, "e4m3");
}

#[test]
fn blockwise_roundtrip_bound_e5m2() {
    check_blockwise(E5M2, "e5m2");
}

/// Same bound for the activation path (`qdq_act_tilewise`). The tile
/// scale is recomputed here exactly as the quantizer computes it, so
/// the bound is tight to the actual divisor used.
fn check_tilewise(fmt: Fp8Format, name: &str) {
    let mut rng = Pcg64::new(0xac7 + fmt.mbits as u64);
    let rel = (2.0f32).powi(-(fmt.mbits as i32));
    for &(rows, cols, tile) in
        &[(8usize, 64usize, 16usize), (13, 29, 7), (1, 128, 128)]
    {
        for sf in [ScaleFormat::Fp32, ScaleFormat::Ue8m0] {
            for &spread in &[1e-4f32, 1.0, 512.0] {
                let t = random_tensor(&mut rng, rows, cols, spread);
                let d = qdq_act_tilewise(&t, tile, fmt, sf).unwrap();
                assert_eq!(d.shape, t.shape);
                for (ri, (row, drow)) in t
                    .data
                    .chunks(cols)
                    .zip(d.data.chunks(cols))
                    .enumerate()
                {
                    for (ti, (seg, dseg)) in
                        row.chunks(tile).zip(drow.chunks(tile)).enumerate()
                    {
                        let amax = seg
                            .iter()
                            .fold(0.0f32, |m, &x| m.max(x.abs()));
                        let s = sf.apply(amax.max(MIN_AMAX) / fmt.max);
                        for (j, (&x, &y)) in
                            seg.iter().zip(dseg).enumerate()
                        {
                            let bound =
                                x.abs() * rel + s * fmt.min_subnormal;
                            assert!(
                                (x - y).abs() <= bound,
                                "{name} tile {tile} {sf:?} row {ri} \
                                 tile {ti} elem {j}: |{x} - {y}| = {} \
                                 exceeds {bound}",
                                (x - y).abs()
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn act_tilewise_roundtrip_bound_e4m3() {
    check_tilewise(E4M3, "e4m3");
}

#[test]
fn act_tilewise_roundtrip_bound_e5m2() {
    check_tilewise(E5M2, "e5m2");
}

/// All-zero and near-zero inputs round-trip to exactly zero (the
/// MIN_AMAX clamp keeps the divisor finite instead of 0/0 -> NaN).
#[test]
fn zero_input_roundtrips_to_zero_everywhere() {
    let t = Tensor::zeros(vec![4, 32]);
    for fmt in [E4M3, E5M2] {
        for sf in [ScaleFormat::Fp32, ScaleFormat::Ue8m0] {
            let d = quantize_blockwise(&t, (2, 8), fmt, sf)
                .unwrap()
                .dequantize();
            assert!(d.data.iter().all(|&x| x == 0.0));
            let a = qdq_act_tilewise(&t, 16, fmt, sf).unwrap();
            assert!(a.data.iter().all(|&x| x == 0.0));
        }
    }
}

/// Q2 non-vacuity: the `ScaleEpoch` assert in `ScaleSet::read` is live.
/// Grab a handle, bump the engine's weight epoch by installing fresh KV
/// scales, then read the old handle at the new epoch -> debug panic.
#[test]
#[cfg(debug_assertions)]
#[should_panic(expected = "stale ScaleSet")]
fn stale_scale_set_trips_the_epoch_assert() {
    let rt = Arc::new(Runtime::hermetic());
    let mut eng =
        HloEngine::new(rt, EngineConfig::new("dense", "bf16")).unwrap();
    let stale = eng.scale_set();
    eng.install_kv_scales(0.9, 1.1); // bumps the weight epoch
    let _ = stale.read(ScaleEpoch::new(eng.weight_epoch()));
}

/// The happy path the assert protects: a handle taken after the install
/// reads back the installed scales at the current epoch.
#[test]
fn fresh_scale_set_reads_installed_values() {
    let rt = Arc::new(Runtime::hermetic());
    let mut eng =
        HloEngine::new(rt, EngineConfig::new("dense", "bf16")).unwrap();
    eng.install_kv_scales(0.7, 1.3);
    let (k, v) =
        eng.scale_set().read(ScaleEpoch::new(eng.weight_epoch()));
    assert_eq!((k, v), (0.7, 1.3));
    assert_eq!(eng.kv_scales(), (0.7, 1.3));
}
