//! Rust-vs-JAX FP8 parity: the Rust codecs/quantizer must agree
//! bit-exactly with the numerics baked into the AOT artifacts (jax's
//! ml_dtypes casts). Golden values were captured from jax 0.8.2
//! (`float8_e4m3fn` / `float8_e5m2` casts after an explicit clip) —
//! python/tests/test_fp8_formats.py asserts the same table from the
//! Python side, so both halves are pinned to one contract.

use fp8_rl::fp8::{
    qdq_act_tilewise, qdq_blockwise, ScaleFormat, Tensor, E4M3, E5M2,
};
use fp8_rl::testkit::check;
use fp8_rl::util::rng::Pcg64;

/// (input, e4m3 round-trip, e5m2 round-trip) — golden from jax.
const GOLDEN: &[(f32, f32, f32)] = &[
    (0.0, 0.0, 0.0),
    (1.0, 1.0, 1.0),
    (1.7, 1.75, 1.75),
    (-300.0, -288.0, -320.0),
    (500.0, 448.0, 512.0),
    (0.001, 0.001953125, 0.0009765625),
    (448.0, 448.0, 448.0),
    (57344.0, 448.0, 57344.0),
    (-0.17, -0.171875, -0.15625),
    (3.14159, 3.25, 3.0),
    (1e-9, 0.0, 0.0),
    (0.0625, 0.0625, 0.0625),
];

#[test]
fn golden_e4m3_parity_with_jax() {
    for &(x, want, _) in GOLDEN {
        assert_eq!(E4M3.qdq(x), want, "e4m3({x})");
    }
}

#[test]
fn golden_e5m2_parity_with_jax() {
    for &(x, _, want) in GOLDEN {
        assert_eq!(E5M2.qdq(x), want, "e5m2({x})");
    }
}

#[test]
fn qdq_is_projection() {
    // property: quantization is idempotent (a projection onto the fp8
    // grid) for every format and any input
    check(
        7,
        2000,
        |r| (r.next_f32() - 0.5) * 1000.0,
        |&x| {
            for f in [E4M3, E5M2] {
                let once = f.qdq(x);
                let twice = f.qdq(once);
                if once != twice {
                    return Err(format!(
                        "{f:?}: qdq({x}) = {once}, qdq^2 = {twice}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn qdq_never_increases_magnitude_error_past_half_ulp() {
    // |x - qdq(x)| <= 2^-mbits * |x| for normals (relative half-ulp-ish
    // bound: ulp(x) <= x * 2^(1-mbits))
    check(
        8,
        2000,
        |r| 0.02f32 + r.next_f32() * 440.0,
        |&x| {
            let q = E4M3.qdq(x);
            let bound = x * (1.0 / 16.0) + 1e-6;
            if (q - x).abs() > bound {
                return Err(format!("e4m3({x}) = {q}, err > {bound}"));
            }
            Ok(())
        },
    );
}

#[test]
fn blockwise_matches_flat_when_single_block() {
    // a whole-tensor block is just per-tensor quantization
    let mut rng = Pcg64::new(9);
    let data: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
    let t = Tensor::new(vec![8, 8], data.clone()).unwrap();
    let q = qdq_blockwise(&t, (8, 8), E4M3, ScaleFormat::Fp32).unwrap();
    let amax = data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let scale = amax / 448.0;
    for (i, (&x, &y)) in data.iter().zip(&q.data).enumerate() {
        let want = E4M3.qdq(x / scale) * scale;
        assert!(
            (y - want).abs() < 1e-7,
            "elem {i}: {y} vs {want}"
        );
    }
}

#[test]
fn act_tilewise_respects_tile_independence() {
    // changing one tile must not change another tile's quantization
    let mut rng = Pcg64::new(10);
    let base: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
    let t1 = Tensor::new(vec![1, 32], base.clone()).unwrap();
    let mut bumped = base.clone();
    bumped[0] = 1000.0; // tile 0 outlier
    let t2 = Tensor::new(vec![1, 32], bumped).unwrap();
    let q1 = qdq_act_tilewise(&t1, 16, E4M3, ScaleFormat::Fp32).unwrap();
    let q2 = qdq_act_tilewise(&t2, 16, E4M3, ScaleFormat::Fp32).unwrap();
    // tile 1 (elements 16..32) identical
    assert_eq!(&q1.data[16..], &q2.data[16..]);
    // tile 0 differs
    assert_ne!(&q1.data[..16], &q2.data[..16]);
}

#[test]
fn ue8m0_scales_never_overflow_codes() {
    // with pow2 ceil scales, |x|/scale <= 448 always (no saturation)
    check(
        11,
        1000,
        |r| {
            let n = 16;
            (0..n)
                .map(|_| (r.next_f32() - 0.5) * 2000.0)
                .collect::<Vec<f32>>()
        },
        |xs: &Vec<f32>| {
            let t = Tensor::new(vec![1, xs.len()], xs.clone()).unwrap();
            let q = fp8_rl::fp8::quantize_blockwise(
                &t,
                (1, xs.len()),
                E4M3,
                ScaleFormat::Ue8m0,
            )
            .map_err(|e| e.to_string())?;
            let s = q.scales()[0];
            for &x in xs {
                if (x / s).abs() > 448.0 + 1e-3 {
                    return Err(format!(
                        "|{x}|/{s} = {} > 448",
                        (x / s).abs()
                    ));
                }
            }
            Ok(())
        },
    );
}
