//! Pool-vs-single-engine determinism and serving-shape integration
//! tests (hermetic: synthetic manifest + RefBackend in every thread).
//!
//! The contract under test: an N-replica pool is a pure throughput
//! knob. For the same request ids and engine seed it must produce
//! BYTE-identical tokens, behavior logprobs, full-vocab logprobs and
//! TIS weights as one engine — across routing policies, replica
//! counts, a mid-run weight sync, and a KV-scale recalibration.

use std::sync::Arc;

use fp8_rl::rollout::{
    EngineConfig, EnginePool, HloEngine, PoolConfig, Request, RoutePolicy,
    SamplingParams,
};
use fp8_rl::runtime::{HostArray, Runtime};
use fp8_rl::sync::{WeightSync, WeightSyncConfig};

const TIS_C: f32 = 2.0;

/// Requests exercising truncated sampling (top-k / top-p / plain / a
/// greedy row), so the determinism claim covers every sampler path.
fn requests(lo: u64, hi: u64) -> Vec<Request> {
    (lo..hi)
        .map(|i| {
            let params = match i % 4 {
                0 => SamplingParams {
                    temperature: 1.0,
                    max_new_tokens: 6,
                    ..Default::default()
                },
                1 => SamplingParams {
                    temperature: 1.0,
                    top_k: 5,
                    max_new_tokens: 6,
                    ..Default::default()
                },
                2 => SamplingParams {
                    temperature: 1.0,
                    top_p: 0.9,
                    max_new_tokens: 6,
                    ..Default::default()
                },
                _ => SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: 6,
                    ..Default::default()
                },
            };
            Request {
                id: i,
                prompt: vec![12, (i % 10) as i32, 10, ((i + 3) % 10) as i32, 11],
                params,
            }
        })
        .collect()
}

fn single_engine(variant: &str) -> HloEngine {
    let rt = Arc::new(Runtime::hermetic());
    HloEngine::new(rt, EngineConfig::new("dense", variant)).unwrap()
}

fn pool(n: usize, variant: &str, policy: RoutePolicy) -> EnginePool {
    use fp8_rl::testkit::hb::{HbHandle, HbRecorder};
    EnginePool::new_traced(
        PoolConfig {
            n_replicas: n,
            policy,
            engine: EngineConfig::new("dense", variant),
        },
        // explicitly hermetic: must not depend on whether an artifacts
        // dir happens to exist in the test cwd
        fp8_rl::rollout::hermetic_runtime_factory(),
        // every pool test doubles as a fence-protocol conformance
        // witness: `hb_check` replays the recorded hb log through the
        // checker (inert under `--no-default-features`)
        HbHandle::traced(HbRecorder::new(n)),
    )
    .unwrap()
}

/// Assert the recorded session conforms to the fence protocol.
fn hb_check(p: &EnginePool, what: &str) {
    if let Err(e) = p.hb_verify() {
        panic!("{what}: hb conformance failed: {e}");
    }
}

/// Per-token TIS weights as the trainer would compute them against the
/// SAME policy: exp(clip(pi_full - pi_behavior)) — equal logprobs imply
/// equal weights, asserted explicitly because the acceptance criterion
/// names them.
fn tis_weights(c: &fp8_rl::rollout::Completion) -> Vec<f32> {
    c.logprobs_full
        .iter()
        .zip(&c.logprobs)
        .map(|(&full, &behave)| {
            ((full - behave) as f64).exp().min(TIS_C as f64) as f32
        })
        .collect()
}

fn assert_identical(
    a: &[fp8_rl::rollout::Completion],
    b: &[fp8_rl::rollout::Completion],
    what: &str,
) {
    assert_eq!(a.len(), b.len(), "{what}: completion count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{what}: merge order");
        assert_eq!(x.tokens, y.tokens, "{what}: tokens for req {}", x.id);
        assert_eq!(
            x.logprobs, y.logprobs,
            "{what}: behavior logprobs for req {}",
            x.id
        );
        assert_eq!(
            x.logprobs_full, y.logprobs_full,
            "{what}: full logprobs for req {}",
            x.id
        );
        assert_eq!(
            tis_weights(x),
            tis_weights(y),
            "{what}: TIS weights for req {}",
            x.id
        );
        assert_eq!(x.finish, y.finish, "{what}: finish for req {}", x.id);
        assert_eq!(
            x.epoch, y.epoch,
            "{what}: weight-epoch tag for req {}",
            x.id
        );
    }
}

/// Perturbed-then-FP8-quantized weights standing in for one trainer
/// step (quantized once; installed everywhere).
fn synced_weights(rt: &Runtime) -> Arc<Vec<HostArray>> {
    let spec = rt.manifest.model("dense").unwrap().clone();
    let init = rt.manifest.load_initial_params("dense").unwrap();
    let params: Vec<HostArray> = init
        .into_iter()
        .zip(&spec.params)
        .map(|(mut v, p)| {
            for x in v.iter_mut() {
                *x *= 1.01;
            }
            HostArray::f32(p.shape.clone(), v)
        })
        .collect();
    let sync = WeightSync::new(WeightSyncConfig::fp8());
    let (w, rep) = sync.run_shared(&spec, &params).unwrap();
    assert!(rep.n_quantized > 0);
    w
}

#[test]
fn four_replica_pool_is_bit_identical_to_single_engine() {
    // kvfp8 so the KV-scale broadcast below is numerically live
    let variant = "kvfp8";
    let mut single = single_engine(variant);
    let mut pool4 = pool(4, variant, RoutePolicy::RoundRobin);

    // ---- phase 1: plain generation (8 = one wave on the single
    // engine; 2-request waves per replica on the pool) ----
    let a_single = single.generate(requests(0, 8)).unwrap();
    let a_pool = pool4.generate(requests(0, 8)).unwrap();
    assert_identical(&a_single, &a_pool, "phase 1");

    // ---- mid-run weight sync: quantize once, install everywhere ----
    let rt = Arc::new(Runtime::hermetic());
    let w = synced_weights(&rt);
    single.install_weights(&w).unwrap();
    pool4.install_weights(w).unwrap();

    // ---- KV-scale recalibration broadcast ----
    single.install_kv_scales(0.7, 1.3);
    pool4.install_kv_scales(0.7, 1.3).unwrap();

    // ---- phase 2: same contract under the new weights + scales ----
    let b_single = single.generate(requests(100, 108)).unwrap();
    let b_pool = pool4.generate(requests(100, 108)).unwrap();
    assert_identical(&b_single, &b_pool, "phase 2");

    // the sync must actually have changed generation (guard against a
    // dead broadcast path vacuously passing the comparison). Only the
    // greedy rows are comparable across phases: request 100+i has the
    // same prompt and params as request i, and greedy ignores the
    // (id-keyed) sampling stream, so any difference comes from the new
    // weights / KV scales alone.
    let changed = a_single
        .iter()
        .filter(|c| c.id % 4 == 3)
        .any(|c| {
            let d = b_single.iter().find(|d| d.id == c.id + 100).unwrap();
            c.tokens != d.tokens || c.logprobs_full != d.logprobs_full
        });
    assert!(changed, "weight sync + kv scales appear dead");
    hb_check(&pool4, "four-replica session");
}

#[test]
fn replica_count_and_policy_do_not_change_outputs() {
    let reference = {
        let mut e = single_engine("bf16");
        e.generate(requests(0, 12)).unwrap()
    };
    for (n, policy) in [
        (1, RoutePolicy::RoundRobin),
        (2, RoutePolicy::LeastLoaded),
        (3, RoutePolicy::RoundRobin),
        (4, RoutePolicy::LeastLoaded),
    ] {
        let mut p = pool(n, "bf16", policy);
        let done = p.generate(requests(0, 12)).unwrap();
        assert_identical(
            &reference,
            &done,
            &format!("{n} replicas / {policy:?}"),
        );
        assert_eq!(
            p.loads(),
            vec![0u64; n].as_slice(),
            "router load must drain at {n} replicas"
        );
        hb_check(&p, &format!("{n}-replica barrier session"));
    }
}

#[test]
fn mid_decode_weight_sync_fences_epochs() {
    // The streaming epoch fence: sequences admitted BEFORE an
    // install complete under the old weights (epoch 0), sequences
    // admitted after run entirely under the new ones (epoch 1), the
    // Completion epoch tags say which is which, and both halves are
    // bit-identical to a sequential single-engine run — i.e. no
    // torn-weights generation even though the fence lands while the
    // replicas are mid-decode.
    let mut p = pool(2, "bf16", RoutePolicy::RoundRobin);
    assert_eq!(p.epoch(), 0);
    // phase A in flight on both replicas...
    for r in requests(0, 8) {
        p.submit(r).unwrap();
    }
    // ...then the fence arrives mid-decode (nothing has been drained)
    let rt = Arc::new(Runtime::hermetic());
    let w = synced_weights(&rt);
    let epoch = p.sync_weights(w.clone()).unwrap();
    assert_eq!(epoch, 1);
    // phase B is admitted behind the fence
    for r in requests(100, 108) {
        p.submit(r).unwrap();
    }
    let done = p.drain().unwrap();
    assert_eq!(done.len(), 16);
    for c in &done {
        let want = if c.id < 100 { 0 } else { 1 };
        assert_eq!(
            c.epoch, want,
            "req {}: fenced epoch tag must match its submit side",
            c.id
        );
    }
    assert_eq!(p.loads(), &[0, 0], "streamed loads must drain");

    // sequential reference: old weights for A, install, new for B
    let mut single = single_engine("bf16");
    let mut want = single.generate(requests(0, 8)).unwrap();
    single.install_weights(&w).unwrap();
    want.extend(single.generate(requests(100, 108)).unwrap());
    want.sort_by_key(|c| c.id);
    assert_identical(&want, &done, "mid-decode fence");

    // the new weights must actually change generation (guard against
    // a dead fence path vacuously passing): greedy rows are the
    // comparable ones — request 100+i repeats request i's prompt and
    // params, and greedy ignores the id-keyed sampling stream
    let changed = done
        .iter()
        .filter(|c| c.id % 4 == 3 && c.id < 100)
        .any(|c| {
            let d = done.iter().find(|d| d.id == c.id + 100).unwrap();
            c.tokens != d.tokens || c.logprobs_full != d.logprobs_full
        });
    assert!(changed, "the epoch fence appears to be a dead path");
    hb_check(&p, "mid-decode fence session");
}

#[test]
fn abort_unblocks_a_fence_blocked_straggler() {
    // the abort-propagation ROADMAP follow-up: a pending epoch fence
    // waits for the in-flight drain, so (a) aborting the straggler it
    // is blocked on must Scheduler::cancel it immediately and let the
    // fence apply, and (b) aborting a submission still PARKED behind
    // the fence must resolve it Aborted without it ever decoding out
    // its max_new_tokens budget under the new epoch (it used to run
    // to completion and resolve Done).
    use std::collections::{BTreeMap, BTreeSet};
    use std::time::Instant;

    use fp8_rl::rollout::Completed;

    let mut p = pool(1, "bf16", RoutePolicy::RoundRobin);
    let long = |id: u64| Request {
        id,
        prompt: vec![12, (id % 10) as i32, 10, 3, 11],
        params: SamplingParams {
            temperature: 1.0,
            max_new_tokens: 10_000,
            eos: -1, // never terminates early
            ..Default::default()
        },
    };
    // the straggler the fence will block on
    p.submit(long(0)).unwrap();
    let rt = Arc::new(Runtime::hermetic());
    let w = synced_weights(&rt);
    assert_eq!(p.sync_weights(w).unwrap(), 1);
    // a long post-fence submission: parked in the worker's backlog
    // until the fence applies
    p.submit(long(2)).unwrap();
    // abort both sides of the fence
    p.abort(0).unwrap();
    p.abort(2).unwrap();
    // a fresh post-fence request must run under the new epoch
    p.submit(Request {
        id: 3,
        prompt: vec![12, 4, 10, 3, 11],
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: 4,
            ..Default::default()
        },
    })
    .unwrap();

    let t0 = Instant::now();
    let mut done: BTreeMap<u64, fp8_rl::rollout::Completion> =
        BTreeMap::new();
    let mut aborted = BTreeSet::new();
    while let Some(c) = p.next_resolved().unwrap() {
        match c {
            Completed::Done(c) => {
                assert!(done.insert(c.id, c).is_none());
            }
            Completed::Aborted(id) => {
                assert!(aborted.insert(id));
            }
            Completed::Failed(id, msg) => {
                panic!("ticket {id} failed: {msg}")
            }
        }
    }
    // "promptly": nobody waited out a 10_000-token budget
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(60),
        "fence-blocked abort took {:?}",
        t0.elapsed()
    );
    // the parked submission must NEVER have decoded: the abort pulls
    // it straight out of the backlog (margins here are deterministic —
    // its abort is queued behind at most one ingest round while any
    // run it could get needs dozens of rounds)
    assert!(
        aborted.contains(&2),
        "backlog-parked ticket 2 must resolve Aborted, got {done:?}"
    );
    // the straggler resolves exactly once; Aborted in all but
    // pathological scheduler timings (if the whole decode outran the
    // abort it legitimately finished under the OLD epoch)
    if let Some(c) = done.get(&0) {
        assert_eq!(c.epoch, 0, "straggler ran pre-fence");
    } else {
        assert!(aborted.contains(&0), "ticket 0 must resolve");
    }
    // the fence applied and post-fence work runs under the new epoch
    assert_eq!(p.epoch(), 1);
    let c3 = done.get(&3).expect("post-fence request must complete");
    assert_eq!(c3.epoch, 1, "post-fence submission on the old epoch");
    assert_eq!(p.loads(), &[0], "everything settled");
    // the pool stays serviceable under the new epoch
    let after = p.generate(requests(10, 12)).unwrap();
    assert_eq!(after.len(), 2);
    for c in &after {
        assert_eq!(c.epoch, 1);
    }
    hb_check(&p, "fence-blocked abort session");
}

#[test]
fn quarantine_while_fence_parked_writes_off_acks_and_reroutes() {
    // the reaper regression from the issue: a replica dies while its
    // fence is still PARKED (draining). The reaper must (a) write off
    // exactly the fence acks that replica still owed — surfacing the
    // broken fence as an error, not hanging drain — and (b) re-route
    // its unresolved tickets to the survivor at the current epoch.
    // The hb conformance check at the end proves the write-off was
    // exact (the checker compares it against fences_sent - acks_recvd)
    // and that every ticket still resolved exactly once.
    use std::collections::{BTreeMap, BTreeSet};

    use fp8_rl::rollout::Completed;

    let mut p = pool(2, "bf16", RoutePolicy::RoundRobin);
    let long = |id: u64| Request {
        id,
        prompt: vec![12, (id % 10) as i32, 10, 3, 11],
        params: SamplingParams {
            temperature: 1.0,
            max_new_tokens: 10_000,
            eos: -1, // never terminates early
            ..Default::default()
        },
    };
    let short = |id: u64| Request {
        id,
        prompt: vec![12, (id % 10) as i32, 10, 3, 11],
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: 4,
            ..Default::default()
        },
    };
    // round-robin: A -> replica 0 (the straggler its fence will park
    // on), B -> replica 1 (finishes, lets 1's fence apply)
    p.submit(long(0)).unwrap();
    p.submit(short(1)).unwrap();
    let w = synced_weights(&Runtime::hermetic());
    assert_eq!(p.sync_weights(w).unwrap(), 1);
    // C -> replica 0: parked in the backlog BEHIND the pending fence
    p.submit(short(2)).unwrap();
    // replica 0 dies with its fence still draining (A in flight, C
    // backlogged, the fence unacknowledged)
    p.kill_worker_for_test(0);
    // the abort's send fails, which triggers the reap: replica 0 is
    // quarantined, its owed ack written off, A and C re-routed to
    // replica 1 at the current epoch — the retried abort then cancels
    // A at its NEW home
    p.abort(0).unwrap();

    let mut done: BTreeMap<u64, fp8_rl::rollout::Completion> =
        BTreeMap::new();
    let mut aborted = BTreeSet::new();
    let mut fence_err = None;
    loop {
        match p.next_resolved() {
            Ok(Some(Completed::Done(c))) => {
                assert!(done.insert(c.id, c).is_none());
            }
            Ok(Some(Completed::Aborted(id))) => {
                assert!(aborted.insert(id));
            }
            Ok(Some(Completed::Failed(id, msg))) => {
                panic!("ticket {id} failed: {msg}")
            }
            Ok(None) => break,
            Err(e) => {
                // the written-off fence surfaces exactly once
                assert!(
                    fence_err.replace(e.to_string()).is_none(),
                    "fence failure reported twice"
                );
            }
        }
    }
    let fence_err = fence_err.expect("written-off fence must surface");
    assert!(fence_err.contains("pool degraded"), "{fence_err}");
    // every ticket resolved exactly once: B and C completed (C at the
    // post-fence epoch on the survivor), A's abort won at its new home
    assert!(aborted.contains(&0), "re-routed straggler must abort");
    assert_eq!(done.get(&1).map(|c| c.epoch), Some(0), "B pre-fence");
    assert_eq!(done.get(&2).map(|c| c.epoch), Some(1), "C post-fence");
    assert_eq!(p.n_outstanding(), 0);
    assert_eq!(p.loads(), &[0, 0], "write-offs must settle the router");
    // drain still terminates (the fence debt was written off, not
    // leaked) and reports nothing new
    assert!(p.drain().unwrap().is_empty());
    hb_check(&p, "quarantine-while-parked session");
}

#[test]
fn pool_aggregates_stats_across_replicas() {
    let mut p = pool(4, "bf16", RoutePolicy::RoundRobin);
    let done = p.generate(requests(0, 16)).unwrap();
    assert_eq!(done.len(), 16);
    let delivered: usize = done.iter().map(|c| c.tokens.len()).sum();
    let total = p.stats().unwrap();
    assert_eq!(total.tokens_generated, delivered as u64);
    let per = p.per_replica_stats().unwrap();
    assert_eq!(per.len(), 4);
    assert!(
        per.iter().all(|s| s.tokens_generated > 0),
        "round-robin must spread work over every replica: {:?}",
        per.iter().map(|s| s.tokens_generated).collect::<Vec<_>>()
    );
    assert_eq!(
        per.iter().map(|s| s.tokens_generated).sum::<u64>(),
        total.tokens_generated
    );
    hb_check(&p, "stats session");
}

#[test]
fn behavior_logprob_is_renormalized_in_completions() {
    // end-to-end check of the headline sampler fix: truncated requests
    // must come back with behavior logprobs that differ from the
    // full-vocab ones (kept-set renormalization), while untruncated
    // temp-1 requests agree between the two
    let mut e = single_engine("bf16");
    let done = e.generate(requests(0, 8)).unwrap();
    for c in &done {
        assert_eq!(c.logprobs.len(), c.tokens.len());
        assert_eq!(c.logprobs_full.len(), c.tokens.len());
        match c.id % 4 {
            0 => {
                // untruncated temp 1: conventions coincide bit-exactly
                // (shared log-softmax route)
                for (a, b) in c.logprobs.iter().zip(&c.logprobs_full) {
                    assert_eq!(a, b, "req {}", c.id);
                }
            }
            1 | 2 => {
                // truncation renormalizes: every kept token is at least
                // as likely under the behavior law, and the TIS weight
                // exp(full - behavior) is <= 1 per token
                for (a, b) in c.logprobs.iter().zip(&c.logprobs_full) {
                    assert!(
                        *a >= *b - 1e-5,
                        "req {}: behavior {a} < full {b}",
                        c.id
                    );
                }
            }
            _ => {
                // greedy: point mass
                for a in &c.logprobs {
                    assert_eq!(*a, 0.0, "req {}", c.id);
                }
                for b in &c.logprobs_full {
                    assert!(*b < 0.0, "req {}", c.id);
                }
            }
        }
    }
}
