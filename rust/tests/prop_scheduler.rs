//! Property tests on the coordinator/rollout invariants (the testkit
//! mini-proptest framework — proptest is unavailable offline).
//!
//! Invariants under arbitrary workloads:
//!   * the KV allocator never double-books or leaks blocks;
//!   * the scheduler's running set and KV allocations stay in sync
//!     through admit / extend / preempt / finish storms;
//!   * every submitted request is eventually admitted at least once when
//!     capacity allows;
//!   * FP8 KV capacity is exactly 2x BF16 for the same budget;
//!   * group-relative advantages are zero-mean within every group.

use fp8_rl::rl::dapo::{group_advantages, Sample};
use fp8_rl::rl::task::make_problem;
use fp8_rl::rollout::kvcache::{
    KvBlockManager, KvGeometry, KvPrecision,
};
use fp8_rl::rollout::request::{
    Completion, FinishReason, Request, SamplingParams,
};
use fp8_rl::rollout::scheduler::Scheduler;
use fp8_rl::testkit::{check, vec_of, Shrink};
use fp8_rl::util::rng::Pcg64;
use fp8_rl::util::units::{Blocks, Bytes};

fn geo(block_tokens: usize) -> KvGeometry {
    KvGeometry {
        n_layers: 2,
        n_kv_heads: 2,
        d_head: 8,
        block_tokens,
        precision: KvPrecision::Bf16,
    }
}

/// One scripted scheduler op.
#[derive(Clone, Debug)]
enum Op {
    Submit(usize),  // prompt length
    Admit,
    Extend,
    FinishOldest,
}

impl Shrink for Op {
    fn shrink(&self) -> Vec<Self> {
        match self {
            Op::Submit(n) if *n > 1 => vec![Op::Submit(n / 2)],
            _ => vec![],
        }
    }
}

fn random_ops(rng: &mut Pcg64) -> Vec<Op> {
    vec_of(rng, 1, 60, |r| match r.below(4) {
        0 => Op::Submit(1 + r.below(12) as usize),
        1 => Op::Admit,
        2 => Op::Extend,
        _ => Op::FinishOldest,
    })
}

fn run_script(
    blocks: usize,
    max_batch: usize,
    ops: &[Op],
) -> Result<(), String> {
    let mut sched = Scheduler::new(
        KvBlockManager::new(geo(4), Blocks::new(blocks))
            .map_err(|e| e.to_string())?,
        max_batch,
    );
    let mut next_id = 0u64;
    for op in ops {
        match op {
            Op::Submit(plen) => {
                sched.submit(Request {
                    id: next_id,
                    prompt: vec![0; *plen],
                    params: SamplingParams::default(),
                });
                next_id += 1;
            }
            Op::Admit => {
                sched.admit();
            }
            Op::Extend => {
                let ids = sched.running_ids().to_vec();
                sched
                    .extend_all(&ids)
                    .map_err(|e| e.to_string())?;
            }
            Op::FinishOldest => {
                if let Some(&id) = sched.running_ids().first() {
                    sched.finish(id);
                }
            }
        }
        sched.check_invariants()?;
    }
    Ok(())
}

#[test]
fn scheduler_invariants_hold_under_op_storms() {
    check(
        101,
        300,
        |r| {
            let blocks = 1 + r.below(24) as usize;
            let max_batch = 1 + r.below(8) as usize;
            (blocks, (max_batch, random_ops(r)))
        },
        |(blocks, (max_batch, ops))| {
            run_script(*blocks, *max_batch, ops)
        },
    );
}

#[test]
fn kv_capacity_doubles_with_fp8() {
    check(
        102,
        200,
        |r| 1usize + r.below(1 << 22) as usize,
        |&budget| {
            let bf = KvGeometry {
                precision: KvPrecision::Bf16,
                ..geo(16)
            };
            let f8 = KvGeometry {
                precision: KvPrecision::Fp8,
                ..geo(16)
            };
            let nb = bf
                .blocks_in(Bytes::new(budget))
                .map_err(|e| e.to_string())?
                .get();
            let nf = f8
                .blocks_in(Bytes::new(budget))
                .map_err(|e| e.to_string())?
                .get();
            // fp8 fits at least 2x-1 blocks (floor effects) and at most 2x+1
            if nf < nb * 2 || nf > nb * 2 + 1 {
                return Err(format!("budget {budget}: bf16 {nb} fp8 {nf}"));
            }
            Ok(())
        },
    );
}

#[test]
fn no_request_starves_with_capacity() {
    // submit K short requests into ample capacity; after one admit all
    // must be running
    check(
        103,
        200,
        |r| 1usize + r.below(6) as usize,
        |&k| {
            let mut sched = Scheduler::new(
                KvBlockManager::new(geo(4), Blocks::new(64))
                    .map_err(|e| e.to_string())?,
                8,
            );
            for id in 0..k as u64 {
                sched.submit(Request {
                    id,
                    prompt: vec![0; 3],
                    params: SamplingParams::default(),
                });
            }
            let admitted = sched.admit();
            if admitted.len() != k.min(8) {
                return Err(format!(
                    "admitted {} of {k}",
                    admitted.len()
                ));
            }
            sched.check_invariants()
        },
    );
}

#[test]
fn admissions_survive_their_admission_round() {
    // cumulative-reserve invariant: a sequence admitted in round N is
    // never preempted by the `extend_all` of round N — the admission
    // reserve covers both the same-round co-admissions' growth blocks
    // and every running sequence sitting at a block boundary
    check(
        105,
        300,
        |r| {
            let blocks = 2 + r.below(24) as usize;
            let max_batch = 1 + r.below(8) as usize;
            let plens =
                vec_of(r, 1, 40, |rr| 1 + rr.below(12) as usize);
            (blocks, (max_batch, plens))
        },
        |(blocks, (max_batch, plens))| {
            let mut sched = Scheduler::new(
                KvBlockManager::new(geo(4), Blocks::new(*blocks))
                    .map_err(|e| e.to_string())?,
                *max_batch,
            );
            let mut next_id = 0u64;
            let mut queue: Vec<usize> = plens.clone();
            let mut round = 0usize;
            while !queue.is_empty() || !sched.is_idle() {
                // feed one new request per round while any remain
                if let Some(plen) = queue.pop() {
                    sched.submit(Request {
                        id: next_id,
                        prompt: vec![0; plen],
                        params: SamplingParams::default(),
                    });
                    next_id += 1;
                }
                let admitted: Vec<u64> =
                    sched.admit().iter().map(|r| r.id).collect();
                if admitted.is_empty()
                    && sched.n_running() == 0
                    && queue.is_empty()
                {
                    // the head-of-line request can never fit this
                    // cache even when it is completely empty
                    break;
                }
                // finish the oldest seq periodically so workloads
                // drain — BEFORE the extend, so progress is guaranteed
                // even when a lone sequence self-preempts at the end
                // of every admit/grow cycle
                if round % 3 == 2 {
                    if let Some(&id) = sched.running_ids().first() {
                        sched.finish(id);
                    }
                }
                let ids = sched.running_ids().to_vec();
                let rep = sched
                    .extend_all(&ids)
                    .map_err(|e| e.to_string())?;
                for id in &admitted {
                    if rep.preempted.contains(id) {
                        return Err(format!(
                            "seq {id} admitted AND preempted in \
                             round {round}"
                        ));
                    }
                }
                sched.check_invariants()?;
                round += 1;
                if round > 10_000 {
                    return Err("workload failed to drain".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prefix_sharing_grouped_saves_blocks_with_identical_admission() {
    // GRPO-style grouped workloads: each group's G members share one
    // prompt. With ample capacity a prefix-sharing scheduler must admit
    // exactly the same ids as an unshared one (sharing is an accounting
    // optimization, never an admission-policy change), must never use
    // MORE blocks, must use strictly FEWER right after admission when
    // any group has G > 1, and must drain back to zero blocks on finish.
    check(
        106,
        200,
        |r| {
            vec_of(r, 1, 8, |rr| {
                (1 + rr.below(8) as usize, 1 + rr.below(12) as usize)
            })
        },
        |groups: &Vec<(usize, usize)>| {
            let mk = |sharing: bool| -> Result<Scheduler, String> {
                let mut s = Scheduler::new(
                    KvBlockManager::new(geo(4), Blocks::new(4096))
                        .map_err(|e| e.to_string())?,
                    256,
                );
                s.set_prefix_sharing(sharing);
                Ok(s)
            };
            let mut shared = mk(true)?;
            let mut plain = mk(false)?;
            let mut next_id = 0u64;
            for (gi, (g, plen)) in groups.iter().enumerate() {
                // distinct prompts per group: first token encodes gi
                let prompt: Vec<i32> = (0..*plen)
                    .map(|t| (gi * 16 + t) as i32)
                    .collect();
                for _ in 0..*g {
                    for s in [&mut shared, &mut plain] {
                        s.submit(Request {
                            id: next_id,
                            prompt: prompt.clone(),
                            params: SamplingParams::default(),
                        });
                    }
                    next_id += 1;
                }
            }
            let a: Vec<u64> =
                shared.admit().iter().map(|r| r.id).collect();
            let b: Vec<u64> =
                plain.admit().iter().map(|r| r.id).collect();
            if a != b {
                return Err(format!(
                    "admissions diverge: shared {a:?} vs plain {b:?}"
                ));
            }
            shared.check_invariants()?;
            plain.check_invariants()?;
            let (su, pu) = (
                shared.kv.used_blocks().get(),
                plain.kv.used_blocks().get(),
            );
            if su > pu {
                return Err(format!(
                    "sharing uses more blocks: {su} vs {pu}"
                ));
            }
            if groups.iter().any(|(g, _)| *g > 1) && su >= pu {
                return Err(format!(
                    "a real group must share: {su} !< {pu}"
                ));
            }
            // decode rounds: COW splits shared tails but full-block
            // prompt prefixes stay shared, so shared <= plain always
            for round in 0..20 {
                let ids = shared.running_ids().to_vec();
                shared
                    .extend_all(&ids)
                    .map_err(|e| e.to_string())?;
                plain
                    .extend_all(&ids)
                    .map_err(|e| e.to_string())?;
                shared.check_invariants()?;
                plain.check_invariants()?;
                let (su, pu) = (
                    shared.kv.used_blocks().get(),
                    plain.kv.used_blocks().get(),
                );
                if su > pu {
                    return Err(format!(
                        "round {round}: sharing uses more blocks: \
                         {su} vs {pu}"
                    ));
                }
            }
            for id in shared.running_ids().to_vec() {
                shared.finish(id);
                plain.finish(id);
            }
            shared.check_invariants()?;
            plain.check_invariants()?;
            if shared.kv.used_blocks().get() != 0 {
                return Err(format!(
                    "shared cache leaked {} blocks after drain",
                    shared.kv.used_blocks().get()
                ));
            }
            Ok(())
        },
    );
}

/// One scripted op on a prefix-sharing scheduler (grouped storms).
#[derive(Clone, Debug)]
enum Gop {
    SubmitGroup(usize, usize), // (group size, prompt length)
    Admit,
    Extend,
    FinishOldest,
    CancelNewest,
    PreemptNewest,
}

impl Shrink for Gop {
    fn shrink(&self) -> Vec<Self> {
        match self {
            Gop::SubmitGroup(g, n) if *g > 1 || *n > 1 => {
                vec![Gop::SubmitGroup(1.max(g / 2), 1.max(n / 2))]
            }
            _ => vec![],
        }
    }
}

fn run_grouped_script(
    blocks: usize,
    max_batch: usize,
    ops: &[Gop],
) -> Result<(), String> {
    let mut sched = Scheduler::new(
        KvBlockManager::new(geo(4), Blocks::new(blocks))
            .map_err(|e| e.to_string())?,
        max_batch,
    );
    sched.set_prefix_sharing(true);
    let mut next_id = 0u64;
    let mut group_no = 0usize;
    for op in ops {
        match op {
            Gop::SubmitGroup(g, plen) => {
                let prompt: Vec<i32> = (0..*plen)
                    .map(|t| (group_no * 16 + t) as i32)
                    .collect();
                group_no += 1;
                for _ in 0..*g {
                    sched.submit(Request {
                        id: next_id,
                        prompt: prompt.clone(),
                        params: SamplingParams::default(),
                    });
                    next_id += 1;
                }
            }
            Gop::Admit => {
                sched.admit();
            }
            Gop::Extend => {
                let ids = sched.running_ids().to_vec();
                sched
                    .extend_all(&ids)
                    .map_err(|e| e.to_string())?;
            }
            Gop::FinishOldest => {
                if let Some(&id) = sched.running_ids().first() {
                    sched.finish(id);
                }
            }
            Gop::CancelNewest => {
                if let Some(&id) = sched.running_ids().last() {
                    sched.cancel(id);
                }
            }
            Gop::PreemptNewest => {
                sched
                    .preempt_newest()
                    .map_err(|e| e.to_string())?;
            }
        }
        // refcount conservation, free-XOR-referenced, registry hygiene
        // — checked after EVERY op, under real block pressure
        sched.check_invariants()?;
    }
    Ok(())
}

#[test]
fn prefix_sharing_invariants_hold_under_grouped_storms() {
    check(
        107,
        300,
        |r| {
            let blocks = 1 + r.below(24) as usize;
            let max_batch = 1 + r.below(8) as usize;
            let ops = vec_of(r, 1, 60, |rr| match rr.below(7) {
                0 | 1 => Gop::SubmitGroup(
                    1 + rr.below(8) as usize,
                    1 + rr.below(12) as usize,
                ),
                2 => Gop::Admit,
                3 => Gop::Extend,
                4 => Gop::FinishOldest,
                5 => Gop::CancelNewest,
                _ => Gop::PreemptNewest,
            });
            (blocks, (max_batch, ops))
        },
        |(blocks, (max_batch, ops))| {
            run_grouped_script(*blocks, *max_batch, ops)
        },
    );
}

#[test]
fn group_advantages_zero_mean_per_group() {
    check(
        104,
        300,
        |r| {
            let n_groups = 1 + r.below(4) as usize;
            vec_of(r, n_groups, n_groups * 6, |rr| {
                (
                    rr.below(n_groups as u64) as usize,
                    (rr.next_f32() * 2.0) - 0.5,
                )
            })
        },
        |pairs: &Vec<(usize, f32)>| {
            let samples: Vec<Sample> = pairs
                .iter()
                .map(|(g, rew)| {
                    let problem = make_problem(1, 2);
                    Sample {
                        problem: problem.clone(),
                        completion: Completion {
                            id: 0,
                            prompt: problem.prompt.clone(),
                            tokens: vec![3, 13],
                            logprobs: vec![-0.1, -0.1],
                            logprobs_full: vec![-0.1, -0.1],
                            finish: FinishReason::Eos,
                            preemptions: 0,
                            epoch: 0,
                        },
                        reward: *rew,
                        group: *g,
                    }
                })
                .collect();
            let advs = group_advantages(&samples, 1e-4);
            let n_groups =
                samples.iter().map(|s| s.group).max().unwrap() + 1;
            for g in 0..n_groups {
                let vals: Vec<f32> = samples
                    .iter()
                    .zip(&advs)
                    .filter(|(s, _)| s.group == g)
                    .map(|(_, &a)| a)
                    .collect();
                if vals.is_empty() {
                    continue;
                }
                let mean: f32 =
                    vals.iter().sum::<f32>() / vals.len() as f32;
                if mean.abs() > 1e-3 {
                    return Err(format!(
                        "group {g} advantage mean {mean}"
                    ));
                }
            }
            Ok(())
        },
    );
}
