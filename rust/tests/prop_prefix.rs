//! Property tests for the prefix-registry / refcount algebra of
//! `KvBlockManager`: `check_invariants()` must hold after EVERY
//! operation, across epoch-fence preemption storms, copy-on-write
//! appends, rc-0 purges, and ABA block-id reuse.
//!
//! Two layers:
//!
//! 1. **Model-seeded traces.** The deterministic traces below were
//!    lifted from the `pallas-model` bounded model checker's clean
//!    exploration of the same algebra (see `tools/model`). Each trace
//!    is annotated with the generating command; the model's action
//!    vocabulary (`Alloc`/`Append`/`Release`/`FencePreempt` over a
//!    6-block, block_tokens=2, 3-slot pool with the fixed prompt pair
//!    `[1,2,5]` / `[1,2,3,4]`) is replayed here against the real
//!    manager. If the model and the implementation drift, either
//!    `tools/model`'s replay bridge or these traces fail first.
//!
//! 2. **Randomized storms.** A seeded `Pcg64` drives thousands of
//!    interleaved admissions (shared and unshared), appends, cancels,
//!    and fence preemptions over a deliberately tiny pool so
//!    exhaustion, COW, and purge paths are hit constantly.

use fp8_rl::rollout::{
    KvBlockManager, KvGeometry, KvPrecision, SharedGrant,
};
use fp8_rl::util::rng::Pcg64;
use fp8_rl::util::units::{Blocks, Tokens};

/// Same prompt pair the model checker uses: slot parity selects the
/// prompt, so slots 0 and 2 share `[1,2,5]` (one full block + a
/// partial tail at block_tokens=2) and slot 1 holds `[1,2,3,4]`
/// (two full blocks sharing the first block's content prefix).
const PROMPTS: [&[i32]; 2] = [&[1, 2, 5], &[1, 2, 3, 4]];

fn prompt_for(slot: usize) -> &'static [i32] {
    PROMPTS[slot % PROMPTS.len()]
}

fn tiny_geometry(block_tokens: usize) -> KvGeometry {
    KvGeometry {
        n_layers: 1,
        n_kv_heads: 1,
        d_head: 2,
        block_tokens,
        precision: KvPrecision::Bf16,
    }
}

/// The model checker's action vocabulary, mirrored 1:1 from
/// `tools/model/src/kv_model.rs::KvAct`.
#[derive(Clone, Copy, Debug)]
enum Op {
    Alloc { slot: usize },
    Append { slot: usize },
    Release { slot: usize },
    FencePreempt,
}

/// Replays a trace against a real manager, asserting
/// `check_invariants()` after every single operation — and basic
/// grant arithmetic on every admission.
struct Harness {
    mgr: KvBlockManager,
    live: Vec<Option<u64>>,
    next_id: u64,
    step: usize,
}

impl Harness {
    fn new(total_blocks: usize, block_tokens: usize, slots: usize) -> Self {
        let mgr = KvBlockManager::new(
            tiny_geometry(block_tokens),
            Blocks::new(total_blocks),
        )
        .expect("valid geometry");
        Harness {
            mgr,
            live: vec![None; slots],
            next_id: 0,
            step: 0,
        }
    }

    fn check(&self, what: &str) {
        if let Err(e) = self.mgr.check_invariants() {
            panic!(
                "invariant broken at step {} after {what}: {e}",
                self.step
            );
        }
    }

    fn grant_sane(&self, g: SharedGrant, prompt: &[i32], what: &str) {
        let total = self.mgr.blocks_for(Tokens::new(prompt.len().max(1)));
        assert_eq!(
            g.shared_blocks.get() + g.new_blocks.get(),
            total.get(),
            "step {}: {what}: grant does not cover the prompt",
            self.step
        );
        assert!(
            g.shared_tokens.get() <= prompt.len(),
            "step {}: {what}: shared more tokens than the prompt has",
            self.step
        );
    }

    fn apply(&mut self, op: Op) {
        match op {
            Op::Alloc { slot } => {
                if self.live[slot].is_some() {
                    // occupied slot: model never emits this; the
                    // randomized driver treats it as a no-op
                    return;
                }
                let prompt = prompt_for(slot);
                self.next_id += 1;
                let before = self.mgr.used_blocks();
                match self.mgr.allocate_shared(
                    self.next_id,
                    Tokens::new(prompt.len()),
                    prompt,
                ) {
                    Some(g) => {
                        self.live[slot] = Some(self.next_id);
                        self.grant_sane(g, prompt, "alloc");
                    }
                    None => {
                        // failed admission must not leak or mutate
                        assert_eq!(
                            before,
                            self.mgr.used_blocks(),
                            "step {}: failed admission changed \
                             used_blocks",
                            self.step
                        );
                    }
                }
                self.check("alloc");
            }
            Op::Append { slot } => {
                if let Some(id) = self.live[slot] {
                    self.mgr
                        .append_token(id)
                        .expect("append on live seq must not Err");
                }
                self.check("append");
            }
            Op::Release { slot } => {
                if let Some(id) = self.live[slot].take() {
                    self.mgr.release(id);
                }
                self.check("release");
            }
            Op::FencePreempt => {
                // An epoch fence with preempt-and-recompute: every
                // in-flight sequence is evicted so its KV is rebuilt
                // under the new weights/scales.
                for slot in 0..self.live.len() {
                    if let Some(id) = self.live[slot].take() {
                        self.mgr.release(id);
                        self.check("fence-preempt release");
                    }
                }
                self.check("fence-preempt");
            }
        }
        self.step += 1;
    }

    fn drain(&mut self) {
        self.apply(Op::FencePreempt);
        assert!(
            self.mgr.used_blocks().is_zero(),
            "blocks leaked after draining every sequence"
        );
        assert_eq!(self.mgr.n_seqs(), 0, "sequences leaked after drain");
    }
}

/// Clean canonical trace lifted from the model checker's exploration:
/// admit every slot (full-prefix hit on slot 2, shared first block on
/// slot 1), append through boundary/COW/in-place paths, then drain
/// through a fence storm and re-admit (ABA: freed block ids get
/// recycled with new contents; the registry must not serve stale
/// entries).
///
/// Generated with:
///   cargo run -p pallas-model -- --model kv --blocks 6 \
///     --block-tokens 2 --slots 3 --appends 1 --allocs 2 \
///     --kv-fences 2 --trace-out kv-clean.trace
#[test]
fn model_seeded_clean_trace_holds_invariants() {
    let mut h = Harness::new(6, 2, 3);
    let trace = [
        Op::Alloc { slot: 0 },
        Op::Alloc { slot: 1 },
        Op::Alloc { slot: 2 },
        Op::Append { slot: 0 },
        Op::Append { slot: 1 },
        Op::Append { slot: 2 },
        Op::FencePreempt,
        Op::Alloc { slot: 0 },
        Op::Alloc { slot: 2 },
        Op::Append { slot: 2 },
        Op::Release { slot: 0 },
        Op::Release { slot: 2 },
        Op::FencePreempt,
    ];
    for op in trace {
        h.apply(op);
    }
    h.drain();
}

/// COW-focused model trace: two sharers of the `[1,2,5]` prompt; an
/// append by one must copy the shared partial tail, not write into
/// it, and releasing in either order must keep refcounts conserved.
///
/// Generated with:
///   cargo run -p pallas-model -- --model kv --blocks 6 \
///     --block-tokens 2 --slots 3 --appends 1 \
///     --trace-out kv-cow.trace
#[test]
fn model_seeded_cow_trace_holds_invariants() {
    let mut h = Harness::new(6, 2, 3);
    let trace = [
        Op::Alloc { slot: 0 },
        Op::Alloc { slot: 2 }, // same prompt -> shares both blocks
        Op::Append { slot: 2 }, // COW: shared tail, rc 2
        Op::Append { slot: 0 }, // now sole owner of the old tail
        Op::Release { slot: 0 },
        Op::Append { slot: 2 },
        Op::Release { slot: 2 },
    ];
    for op in trace {
        h.apply(op);
    }
    h.drain();
}

/// rc-0 purge + ABA reuse: release drops the only reference, the
/// registry entry must purge with the block, and a re-admission that
/// recycles the same block id with a *different* prompt must not hit
/// the stale entry.
///
/// Generated with:
///   cargo run -p pallas-model -- --model kv --blocks 6 \
///     --block-tokens 2 --slots 3 --allocs 2 --kv-fences 1 \
///     --trace-out kv-aba.trace
#[test]
fn model_seeded_aba_trace_holds_invariants() {
    let mut h = Harness::new(6, 2, 3);
    h.apply(Op::Alloc { slot: 0 });
    h.apply(Op::Release { slot: 0 }); // rc->0, purge, blocks recycled
    // slot 1's prompt reuses the freed block ids; a stale registry
    // entry for [1,2,5] would claim its first block wrongly
    h.apply(Op::Alloc { slot: 1 });
    let g = {
        // fresh sharer of [1,2,3,4]: must share on content, and the
        // purged [1,2,5] entry must contribute nothing
        let prompt = prompt_for(1);
        h.next_id += 1;
        let g = h
            .mgr
            .allocate_shared(h.next_id, Tokens::new(prompt.len()), prompt)
            .expect("pool has room");
        h.check("aba re-admission");
        g
    };
    assert_eq!(
        g.shared_tokens.get(),
        prompt_for(1).len(),
        "re-registered prefix should fully share"
    );
    h.mgr.release(h.next_id);
    h.check("aba release");
    h.drain();
}

/// Randomized cancel/preempt storms over a tiny pool. Weighted ops
/// keep the pool near exhaustion so admission failure, COW, boundary
/// growth, purge, and fence preemption interleave densely; the
/// invariants are asserted inside `Harness::apply` after every op.
#[test]
fn randomized_storms_hold_invariants_after_every_op() {
    for seed in 0..8u64 {
        let mut rng = Pcg64::new(0xC0DE_BA5E ^ seed);
        let mut h = Harness::new(6, 2, 3);
        for _ in 0..2000 {
            let slot = (rng.next_u64() % 3) as usize;
            let op = match rng.next_u64() % 10 {
                0..=3 => Op::Alloc { slot },
                4..=7 => Op::Append { slot },
                8 => Op::Release { slot },
                _ => Op::FencePreempt,
            };
            h.apply(op);
        }
        h.drain();
    }
}

/// Storm variant at a different geometry (bigger blocks, more room)
/// so full-block-prefix registration paths dominate instead of the
/// partial-tail path.
#[test]
fn randomized_storms_alternate_geometry() {
    for seed in 0..4u64 {
        let mut rng = Pcg64::new(0xFACE_FEED ^ seed);
        let mut h = Harness::new(8, 4, 3);
        for _ in 0..1500 {
            let slot = (rng.next_u64() % 3) as usize;
            let op = match rng.next_u64() % 8 {
                0..=2 => Op::Alloc { slot },
                3..=5 => Op::Append { slot },
                6 => Op::Release { slot },
                _ => Op::FencePreempt,
            };
            h.apply(op);
        }
        h.drain();
    }
}
