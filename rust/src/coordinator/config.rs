//! Experiment configuration — the knobs every paper figure varies.

use crate::fp8::ScaleFormat;
use crate::sync::CalibStrategy;

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub arch: String,            // dense | moe
    pub rollout_variant: String, // bf16 | fp8lin | kvfp8 | fullfp8 | ...
    pub train_variant: String,   // bf16 | fp8hybrid | fp8e4m3 | ...
    /// token-level TIS clip C; <= 0 disables rollout correction
    pub tis_c: f32,
    /// Masked IS instead of Truncated IS (drop out-of-band tokens)
    pub mis: bool,
    pub calib: CalibStrategy,
    /// weight-sync scale format (Fig 12)
    pub scale_fmt: ScaleFormat,
    /// quantize the MoE router during sync (Fig 6 FP8-router arm)
    pub quantize_router: bool,
    pub steps: usize,
    pub prompts_per_step: usize,
    pub samples_per_prompt: usize,
    pub lr: f32,
    pub ent_coef: f32,
    pub validate_every: usize,
    pub max_new_tokens: usize,
    /// rollout engine replicas; 1 = single in-process engine, >1 =
    /// thread-per-replica pool behind the router (outputs are
    /// bit-identical either way — see rollout::pool; replicas always
    /// load from the same manifest source as the loop's runtime)
    pub rollout_replicas: usize,
    /// continuous streaming admission: requests are submitted into the
    /// running pool as they are built and weight/KV-scale installs
    /// become asynchronous epoch fences, instead of the batch-barrier
    /// generate + ack'd broadcast. Outputs are bit-identical either
    /// way (the epoch fence pins every completion to its submit-time
    /// weights — see rollout::pool); this is purely a throughput /
    /// latency knob. Forces the pool topology even at 1 replica.
    pub rollout_streaming: bool,
    /// cross-step pipelining: number of NEXT-step rollout waves kept in
    /// flight inside the streaming pool while the current step trains.
    /// 0 (default) is the strictly sequential sync->rollout->train loop
    /// (bit-identical to the pre-pipelining driver); >= 1 overlaps
    /// rollout and training so step time approaches max(rollout, train)
    /// instead of their sum. Requires `rollout_streaming` (the session
    /// API) and a `max_epoch_staleness` wide enough for the depth —
    /// `RlLoop::new` checks both up front. NOTE: epoch fences serialize
    /// waves on each replica (a wave decodes only after its
    /// predecessor drains), so depth > 1 buys NO extra overlap over
    /// depth 1 in steady state while linearly increasing staleness —
    /// `RlLoop::new` warns. See DESIGN.md §6.
    pub pipeline_depth: usize,
    /// shared-prefix KV reuse (DESIGN.md §10): GRPO group members
    /// share their prompt's KV blocks copy-on-write, the engine skips
    /// prefill for shared-prefix hits, and the pool routes by prompt
    /// hash so a group lands on one replica. Outputs are bit-identical
    /// either way — this is purely a memory/FLOPs knob.
    pub prefix_sharing: bool,
    /// bounded-staleness window for the TIS/MIS epoch check: a training
    /// batch may contain completions whose behavior-policy epoch tag is
    /// up to this many weight epochs BEHIND the epoch the loop last
    /// synced (never ahead). 0 (default) is the hard same-epoch error
    /// the sequential loop has always enforced; cross-step pipelining
    /// at depth d with e epoch bumps per step needs >= d*e.
    pub max_epoch_staleness: u64,
    pub seed: u64,
    /// task difficulty
    pub max_digits: u32,
    /// cap a+b (Some(9) keeps answers one digit — the fast curriculum)
    pub max_sum: Option<u64>,
}

impl ExperimentConfig {
    /// Load from a JSON config file; only present keys override the
    /// defaults (the config system for scripted experiment sweeps).
    pub fn from_json_file(path: &str) -> crate::util::error::Result<Self> {
        use crate::util::json::Json;
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        let gets = |k: &str, d: &str| -> String {
            j.opt(k)
                .and_then(|v| v.as_str().ok())
                .unwrap_or(d)
                .to_string()
        };
        let mut c = ExperimentConfig::new(
            &gets("name", "config_run"),
            &gets("arch", "dense"),
            &gets("rollout_variant", "bf16"),
            &gets("train_variant", "bf16"),
        );
        let getf = |k: &str, d: f64| -> f64 {
            j.opt(k).and_then(|v| v.as_f64().ok()).unwrap_or(d)
        };
        let getb = |k: &str, d: bool| -> bool {
            j.opt(k).and_then(|v| v.as_bool().ok()).unwrap_or(d)
        };
        c.tis_c = getf("tis_c", c.tis_c as f64) as f32;
        c.mis = getb("mis", c.mis);
        c.steps = getf("steps", c.steps as f64) as usize;
        c.prompts_per_step =
            getf("prompts_per_step", c.prompts_per_step as f64) as usize;
        c.samples_per_prompt =
            getf("samples_per_prompt", c.samples_per_prompt as f64) as usize;
        c.lr = getf("lr", c.lr as f64) as f32;
        c.ent_coef = getf("ent_coef", c.ent_coef as f64) as f32;
        c.validate_every =
            getf("validate_every", c.validate_every as f64) as usize;
        c.max_new_tokens =
            getf("max_new_tokens", c.max_new_tokens as f64) as usize;
        c.rollout_replicas =
            getf("rollout_replicas", c.rollout_replicas as f64) as usize;
        c.rollout_streaming =
            getb("rollout_streaming", c.rollout_streaming);
        c.pipeline_depth =
            getf("pipeline_depth", c.pipeline_depth as f64) as usize;
        c.prefix_sharing = getb("prefix_sharing", c.prefix_sharing);
        c.max_epoch_staleness = getf(
            "max_epoch_staleness",
            c.max_epoch_staleness as f64,
        ) as u64;
        c.seed = getf("seed", c.seed as f64) as u64;
        c.max_digits = getf("max_digits", c.max_digits as f64) as u32;
        if let Some(ms) = j.opt("max_sum") {
            c.max_sum = Some(ms.as_f64()? as u64);
        }
        c.quantize_router = getb("quantize_router", c.quantize_router);
        match gets("scale_fmt", "fp32").as_str() {
            "ue8m0" => c.scale_fmt = ScaleFormat::Ue8m0,
            _ => c.scale_fmt = ScaleFormat::Fp32,
        }
        match gets("calib", "inference").as_str() {
            "trainer" => c.calib = CalibStrategy::TrainerSide,
            _ => c.calib = CalibStrategy::InferenceSide,
        }
        Ok(c)
    }

    pub fn new(name: &str, arch: &str, rollout: &str, train: &str) -> Self {
        ExperimentConfig {
            name: name.to_string(),
            arch: arch.to_string(),
            rollout_variant: rollout.to_string(),
            train_variant: train.to_string(),
            tis_c: 2.0,
            mis: false,
            calib: CalibStrategy::InferenceSide,
            scale_fmt: ScaleFormat::Fp32,
            quantize_router: false,
            steps: 150,
            prompts_per_step: 16,
            samples_per_prompt: 4,
            lr: 3e-4,
            ent_coef: 0.02,
            validate_every: 5,
            max_new_tokens: 8,
            rollout_replicas: 1,
            rollout_streaming: false,
            pipeline_depth: 0,
            prefix_sharing: false,
            max_epoch_staleness: 0,
            seed: 1234,
            max_digits: 2,
            max_sum: None,
        }
    }

    /// Rollout path uses FP8 linears? (drives the weight-sync pipeline)
    pub fn rollout_fp8_linear(&self) -> bool {
        self.rollout_variant.contains("fp8lin")
            || self.rollout_variant.contains("fullfp8")
    }

    pub fn rollout_fp8_kv(&self) -> bool {
        self.rollout_variant.contains("kvfp8")
            || self.rollout_variant.contains("fullfp8")
    }

    /// Weight epochs the rollout engine advances per RL step: one for
    /// the weight sync, plus one when FP8-KV recalibration installs
    /// fresh scales. Cross-step pipelining at depth d therefore trains
    /// on completions exactly `d * epochs_per_step()` epochs stale,
    /// which is the floor `max_epoch_staleness` must cover.
    pub fn epochs_per_step(&self) -> u64 {
        1 + self.rollout_fp8_kv() as u64
    }
}
