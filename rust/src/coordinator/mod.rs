//! L3 coordinator: the RL loop leader (rollout -> weight sync -> train)
//! and the experiment harness driving every paper figure.
pub mod config;
pub mod metrics;
pub mod rlloop;

pub use config::ExperimentConfig;
pub use metrics::{Recorder, StepRecord, CURVE_COLUMNS};
pub use rlloop::RlLoop;
