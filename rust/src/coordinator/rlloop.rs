//! The synchronous RL loop leader — verl's role in the paper (Fig 1):
//! rollout phase -> weight-sync phase -> training phase, once per step,
//! with validation probes and per-step metric recording.
//!
//! Everything precision-related is injected through the experiment
//! config: which decode artifact the engine runs (rollout precision),
//! which train artifact updates the policy (training precision), whether
//! the sync pipeline quantizes (and with which scale format), whether
//! TIS corrects the mismatch, and which calibration strategy refreshes
//! the KV scales.
//!
//! The rollout phase runs behind the [`Rollout`] backend: a single
//! in-process engine by default, or — at `rollout_replicas > 1` or
//! `rollout_streaming` — the streaming
//! [`rollout::pool`](crate::rollout::pool) behind the router, with
//! weights quantized once per step and broadcast to every replica.
//! Outputs are bit-identical either way (per-request sampling streams
//! + deterministic merge), so the serving topology is purely a
//! throughput knob.
//!
//! In streaming mode the weight sync and KV-scale recalibration go out
//! as asynchronous **epoch fences** (`EnginePool::sync_weights` /
//! `sync_kv_scales`) and requests are submitted into the running pool
//! one by one; the loop then checks every completion's epoch tag
//! against the epoch it synced, which is what guarantees the
//! `Completion::logprobs` used as the TIS/MIS denominator were
//! measured under THIS step's behavior policy and not a torn or stale
//! one. A mismatched tag is a hard error, not a silent bias.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::rl::dapo::{Sample, TrainBatch};
use crate::rl::task::{Task, TaskConfig, TOK_PAD};
use crate::rl::trainer::{Trainer, TrainerConfig};
use crate::rollout::{
    factory_like, EngineConfig, EnginePool, HloEngine, PoolConfig,
    Request, Rollout, RoutePolicy, SamplingParams,
};
use crate::runtime::Runtime;
use crate::sync::{CalibStrategy, Calibrator, WeightSync, WeightSyncConfig};
use crate::util::error::{bail, Result};

use super::config::ExperimentConfig;
use super::metrics::{Recorder, StepRecord};

/// Globally unique, monotone request ids. The old scheme —
/// `(pi * n + si) + req_counter * 10_000` — collided as soon as a step
/// produced >= 10_000 requests, silently cross-wiring completions
/// between prompt groups; the bare counter cannot collide and the id ->
/// origin maps below replace the O(n^2) `position()` scans.
fn next_request_id(counter: &mut u64) -> u64 {
    *counter += 1;
    *counter
}

pub struct RlLoop {
    pub cfg: ExperimentConfig,
    rt: Arc<Runtime>,
    task: Task,
    rollout: Rollout,
    trainer: Trainer,
    sync: WeightSync,
    calib: Calibrator,
    pub recorder: Recorder,
    /// last training-batch rows (trainer-side calibration data)
    last_train_rows: Vec<Vec<i32>>,
    req_counter: u64,
    last_val_acc: f64,
}

impl RlLoop {
    pub fn new(rt: Arc<Runtime>, cfg: ExperimentConfig) -> Result<RlLoop> {
        if cfg.rollout_replicas == 0 {
            // don't silently coerce a nonsense config to a single
            // engine — EnginePool::new rejects 0 too
            bail!("rollout_replicas must be >= 1, got 0");
        }
        let engine_cfg = EngineConfig {
            seed: cfg.seed,
            ..EngineConfig::new(&cfg.arch, &cfg.rollout_variant)
        };
        // streaming admission needs the pool's session API, so the
        // knob forces the pool topology even at one replica
        let rollout = if cfg.rollout_replicas > 1 || cfg.rollout_streaming
        {
            Rollout::Pool(EnginePool::new(
                PoolConfig {
                    n_replicas: cfg.rollout_replicas,
                    policy: RoutePolicy::LeastLoaded,
                    engine: engine_cfg,
                },
                // replicas MUST load from the same manifest source as
                // `rt` (which the trainer shares) — a second config
                // knob here could silently train one model while
                // sampling from another
                factory_like(&rt),
            )?)
        } else {
            Rollout::Single(Box::new(HloEngine::new(
                rt.clone(),
                engine_cfg,
            )?))
        };
        let trainer = Trainer::new(
            rt.clone(),
            TrainerConfig {
                lr: cfg.lr,
                tis_c: cfg.tis_c,
                ent_coef: cfg.ent_coef,
                mis: cfg.mis,
                ..TrainerConfig::new(&cfg.arch, &cfg.train_variant)
            },
        )?;
        let sync_cfg = WeightSyncConfig {
            fp8: cfg.rollout_fp8_linear(),
            scale_fmt: cfg.scale_fmt,
            quantize_router: cfg.quantize_router,
            ..WeightSyncConfig::bf16()
        };
        let calib = Calibrator::new(rt.clone(), &cfg.arch, cfg.calib)?;
        let task = Task::new(TaskConfig {
            max_digits: cfg.max_digits,
            max_sum: cfg.max_sum,
            n_validation: 64,
            seed: cfg.seed ^ 0xABCD,
        });
        Ok(RlLoop {
            rollout,
            trainer,
            sync: WeightSync::new(sync_cfg),
            calib,
            task,
            rt,
            cfg,
            recorder: Recorder::default(),
            last_train_rows: Vec::new(),
            req_counter: 0,
            last_val_acc: f64::NAN,
        })
    }

    /// Run the configured number of steps; returns the recorder.
    pub fn run(&mut self) -> Result<()> {
        for step in 0..self.cfg.steps {
            let rec = self.step(step)?;
            if step % 10 == 0 {
                crate::log_info!(
                    "[{}] step {step}: reward={:.3} acc={:.3} kl={:.2e}",
                    self.cfg.name,
                    rec.get("reward"),
                    rec.get("val_accuracy"),
                    rec.get("mismatch_kl"),
                );
            }
            self.recorder.push(rec);
        }
        Ok(())
    }

    /// One full RL iteration (public so figures can interleave probes).
    pub fn step(&mut self, step: usize) -> Result<StepRecord> {
        let streaming = self.cfg.rollout_streaming;
        let mut rec = StepRecord::default();
        rec.set("step", step as f64);

        // ---- phase 1: weight synchronization (paper Fig 1) ----
        // quantized ONCE, then broadcast: every pool replica installs
        // the same Arc'd parameter list
        let t0 = Instant::now();
        let spec = self.rt.manifest.model(&self.cfg.arch)?.clone();
        let (weights, _report) =
            self.sync.run_shared(&spec, self.trainer.params())?;
        match &mut self.rollout {
            Rollout::Pool(p) if streaming => {
                // asynchronous epoch fence: replicas finish any
                // in-flight work under the old weights; this step's
                // submissions are stamped with the new epoch
                p.sync_weights(weights)?;
            }
            r => r.install_weights(weights)?,
        }

        // sample this step's problems first: inference-side calibration
        // uses the upcoming prompts (vLLM forced-recalibration style)
        let problems: Vec<_> = (0..self.cfg.prompts_per_step)
            .map(|_| self.task.sample())
            .collect();

        if self.cfg.rollout_fp8_kv() {
            let rows: Vec<Vec<i32>> = match self.calib.strategy() {
                CalibStrategy::InferenceSide => {
                    problems.iter().map(|p| p.prompt.clone()).collect()
                }
                CalibStrategy::TrainerSide => {
                    if self.last_train_rows.is_empty() {
                        problems.iter().map(|p| p.prompt.clone()).collect()
                    } else {
                        self.last_train_rows.clone()
                    }
                }
            };
            let (ks, vs) = self.calib.recalibrate(
                self.trainer.params(),
                &rows,
                TOK_PAD,
            )?;
            match &mut self.rollout {
                Rollout::Pool(p) if streaming => {
                    p.sync_kv_scales(ks, vs)?;
                }
                r => r.install_kv_scales(ks, vs)?,
            }
        }
        rec.set("sync_s", t0.elapsed().as_secs_f64());

        // ---- phase 2: rollout (generation) ----
        let t1 = Instant::now();
        let n = self.cfg.samples_per_prompt;
        let mut requests = Vec::new();
        // id -> flat (problem, sample) slot, for completion mapping
        let mut origin: BTreeMap<u64, usize> = BTreeMap::new();
        for (pi, p) in problems.iter().enumerate() {
            for si in 0..n {
                let id = next_request_id(&mut self.req_counter);
                origin.insert(id, pi * n + si);
                requests.push(Request {
                    id,
                    prompt: p.prompt.clone(),
                    params: SamplingParams {
                        temperature: 1.0,
                        max_new_tokens: self.cfg.max_new_tokens,
                        ..Default::default()
                    },
                });
            }
        }
        debug_assert_eq!(origin.len(), requests.len());
        let pre = self.rollout.stats()?;
        // the pool's `generate` IS continuous admission since the
        // streaming rewrite (submit-all + mid-decode injection +
        // drain, with all-or-nothing failure accounting) — what the
        // streaming knob changes in this loop is the asynchronous
        // epoch fences above, not the generation call
        let completions = self.rollout.generate(requests)?;
        let post = self.rollout.stats()?;
        // the epoch tag is what makes the TIS/MIS denominator honest:
        // every completion must have been generated under THE weights
        // this step synced — a mismatch means a torn/stale behavior
        // policy, which must fail loudly instead of biasing the
        // importance weights
        let epoch = self.rollout.epoch();
        for c in &completions {
            if c.epoch != epoch {
                bail!(
                    "completion {} is tagged weight epoch {} but the \
                     loop synced epoch {epoch}: its behavior logprobs \
                     would be off-policy for TIS/MIS",
                    c.id,
                    c.epoch
                );
            }
        }
        rec.set("rollout_epoch", epoch as f64);
        rec.set(
            "rollout_streaming",
            self.cfg.rollout_streaming as u8 as f64,
        );
        rec.set(
            "preemptions",
            (post.preemptions - pre.preemptions) as f64,
        );
        rec.set(
            "rollout_tokens",
            (post.tokens_generated - pre.tokens_generated) as f64,
        );
        rec.set("rollout_replicas", self.rollout.n_replicas() as f64);
        rec.set("rollout_s", t1.elapsed().as_secs_f64());

        // map completions back to (problem, group)
        let mut samples: Vec<Sample> = Vec::new();
        for c in completions {
            let idx = *origin
                .get(&c.id)
                .expect("completion for unknown request");
            let pi = idx / n;
            samples.push(Sample {
                problem: problems[pi].clone(),
                completion: c,
                reward: 0.0,
                group: pi,
            });
        }
        crate::rl::dapo::score(&mut samples);

        // ---- phase 3: training (DAPO + TIS) ----
        let t2 = Instant::now();
        let c = &self.rt.manifest.constants;
        let batch = TrainBatch::assemble(
            &samples,
            c.b_train,
            c.t_train,
            1e-4,
            true,
        );
        self.last_train_rows = batch
            .tokens
            .chunks(c.t_train)
            .take(samples.len())
            .map(|r| r.to_vec())
            .collect();
        let metrics = self.trainer.train_step(&batch)?;
        rec.set("train_s", t2.elapsed().as_secs_f64());

        rec.set("reward", batch.mean_reward as f64);
        rec.set("response_len", batch.mean_response_len as f64);
        rec.set("loss", metrics.get("loss") as f64);
        rec.set("mismatch_kl", metrics.get("kl_k3") as f64);
        rec.set("mismatch_kl_k3", metrics.get("kl_k3") as f64);
        rec.set("entropy", metrics.get("entropy") as f64);
        rec.set("grad_norm", metrics.get("grad_norm") as f64);
        rec.set("tis_mean", metrics.get("tis_mean") as f64);
        rec.set(
            "ratio_raw_mean",
            metrics.get("ratio_raw_mean") as f64,
        );
        rec.set("exceed_fc1", metrics.get("exceed_fc1") as f64);
        rec.set("exceed_other", metrics.get("exceed_other") as f64);
        rec.set("exceed_p99", metrics.get("exceed_p99") as f64);

        // ---- validation probe (through the rollout engine, like the
        // paper's online AIME24 eval) ----
        if step % self.cfg.validate_every == 0 {
            self.last_val_acc = self.validate()?;
        }
        rec.set("val_accuracy", self.last_val_acc);
        Ok(rec)
    }

    /// Greedy decoding over the held-out set; exact-match accuracy.
    pub fn validate(&mut self) -> Result<f64> {
        let problems = self.task.validation().to_vec();
        let mut requests = Vec::new();
        let mut origin: BTreeMap<u64, usize> = BTreeMap::new();
        for (i, p) in problems.iter().enumerate() {
            let id = next_request_id(&mut self.req_counter);
            origin.insert(id, i);
            requests.push(Request {
                id,
                prompt: p.prompt.clone(),
                params: SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: self.cfg.max_new_tokens,
                    ..Default::default()
                },
            });
        }
        let completions = self.rollout.generate(requests)?;
        let mut correct = 0usize;
        for c in &completions {
            let idx = origin[&c.id];
            if Task::is_correct(&problems[idx], &c.tokens) {
                correct += 1;
            }
        }
        Ok(correct as f64 / problems.len() as f64)
    }

    /// Aggregate rollout-engine counters (summed across pool replicas).
    pub fn engine_stats(&self) -> Result<crate::rollout::EngineStats> {
        self.rollout.stats()
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeSet;

    use super::next_request_id;

    #[test]
    fn request_ids_never_collide() {
        // regression for the old `(pi*n+si) + counter*10_000` scheme:
        // with 10_001 requests per step, step 0's request 10_000 and
        // step 1's request 0 produced the same id
        const PER_STEP: u64 = 10_001;
        let mut old_counter = 0u64;
        let mut old_ids = BTreeSet::new();
        let mut old_collided = false;
        for _step in 0..2 {
            for j in 0..PER_STEP {
                old_counter += 1;
                if !old_ids.insert(j + old_counter * 10_000) {
                    old_collided = true;
                }
            }
        }
        assert!(old_collided, "old id scheme should collide here");

        let mut counter = 0u64;
        let mut ids = BTreeSet::new();
        for _step in 0..2 {
            for _ in 0..PER_STEP {
                assert!(
                    ids.insert(next_request_id(&mut counter)),
                    "monotone ids must be unique"
                );
            }
        }
    }
}
