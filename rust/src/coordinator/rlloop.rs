//! The synchronous RL loop leader — verl's role in the paper (Fig 1):
//! rollout phase -> weight-sync phase -> training phase, once per step,
//! with validation probes and per-step metric recording.
//!
//! Everything precision-related is injected through the experiment
//! config: which decode artifact the engine runs (rollout precision),
//! which train artifact updates the policy (training precision), whether
//! the sync pipeline quantizes (and with which scale format), whether
//! TIS corrects the mismatch, and which calibration strategy refreshes
//! the KV scales.
//!
//! The rollout phase runs behind the [`Rollout`] backend: a single
//! in-process engine by default, or — at `rollout_replicas > 1` or
//! `rollout_streaming` — the streaming
//! [`rollout::pool`](crate::rollout::pool) behind the router, with
//! weights quantized once per step and broadcast to every replica.
//! Outputs are bit-identical either way (per-request sampling streams
//! + deterministic merge), so the serving topology is purely a
//! throughput knob.
//!
//! In streaming mode the weight sync and KV-scale recalibration go out
//! as asynchronous **epoch fences** (`EnginePool::sync_weights` /
//! `sync_kv_scales`) and requests are submitted into the running pool
//! one by one; the loop then checks every completion's epoch tag
//! against the epoch it synced, which is what guarantees the
//! `Completion::logprobs` used as the TIS/MIS denominator were
//! measured under a behavior policy inside the allowed staleness
//! window. At the default `max_epoch_staleness = 0` a mismatched tag
//! is a hard error, not a silent bias.
//!
//! ## Cross-step pipelining (`pipeline_depth >= 1`, DESIGN.md §6)
//!
//! With streaming on and `pipeline_depth = d`, the loop keeps the next
//! `d` steps' rollout waves IN FLIGHT inside the pool while the current
//! step trains: step N's `train_step` runs concurrently with step
//! N+1's decoding, so wall time per step approaches
//! `max(rollout, train)` instead of `rollout + train`. The wave
//! consumed at step N was submitted after step N-d's fences, so its
//! completions are tagged `d * epochs_per_step` weight epochs behind
//! the epoch the loop just synced — temporal off-policyness that
//! TIS/MIS corrects exactly like precision mismatch, because every
//! completion's `logprobs` ARE the behavior policy of its own tagged
//! epoch (the epoch fence pins them; no completion spans an install).
//! The bounded-staleness check (`epoch ∈ [synced - max_epoch_staleness,
//! synced]`) turns anything outside that window into a hard error.
//! `pipeline_depth = 0` takes the exact sequential code path and stays
//! bit-identical to the pre-pipelining driver.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

use crate::rl::dapo::{Sample, TrainBatch};
use crate::rl::task::{Problem, Task, TaskConfig, TOK_PAD};
use crate::rl::trainer::{Trainer, TrainerConfig};
use crate::rollout::{
    factory_like, Completed, Completion, EngineConfig, EnginePool,
    HloEngine, PoolConfig, Request, Rollout, RoutePolicy,
    SamplingParams,
};
use crate::runtime::Runtime;
use crate::sync::{CalibStrategy, Calibrator, WeightSync, WeightSyncConfig};
use crate::util::clock::WallTimer;
use crate::util::error::{bail, Context, Result};

use super::config::ExperimentConfig;
use super::metrics::{Recorder, StepRecord};

/// Globally unique, monotone request ids. The old scheme —
/// `(pi * n + si) + req_counter * 10_000` — collided as soon as a step
/// produced >= 10_000 requests, silently cross-wiring completions
/// between prompt groups; the bare counter cannot collide and the id ->
/// origin maps below replace the O(n^2) `position()` scans.
fn next_request_id(counter: &mut u64) -> u64 {
    *counter += 1;
    *counter
}

/// One rollout wave submitted into the streaming pool but not yet
/// consumed by a training step (the cross-step pipeline's unit of
/// in-flight work).
struct PendingWave {
    problems: Vec<Problem>,
    /// request id -> flat (problem, sample) slot
    origin: BTreeMap<u64, usize>,
    /// pool weight epoch the wave's requests were stamped with
    submitted_epoch: u64,
    /// when the wave became ELIGIBLE to decode: at submission for the
    /// front of an empty pipeline, else when the previous wave
    /// finished collection (a non-front wave sits parked behind its
    /// epoch fence until the replicas drain, so counting from
    /// submission would overstate concurrency). The gap from here to
    /// collection start is the time the wave decoded concurrently
    /// with sync/train/validation work — the `pipeline_overlap_s`
    /// metric.
    eligible_at: WallTimer,
}

pub struct RlLoop {
    pub cfg: ExperimentConfig,
    rt: Arc<Runtime>,
    task: Task,
    rollout: Rollout,
    trainer: Trainer,
    sync: WeightSync,
    calib: Calibrator,
    pub recorder: Recorder,
    /// last training-batch rows (trainer-side calibration data)
    last_train_rows: Vec<Vec<i32>>,
    req_counter: u64,
    last_val_acc: f64,
    /// waves in flight ahead of training (empty at pipeline_depth 0)
    inflight: VecDeque<PendingWave>,
    /// completions that arrived while collecting a different id set
    /// (a later wave finishing early) — consumed by their own wave
    early: BTreeMap<u64, Completion>,
    /// next wave index to submit == the RL step that will train on it
    next_wave: usize,
}

impl RlLoop {
    pub fn new(rt: Arc<Runtime>, cfg: ExperimentConfig) -> Result<RlLoop> {
        if cfg.rollout_replicas == 0 {
            // don't silently coerce a nonsense config to a single
            // engine — EnginePool::new rejects 0 too
            bail!("rollout_replicas must be >= 1, got 0");
        }
        if cfg.pipeline_depth > 0 {
            // fail at construction, not at step d+1: pipelining rides
            // the pool's session API (partial collection while later
            // waves decode), and the staleness window must admit the
            // exact lag the schedule will produce
            if !cfg.rollout_streaming {
                bail!(
                    "pipeline_depth {} requires rollout_streaming: \
                     cross-step overlap submits into the running pool \
                     while training (enable --streaming / \
                     rollout_streaming)",
                    cfg.pipeline_depth
                );
            }
            if cfg.pipeline_depth > 1 {
                // each wave is submitted behind its step's epoch
                // fence, and a fence applies only once the replicas
                // drain — waves serialize, so extra depth parks more
                // work without adding concurrency
                crate::log_warn!(
                    "pipeline_depth {} > 1: epoch fences serialize \
                     waves, so this adds staleness without adding \
                     overlap beyond depth 1",
                    cfg.pipeline_depth
                );
            }
            let need =
                cfg.pipeline_depth as u64 * cfg.epochs_per_step();
            if cfg.max_epoch_staleness < need {
                bail!(
                    "pipeline_depth {} with {} weight epoch(s) per \
                     step trains on completions {need} epoch(s) stale \
                     — max_epoch_staleness {} would reject every \
                     steady-state batch (set it to at least {need})",
                    cfg.pipeline_depth,
                    cfg.epochs_per_step(),
                    cfg.max_epoch_staleness
                );
            }
        }
        let engine_cfg = EngineConfig {
            seed: cfg.seed,
            prefix_sharing: cfg.prefix_sharing,
            ..EngineConfig::new(&cfg.arch, &cfg.rollout_variant)
        };
        // prefix sharing only pays off when a GRPO group lands on one
        // replica, so the knob also flips placement to content-
        // addressed routing (outputs are placement-invariant either
        // way — per-request RNG streams)
        let policy = if cfg.prefix_sharing {
            RoutePolicy::PrefixAffinity
        } else {
            RoutePolicy::LeastLoaded
        };
        // streaming admission needs the pool's session API, so the
        // knob forces the pool topology even at one replica
        let rollout = if cfg.rollout_replicas > 1 || cfg.rollout_streaming
        {
            Rollout::Pool(EnginePool::new(
                PoolConfig {
                    n_replicas: cfg.rollout_replicas,
                    policy,
                    engine: engine_cfg,
                },
                // replicas MUST load from the same manifest source as
                // `rt` (which the trainer shares) — a second config
                // knob here could silently train one model while
                // sampling from another
                factory_like(&rt),
            )?)
        } else {
            Rollout::Single(Box::new(HloEngine::new(
                rt.clone(),
                engine_cfg,
            )?))
        };
        let trainer = Trainer::new(
            rt.clone(),
            TrainerConfig {
                lr: cfg.lr,
                tis_c: cfg.tis_c,
                ent_coef: cfg.ent_coef,
                mis: cfg.mis,
                ..TrainerConfig::new(&cfg.arch, &cfg.train_variant)
            },
        )?;
        let sync_cfg = WeightSyncConfig {
            fp8: cfg.rollout_fp8_linear(),
            scale_fmt: cfg.scale_fmt,
            quantize_router: cfg.quantize_router,
            ..WeightSyncConfig::bf16()
        };
        let calib = Calibrator::new(rt.clone(), &cfg.arch, cfg.calib)?;
        let task = Task::new(TaskConfig {
            max_digits: cfg.max_digits,
            max_sum: cfg.max_sum,
            n_validation: 64,
            seed: cfg.seed ^ 0xABCD,
        });
        Ok(RlLoop {
            rollout,
            trainer,
            sync: WeightSync::new(sync_cfg),
            calib,
            task,
            rt,
            cfg,
            recorder: Recorder::default(),
            last_train_rows: Vec::new(),
            req_counter: 0,
            last_val_acc: f64::NAN,
            inflight: VecDeque::new(),
            early: BTreeMap::new(),
            next_wave: 0,
        })
    }

    /// Run the configured number of steps; returns the recorder.
    pub fn run(&mut self) -> Result<()> {
        for step in 0..self.cfg.steps {
            let rec = self.step(step)?;
            if step % 10 == 0 {
                crate::log_info!(
                    "[{}] step {step}: reward={:.3} acc={:.3} kl={:.2e}",
                    self.cfg.name,
                    rec.get("reward"),
                    rec.get("val_accuracy"),
                    rec.get("mismatch_kl"),
                );
            }
            self.recorder.push(rec);
        }
        Ok(())
    }

    /// One full RL iteration (public so figures can interleave probes).
    /// At `pipeline_depth >= 1` this is the cross-step pipelined
    /// driver; at 0 it is the sequential loop, bit-identical to the
    /// pre-pipelining behavior.
    pub fn step(&mut self, step: usize) -> Result<StepRecord> {
        if self.cfg.pipeline_depth > 0 {
            return self.step_pipelined(step);
        }
        let streaming = self.cfg.rollout_streaming;
        let mut rec = StepRecord::default();
        rec.set("step", step as f64);

        // ---- phase 1: weight synchronization (paper Fig 1) ----
        // quantized ONCE, then broadcast: every pool replica installs
        // the same Arc'd parameter list
        let t0 = WallTimer::start();
        let spec = self.rt.manifest.model(&self.cfg.arch)?.clone();
        let (weights, _report) =
            self.sync.run_shared(&spec, self.trainer.params())?;
        match &mut self.rollout {
            Rollout::Pool(p) if streaming => {
                // asynchronous epoch fence: replicas finish any
                // in-flight work under the old weights; this step's
                // submissions are stamped with the new epoch
                p.sync_weights(weights)?;
            }
            r => r.install_weights(weights)?,
        }

        // sample this step's problems first: inference-side calibration
        // uses the upcoming prompts (vLLM forced-recalibration style)
        let problems: Vec<_> = (0..self.cfg.prompts_per_step)
            .map(|_| self.task.sample())
            .collect();

        if self.cfg.rollout_fp8_kv() {
            let rows = self.calib_rows(&problems);
            let (ks, vs) = self.calib.recalibrate(
                self.trainer.params(),
                &rows,
                TOK_PAD,
            )?;
            match &mut self.rollout {
                Rollout::Pool(p) if streaming => {
                    p.sync_kv_scales(ks, vs)?;
                }
                r => r.install_kv_scales(ks, vs)?,
            }
        }
        rec.set("sync_s", t0.elapsed_s());

        // ---- phase 2: rollout (generation) ----
        let t1 = WallTimer::start();
        let (requests, origin) = self.build_wave(&problems);
        debug_assert_eq!(origin.len(), requests.len());
        let pre = self.rollout.stats()?;
        // the pool's `generate` IS continuous admission since the
        // streaming rewrite (submit-all + mid-decode injection +
        // drain, with all-or-nothing failure accounting) — what the
        // streaming knob changes in this loop is the asynchronous
        // epoch fences above, not the generation call
        let completions = self.rollout.generate(requests)?;
        let post = self.rollout.stats()?;
        // the epoch tag is what makes the TIS/MIS denominator honest:
        // every completion must have been generated under weights
        // inside the bounded-staleness window ending at THE epoch this
        // step synced (the window is [synced, synced] at the default
        // max_epoch_staleness of 0) — anything outside means a torn or
        // too-stale behavior policy, which must fail loudly instead of
        // biasing the importance weights
        let epoch = self.rollout.epoch();
        let staleness =
            Self::check_epoch_window(&self.cfg, &completions, epoch)?;
        rec.set("rollout_epoch", epoch as f64);
        rec.set("staleness_mean", staleness);
        rec.set("pipeline_depth", 0.0);
        rec.set("pipeline_overlap_s", 0.0);
        rec.set(
            "rollout_streaming",
            self.cfg.rollout_streaming as u8 as f64,
        );
        rec.set(
            "preemptions",
            (post.preemptions - pre.preemptions) as f64,
        );
        rec.set(
            "rollout_tokens",
            (post.tokens_generated - pre.tokens_generated) as f64,
        );
        rec.set("rollout_replicas", self.rollout.n_replicas() as f64);
        rec.set("rollout_s", t1.elapsed_s());

        // ---- phase 3: training (DAPO + TIS) ----
        self.train_phase(&mut rec, &problems, &origin, completions)?;

        // ---- validation probe (through the rollout engine, like the
        // paper's online AIME24 eval) ----
        if step % self.cfg.validate_every == 0 {
            self.last_val_acc = self.validate()?;
        }
        rec.set("val_accuracy", self.last_val_acc);
        Ok(rec)
    }

    /// One pipelined RL iteration (DESIGN.md §6): the sync fences
    /// advance the weight epoch, this step's wave(s) are submitted
    /// into the running pool BEHIND those fences, and then the OLDEST
    /// in-flight wave — which has been decoding since an earlier step,
    /// concurrently with that step's training — is collected and
    /// trained on under the bounded-staleness window. Rollout and
    /// training overlap, so step wall time approaches
    /// max(rollout, train) instead of their sum.
    fn step_pipelined(&mut self, step: usize) -> Result<StepRecord> {
        let mut rec = StepRecord::default();
        rec.set("step", step as f64);

        // ---- phase 1: weight synchronization (asynchronous epoch
        // fences: in-flight waves finish under the weights they were
        // submitted under — the pipeline's whole premise) ----
        let t0 = WallTimer::start();
        let spec = self.rt.manifest.model(&self.cfg.arch)?.clone();
        let (weights, _report) =
            self.sync.run_shared(&spec, self.trainer.params())?;
        self.pool_mut()?.sync_weights(weights)?;

        // sample the problems for every wave submitted this step: one
        // in steady state, pipeline_depth+1 on the first call (the
        // prologue fill), zero once the tail of the run needs no more
        // waves. Sampling order matches the sequential loop: wave k's
        // problems are the k-th batch drawn from the task stream.
        let mut new_waves: Vec<Vec<Problem>> = Vec::new();
        while self.inflight.len() + new_waves.len()
            < self.cfg.pipeline_depth + 1
            && self.next_wave < self.cfg.steps
        {
            new_waves.push(
                (0..self.cfg.prompts_per_step)
                    .map(|_| self.task.sample())
                    .collect(),
            );
            self.next_wave += 1;
        }

        // recalibrate only when fresh waves will run under the new
        // scales: at the tail of the run (no submissions left) the
        // only consumer would be greedy validation, and inference-side
        // calibration would otherwise see an empty (all-PAD) prompt
        // set. Skipping shrinks the epoch increment, which can only
        // tighten — never violate — the staleness window.
        if self.cfg.rollout_fp8_kv() && !new_waves.is_empty() {
            let rows =
                self.calib_rows(new_waves.iter().flatten());
            let (ks, vs) = self.calib.recalibrate(
                self.trainer.params(),
                &rows,
                TOK_PAD,
            )?;
            self.pool_mut()?.sync_kv_scales(ks, vs)?;
        }
        rec.set("sync_s", t0.elapsed_s());

        // ---- phase 2a: submit this step's wave(s) behind the fences ----
        for problems in new_waves {
            self.submit_wave(problems)?;
        }

        // ---- phase 2b: collect the oldest in-flight wave ----
        let wave = match self.inflight.pop_front() {
            Some(w) => w,
            // only reachable when step() is driven past cfg.steps
            None => bail!(
                "pipelined step {step} has no wave to train on — the \
                 configured {} steps are exhausted",
                self.cfg.steps
            ),
        };
        // how long the wave decoded in the background before the loop
        // needed it (sync/train/validation work it overlapped with)
        rec.set("pipeline_overlap_s", wave.eligible_at.elapsed_s());
        let t1 = WallTimer::start();
        let ids: BTreeSet<u64> = wave.origin.keys().copied().collect();
        let completions = self.collect_ids(&ids)?;
        // this wave has drained, so its epoch fence has applied on
        // every replica and the NEXT wave starts decoding about now —
        // that is the moment its overlap clock must start from
        if let Some(front) = self.inflight.front_mut() {
            front.eligible_at.restart();
        }
        // the fence stamping contract: every completion's tag equals
        // the pool epoch its wave was submitted under
        for c in &completions {
            if c.epoch != wave.submitted_epoch {
                bail!(
                    "completion {} is tagged epoch {} but its wave was \
                     submitted under epoch {} — the pool's fence \
                     stamping contract was violated",
                    c.id,
                    c.epoch,
                    wave.submitted_epoch
                );
            }
        }
        let synced = self.rollout.epoch();
        let staleness =
            Self::check_epoch_window(&self.cfg, &completions, synced)?;
        rec.set("rollout_epoch", synced as f64);
        rec.set("staleness_mean", staleness);
        rec.set("pipeline_depth", self.cfg.pipeline_depth as f64);
        rec.set(
            "rollout_streaming",
            self.cfg.rollout_streaming as u8 as f64,
        );
        // per-wave accounting from the completions themselves: engine
        // counter deltas would blend in the concurrently-decoding waves
        rec.set(
            "preemptions",
            completions
                .iter()
                .map(|c| c.preemptions as u64)
                .sum::<u64>() as f64,
        );
        rec.set(
            "rollout_tokens",
            completions.iter().map(|c| c.tokens.len()).sum::<usize>()
                as f64,
        );
        rec.set("rollout_replicas", self.rollout.n_replicas() as f64);
        // the visible stall: how long the loop had to WAIT for the
        // wave on top of what already decoded during earlier phases
        rec.set("rollout_s", t1.elapsed_s());

        // ---- phase 3: training, overlapped by the next wave's decode ----
        self.train_phase(
            &mut rec,
            &wave.problems,
            &wave.origin,
            completions,
        )?;

        if step % self.cfg.validate_every == 0 {
            self.last_val_acc = self.validate()?;
        }
        rec.set("val_accuracy", self.last_val_acc);
        Ok(rec)
    }

    /// Enforce the bounded-staleness epoch window on a training wave:
    /// every completion's behavior epoch must lie in
    /// `[synced - max_epoch_staleness, synced]`. Returns the mean
    /// staleness (`synced - epoch`) over the wave — the
    /// `staleness_mean` metric.
    fn check_epoch_window(
        cfg: &ExperimentConfig,
        completions: &[Completion],
        synced: u64,
    ) -> Result<f64> {
        let mut stale_sum = 0.0f64;
        for c in completions {
            if c.epoch > synced
                || c.epoch + cfg.max_epoch_staleness < synced
            {
                bail!(
                    "completion {} is tagged weight epoch {} but the \
                     loop synced epoch {synced} (allowed window \
                     [{}, {synced}]): its behavior logprobs would be \
                     off-policy beyond what TIS/MIS is configured to \
                     correct",
                    c.id,
                    c.epoch,
                    synced.saturating_sub(cfg.max_epoch_staleness),
                );
            }
            stale_sum += (synced - c.epoch) as f64;
        }
        Ok(stale_sum / completions.len().max(1) as f64)
    }

    /// The streaming pool behind the pipelined helpers (construction
    /// already rejects pipelining on other topologies; this re-checks
    /// so the helpers cannot be misused).
    fn pool_mut(&mut self) -> Result<&mut EnginePool> {
        match &mut self.rollout {
            Rollout::Pool(p) => Ok(p),
            Rollout::Single(_) => bail!(
                "cross-step pipelining requires the streaming engine \
                 pool"
            ),
        }
    }

    /// Rows fed to a KV-scale recalibration, shared by both drivers:
    /// the upcoming prompts for inference-side calibration (vLLM
    /// forced-recalibration style), the last training batch for
    /// trainer-side — falling back to the prompts before the first
    /// train step has produced any rows.
    fn calib_rows<'a>(
        &self,
        upcoming: impl IntoIterator<Item = &'a Problem>,
    ) -> Vec<Vec<i32>> {
        match self.calib.strategy() {
            CalibStrategy::TrainerSide
                if !self.last_train_rows.is_empty() =>
            {
                self.last_train_rows.clone()
            }
            _ => upcoming
                .into_iter()
                .map(|p| p.prompt.clone())
                .collect(),
        }
    }

    /// Build one wave's sampling requests plus its id -> (problem,
    /// sample)-slot origin map — the SAME construction for the
    /// sequential and pipelined drivers, so the two cannot drift (the
    /// depth-0 bit-identity anchor depends on it).
    fn build_wave(
        &mut self,
        problems: &[Problem],
    ) -> (Vec<Request>, BTreeMap<u64, usize>) {
        let n = self.cfg.samples_per_prompt;
        let mut origin: BTreeMap<u64, usize> = BTreeMap::new();
        let mut requests = Vec::with_capacity(problems.len() * n);
        for (pi, p) in problems.iter().enumerate() {
            for si in 0..n {
                let id = next_request_id(&mut self.req_counter);
                origin.insert(id, pi * n + si);
                requests.push(Request {
                    id,
                    prompt: p.prompt.clone(),
                    params: SamplingParams {
                        temperature: 1.0,
                        max_new_tokens: self.cfg.max_new_tokens,
                        ..Default::default()
                    },
                });
            }
        }
        (requests, origin)
    }

    /// Build one wave of sampling requests and submit it into the
    /// running pool, recording it as in flight. The requests are
    /// stamped with the pool's current epoch (the fence contract), so
    /// the wave decodes under exactly the weights most recently synced.
    fn submit_wave(&mut self, problems: Vec<Problem>) -> Result<()> {
        let (requests, origin) = self.build_wave(&problems);
        let pool = self.pool_mut()?;
        for r in requests {
            pool.submit(r)?;
        }
        let submitted_epoch = pool.epoch();
        self.inflight.push_back(PendingWave {
            problems,
            origin,
            submitted_epoch,
            // a non-front wave is parked behind its fence; its clock
            // is restarted when the wave ahead of it drains
            eligible_at: WallTimer::start(),
        });
        Ok(())
    }

    /// Pull resolved tickets from the streaming pool until every id in
    /// `want` has completed, buffering completions that belong to
    /// other (later) waves for their own collection. Returns the
    /// wanted completions sorted by request id.
    fn collect_ids(
        &mut self,
        want: &BTreeSet<u64>,
    ) -> Result<Vec<Completion>> {
        let mut out: Vec<Completion> = Vec::with_capacity(want.len());
        let mut missing: BTreeSet<u64> = want.clone();
        // an earlier collection may already have buffered some of ours
        let buffered: Vec<u64> = missing
            .iter()
            .copied()
            .filter(|id| self.early.contains_key(id))
            .collect();
        for id in buffered {
            if let Some(c) = self.early.remove(&id) {
                out.push(c);
                missing.remove(&id);
            }
        }
        while !missing.is_empty() {
            let resolved = match &mut self.rollout {
                Rollout::Pool(p) => p.next_resolved()?,
                Rollout::Single(_) => bail!(
                    "streaming collection requires the engine pool"
                ),
            };
            match resolved {
                Some(Completed::Done(c)) => {
                    if missing.remove(&c.id) {
                        out.push(c);
                    } else {
                        self.early.insert(c.id, c);
                    }
                }
                Some(Completed::Aborted(id)) => bail!(
                    "request {id} was aborted while the RL loop was \
                     waiting on it"
                ),
                Some(Completed::Failed(id, msg)) => {
                    bail!("request {id} failed: {msg}")
                }
                None => bail!(
                    "the pool ran dry with {} wave requests unresolved",
                    missing.len()
                ),
            }
        }
        out.sort_by_key(|c| c.id);
        Ok(out)
    }

    /// Phase 3 shared by both drivers: map completions back onto their
    /// problems, score, assemble the DAPO batch (threading each
    /// completion's behavior epoch through `TrainBatch::epochs`, so
    /// the TIS/MIS denominators stay attributable to the epoch the
    /// tokens were actually sampled under) and run one train step,
    /// recording the training metrics.
    fn train_phase(
        &mut self,
        rec: &mut StepRecord,
        problems: &[Problem],
        origin: &BTreeMap<u64, usize>,
        completions: Vec<Completion>,
    ) -> Result<()> {
        let n = self.cfg.samples_per_prompt;
        let mut samples: Vec<Sample> = Vec::new();
        for c in completions {
            let idx = *origin
                .get(&c.id)
                .context("completion for unknown request")?;
            let pi = idx / n;
            let problem = problems
                .get(pi)
                .context("completion origin slot out of range")?
                .clone();
            samples.push(Sample {
                problem,
                completion: c,
                reward: 0.0,
                group: pi,
            });
        }
        crate::rl::dapo::score(&mut samples);

        let t2 = WallTimer::start();
        let c = self.rt.manifest.constants.clone();
        let batch = TrainBatch::assemble(
            &samples,
            c.b_train,
            c.t_train,
            1e-4,
            true,
        );
        self.last_train_rows = batch
            .tokens
            .chunks(c.t_train)
            .take(samples.len())
            .map(|r| r.to_vec())
            .collect();
        let metrics = self.trainer.train_step(&batch)?;
        rec.set("train_s", t2.elapsed_s());

        rec.set("reward", batch.mean_reward as f64);
        rec.set("response_len", batch.mean_response_len as f64);
        rec.set("loss", metrics.get("loss") as f64);
        rec.set("mismatch_kl", metrics.get("kl_k3") as f64);
        rec.set("mismatch_kl_k3", metrics.get("kl_k3") as f64);
        rec.set("entropy", metrics.get("entropy") as f64);
        rec.set("grad_norm", metrics.get("grad_norm") as f64);
        rec.set("tis_mean", metrics.get("tis_mean") as f64);
        rec.set(
            "ratio_raw_mean",
            metrics.get("ratio_raw_mean") as f64,
        );
        rec.set("exceed_fc1", metrics.get("exceed_fc1") as f64);
        rec.set("exceed_other", metrics.get("exceed_other") as f64);
        rec.set("exceed_p99", metrics.get("exceed_p99") as f64);
        rec.set(
            "behavior_epoch_min",
            metrics.get("behavior_epoch_min") as f64,
        );
        rec.set(
            "behavior_epoch_max",
            metrics.get("behavior_epoch_max") as f64,
        );
        Ok(())
    }

    /// Greedy decoding over the held-out set; exact-match accuracy.
    pub fn validate(&mut self) -> Result<f64> {
        let problems = self.task.validation().to_vec();
        let mut requests = Vec::new();
        let mut origin: BTreeMap<u64, usize> = BTreeMap::new();
        for (i, p) in problems.iter().enumerate() {
            let id = next_request_id(&mut self.req_counter);
            origin.insert(id, i);
            requests.push(Request {
                id,
                prompt: p.prompt.clone(),
                params: SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: self.cfg.max_new_tokens,
                    ..Default::default()
                },
            });
        }
        // with pipelined waves in flight the barrier generate would
        // (rightly) refuse to mix with the live stream, so the probes
        // ride the session API instead — greedy decoding under the
        // current weights either way, and the wave outputs are
        // admission-interleaving-independent by the pool's
        // determinism contract
        let completions = if self.cfg.pipeline_depth > 0 {
            let ids: BTreeSet<u64> = origin.keys().copied().collect();
            {
                let pool = self.pool_mut()?;
                for r in requests {
                    pool.submit(r)?;
                }
            }
            self.collect_ids(&ids)?
        } else {
            self.rollout.generate(requests)?
        };
        let mut correct = 0usize;
        for c in &completions {
            let idx = *origin
                .get(&c.id)
                .context("validation completion for unknown request")?;
            let p = problems
                .get(idx)
                .context("validation origin index out of range")?;
            if Task::is_correct(p, &c.tokens) {
                correct += 1;
            }
        }
        Ok(correct as f64 / problems.len() as f64)
    }

    /// Aggregate rollout-engine counters (summed across pool replicas).
    pub fn engine_stats(&self) -> Result<crate::rollout::EngineStats> {
        self.rollout.stats()
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeSet;

    use super::next_request_id;

    #[test]
    fn request_ids_never_collide() {
        // regression for the old `(pi*n+si) + counter*10_000` scheme:
        // with 10_001 requests per step, step 0's request 10_000 and
        // step 1's request 0 produced the same id
        const PER_STEP: u64 = 10_001;
        let mut old_counter = 0u64;
        let mut old_ids = BTreeSet::new();
        let mut old_collided = false;
        for _step in 0..2 {
            for j in 0..PER_STEP {
                old_counter += 1;
                if !old_ids.insert(j + old_counter * 10_000) {
                    old_collided = true;
                }
            }
        }
        assert!(old_collided, "old id scheme should collide here");

        let mut counter = 0u64;
        let mut ids = BTreeSet::new();
        for _step in 0..2 {
            for _ in 0..PER_STEP {
                assert!(
                    ids.insert(next_request_id(&mut counter)),
                    "monotone ids must be unique"
                );
            }
        }
    }
}
