//! Per-step metric recording + CSV export for the figure harness.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::csv::CsvWriter;
use crate::util::error::Result;

/// Column order for the training-curve CSVs (matches the paper's panels:
/// accuracy / reward / response length / mismatch KL, plus diagnostics).
pub const CURVE_COLUMNS: &[&str] = &[
    "step",
    "val_accuracy",
    "reward",
    "response_len",
    "mismatch_kl",
    "mismatch_kl_k3",
    "entropy",
    "grad_norm",
    "tis_mean",
    "ratio_raw_mean",
    "exceed_fc1",
    "exceed_other",
    "exceed_p99",
    "preemptions",
    "rollout_replicas",
    "rollout_streaming",
    "rollout_epoch",
    "staleness_mean",
    "behavior_epoch_min",
    "behavior_epoch_max",
    "pipeline_depth",
    "pipeline_overlap_s",
    "rollout_tokens",
    "rollout_s",
    "sync_s",
    "train_s",
    "loss",
];

#[derive(Clone, Debug, Default)]
pub struct StepRecord {
    pub values: BTreeMap<String, f64>,
}

impl StepRecord {
    pub fn set(&mut self, key: &str, v: f64) {
        self.values.insert(key.to_string(), v);
    }

    pub fn get(&self, key: &str) -> f64 {
        *self.values.get(key).unwrap_or(&f64::NAN)
    }
}

#[derive(Default)]
pub struct Recorder {
    pub steps: Vec<StepRecord>,
}

impl Recorder {
    pub fn push(&mut self, rec: StepRecord) {
        self.steps.push(rec);
    }

    pub fn last(&self) -> Option<&StepRecord> {
        self.steps.last()
    }

    /// Write the run as a curve CSV.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = CsvWriter::create(path, CURVE_COLUMNS)?;
        for rec in &self.steps {
            let row: Vec<f64> =
                CURVE_COLUMNS.iter().map(|c| rec.get(c)).collect();
            w.row(&row)?;
        }
        w.flush()
    }

    /// Mean of a column over the last `n` steps (summary reporting).
    pub fn tail_mean(&self, key: &str, n: usize) -> f64 {
        let tail: Vec<f64> = self
            .steps
            .iter()
            .rev()
            .take(n)
            .map(|r| r.get(key))
            .filter(|v| v.is_finite())
            .collect();
        if tail.is_empty() {
            f64::NAN
        } else {
            tail.iter().sum::<f64>() / tail.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let mut rec = Recorder::default();
        for i in 0..10 {
            let mut r = StepRecord::default();
            r.set("step", i as f64);
            r.set("reward", i as f64 * 0.1);
            rec.push(r);
        }
        assert!((rec.tail_mean("reward", 2) - 0.85).abs() < 1e-12);
        assert!(rec.tail_mean("missing", 3).is_nan());
    }

    #[test]
    fn csv_roundtrip() {
        let mut rec = Recorder::default();
        let mut r = StepRecord::default();
        r.set("step", 1.0);
        r.set("reward", 0.5);
        rec.push(r);
        let dir = std::env::temp_dir().join("fp8rl_metrics_test");
        let path = dir.join("curve.csv");
        rec.write_csv(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.starts_with("step,val_accuracy"));
        assert!(s.lines().count() == 2);
        std::fs::remove_dir_all(dir).ok();
    }
}
