//! Happens-before ordering oracle + fence-protocol conformance checker
//! for the streaming engine pool (`rollout::pool`).
//!
//! ## Why
//!
//! The paper's TIS/MIS mismatch correction is only sound if every
//! rollout token is tagged with the exact weight epoch it was sampled
//! under. The pool enforces that with the epoch-fence protocol; this
//! module checks the protocol *as executed*, event by event, instead
//! of trusting module docs:
//!
//! * every completion's epoch tag equals its submit stamp;
//! * no completion spans a weight/KV-scale install (a fence may only
//!   apply on a drained engine);
//! * each fence is acknowledged exactly once, and a quarantined
//!   replica's write-off covers exactly the acks it still owed;
//! * every submitted ticket resolves exactly once, with a
//!   happens-before edge from its submit to its resolution.
//!
//! ## How
//!
//! [`HbRecorder`] keeps one vector clock per actor (actor 0 is the
//! pool/coordinator thread, actor `1 + r` is replica `r`'s worker) and
//! one FIFO queue of clock snapshots per (channel, sender). Hooks in
//! `rollout::pool` call into it on every channel send/recv, fence
//! park/apply/ack, quarantine write-off, and completion delivery; a
//! send pushes the sender's clock onto the channel queue, the matching
//! recv pops and joins it, so clocks encode the real happens-before
//! order (pool→worker channels are single-producer FIFO; the shared
//! event channel is per-sender FIFO and every event names its
//! replica). Each hook also appends a record to a global log whose
//! order — serialized by the recorder lock — is a linearization
//! consistent with every per-actor program order and every
//! send/receive pair.
//!
//! [`HbRecorder::check`] then replays the log against an explicit
//! per-replica fence state machine ([`FenceState`]:
//! `Running → Draining(target) → Installed(epoch)`) and the invariants
//! above. The checker is deliberately paranoid: it re-derives engine
//! epochs from fence events and cross-checks them against what the
//! worker reported, so a pool that "fixes up" a mis-tagged completion
//! cannot slip past it.
//!
//! Hooks are compiled to no-ops unless the `hb` cargo feature is on
//! (it is in the default set; `--no-default-features` builds the
//! zero-cost stubs). The recorder and checker themselves are always
//! compiled so synthetic-log tests (the chaos-worker fixture proving
//! the checker non-vacuous) run everywhere.
//!
//! Send hooks run BEFORE the physical `send` so a queue push always
//! happens-before its pop; a failed send (dead receiver) calls the
//! matching `*_failed` hook, which voids the phantom record — safe
//! because a failed send means the receiver was dropped, so nobody
//! can concurrently pop that queue.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::{Arc, Mutex};

use crate::util::error::{anyhow, bail, Result};

/// Label of one pool→worker message (what rides the per-replica FIFO
/// channel). Used for channel-conformance checking: the worker derives
/// the label from the message it actually received and the recorder
/// compares it against what the pool said it sent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgLabel {
    /// Epoch-ordered submission stamped with the pool epoch.
    Submit { ticket: u64, stamp: u64 },
    /// Epoch fence (weights or KV scales) to the target epoch.
    Fence { target: u64 },
    Abort { ticket: u64 },
    Discard,
    Stats,
    Shutdown,
}

/// Label of one worker→pool event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvLabel {
    Done { ticket: u64, epoch: u64 },
    Aborted { ticket: u64 },
    Failed { ticket: u64 },
    FenceAck { target: u64, ok: bool },
}

/// How a ticket resolved at the pool (delivery to the caller).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResolveKind {
    Done { epoch: u64 },
    Aborted,
    Failed,
}

/// The explicit per-replica fence state machine the checker validates
/// event-by-event. `Installed` is the post-apply state; the next
/// admission returns the replica to `Running`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FenceState {
    /// No fence pending; admissions run under `epoch`.
    Running,
    /// A fence to `target` is parked, waiting for in-flight work to
    /// drain. Nothing may be admitted in this state.
    Draining { target: u64 },
    /// The fence applied; the engine is at `epoch`.
    Installed { epoch: u64 },
}

/// One recorded protocol event (with the acting thread's vector clock
/// snapshot taken at record time).
#[derive(Clone, Debug)]
enum Ev {
    /// Pool sent a submission to replica's worker channel.
    SubmitSend { replica: usize, ticket: u64, stamp: u64 },
    /// Pool sent a fence to replica's worker channel.
    FenceSend { replica: usize, target: u64 },
    /// Pool sent order-insensitive control to replica.
    CtlSend { replica: usize, label: MsgLabel },
    /// Worker ingested one message off its channel.
    WorkerRecv { replica: usize, label: MsgLabel },
    /// Worker admitted a submission into its engine.
    Admit { replica: usize, ticket: u64, engine_epoch: u64 },
    /// Worker parked a fence (entered `Draining`).
    FencePark { replica: usize, target: u64 },
    /// Worker applied a parked fence on a drained engine.
    FenceApply { replica: usize, target: u64, ok: bool, engine_epoch: u64 },
    /// Worker sent one event to the pool.
    EventSend { replica: usize, label: EvLabel },
    /// Pool received one event.
    EventRecv { replica: usize, label: EvLabel },
    /// Pool delivered a resolution to the caller.
    Resolve { ticket: u64, kind: ResolveKind },
    /// Pool (reaper) quarantined a replica, writing off `owed` fence
    /// acks it can never deliver.
    Quarantine { replica: usize, owed: usize },
    /// A send to / from `replica` failed (receiver gone); the
    /// immediately preceding send record on that channel is voided.
    SendFailed { replica: usize },
}

struct Record {
    ev: Ev,
    clock: Vec<u64>,
    voided: bool,
}

/// Queue entry: (sender clock snapshot, label-ish tag, log index of
/// the send record — so a failed send can void it).
struct ChanEntry<L> {
    clock: Vec<u64>,
    label: L,
    log_idx: usize,
}

struct Inner {
    /// actor 0 = pool thread, actor 1+r = replica r's worker.
    clocks: Vec<Vec<u64>>,
    /// pool → worker r FIFO (ToWorker channel).
    wchan: Vec<VecDeque<ChanEntry<MsgLabel>>>,
    /// worker r → pool per-sender FIFO (shared event channel).
    echan: Vec<VecDeque<ChanEntry<EvLabel>>>,
    log: Vec<Record>,
    /// violations detected at record time (channel label mismatches).
    live_violations: Vec<String>,
}

impl Inner {
    fn tick(&mut self, actor: usize) -> Vec<u64> {
        if let Some(c) =
            self.clocks.get_mut(actor).and_then(|c| c.get_mut(actor))
        {
            *c += 1;
        }
        self.clocks.get(actor).cloned().unwrap_or_default()
    }

    fn join(&mut self, actor: usize, other: &[u64]) {
        if let Some(c) = self.clocks.get_mut(actor) {
            for (d, s) in c.iter_mut().zip(other) {
                if *s > *d {
                    *d = *s;
                }
            }
        }
    }

    fn push(&mut self, actor: usize, ev: Ev) -> usize {
        let clock = self.tick(actor);
        self.log.push(Record { ev, clock, voided: false });
        self.log.len() - 1
    }
}

/// `a` happens-before-or-equals `b` (componentwise ≤).
fn clock_leq(a: &[u64], b: &[u64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x <= y)
}

const POOL: usize = 0;

/// The happens-before recorder: one per traced pool session. Cheap
/// enough to leave on in tests; production pools run untraced.
pub struct HbRecorder {
    n_replicas: usize,
    inner: Mutex<Inner>,
}

impl HbRecorder {
    pub fn new(n_replicas: usize) -> Arc<HbRecorder> {
        let n_actors = n_replicas + 1;
        Arc::new(HbRecorder {
            n_replicas,
            inner: Mutex::new(Inner {
                clocks: vec![vec![0; n_actors]; n_actors],
                wchan: (0..n_replicas).map(|_| VecDeque::new()).collect(),
                echan: (0..n_replicas).map(|_| VecDeque::new()).collect(),
                log: Vec::new(),
                live_violations: Vec::new(),
            }),
        })
    }

    pub fn n_replicas(&self) -> usize {
        self.n_replicas
    }

    fn with<R>(&self, f: impl FnOnce(&mut Inner) -> R) -> Option<R> {
        // a poisoned lock means a hook panicked; recording stops and
        // check() reports the poisoning instead of half a log
        self.inner.lock().ok().map(|mut g| f(&mut g))
    }

    // ---- pool-side send hooks (call BEFORE the physical send) ----

    fn send_to_worker(&self, replica: usize, ev: Ev, label: MsgLabel) {
        self.with(|g| {
            let idx = g.push(POOL, ev);
            let clock =
                g.clocks.get(POOL).cloned().unwrap_or_default();
            if let Some(q) = g.wchan.get_mut(replica) {
                q.push_back(ChanEntry { clock, label, log_idx: idx });
            }
        });
    }

    pub fn submit_send(&self, replica: usize, ticket: u64, stamp: u64) {
        self.send_to_worker(
            replica,
            Ev::SubmitSend { replica, ticket, stamp },
            MsgLabel::Submit { ticket, stamp },
        );
    }

    pub fn fence_send(&self, replica: usize, target: u64) {
        self.send_to_worker(
            replica,
            Ev::FenceSend { replica, target },
            MsgLabel::Fence { target },
        );
    }

    pub fn ctl_send(&self, replica: usize, label: MsgLabel) {
        self.send_to_worker(replica, Ev::CtlSend { replica, label }, label);
    }

    /// The pool's last send to `replica` failed (worker gone): void
    /// its record and pop the phantom queue entry. Safe: a failed
    /// send means the receiver was dropped, so no concurrent pop.
    pub fn send_failed(&self, replica: usize) {
        self.with(|g| {
            if let Some(e) =
                g.wchan.get_mut(replica).and_then(|q| q.pop_back())
            {
                if let Some(r) = g.log.get_mut(e.log_idx) {
                    r.voided = true;
                }
            }
            g.push(POOL, Ev::SendFailed { replica });
        });
    }

    // ---- worker-side hooks ----

    /// Worker `replica` ingested one message; `label` is derived from
    /// the message it actually received and checked against the
    /// channel queue (FIFO conformance).
    pub fn worker_recv(&self, replica: usize, label: MsgLabel) {
        self.with(|g| {
            let popped =
                g.wchan.get_mut(replica).and_then(|q| q.pop_front());
            match popped {
                Some(e) => {
                    if e.label != label {
                        g.live_violations.push(format!(
                            "replica {replica}: channel FIFO breach — \
                             pool sent {:?}, worker received {label:?}",
                            e.label
                        ));
                    }
                    g.join(replica + 1, &e.clock);
                }
                None => g.live_violations.push(format!(
                    "replica {replica}: received {label:?} with no \
                     recorded send (untracked producer?)"
                )),
            }
            g.push(replica + 1, Ev::WorkerRecv { replica, label });
        });
    }

    pub fn admit(&self, replica: usize, ticket: u64, engine_epoch: u64) {
        self.with(|g| {
            g.push(replica + 1, Ev::Admit { replica, ticket, engine_epoch });
        });
    }

    pub fn fence_park(&self, replica: usize, target: u64) {
        self.with(|g| {
            g.push(replica + 1, Ev::FencePark { replica, target });
        });
    }

    pub fn fence_apply(
        &self,
        replica: usize,
        target: u64,
        ok: bool,
        engine_epoch: u64,
    ) {
        self.with(|g| {
            g.push(
                replica + 1,
                Ev::FenceApply { replica, target, ok, engine_epoch },
            );
        });
    }

    pub fn event_send(&self, replica: usize, label: EvLabel) {
        self.with(|g| {
            let idx =
                g.push(replica + 1, Ev::EventSend { replica, label });
            let clock =
                g.clocks.get(replica + 1).cloned().unwrap_or_default();
            if let Some(q) = g.echan.get_mut(replica) {
                q.push_back(ChanEntry { clock, label, log_idx: idx });
            }
        });
    }

    /// Worker's event send failed (pool hung up): void the record.
    pub fn event_send_failed(&self, replica: usize) {
        self.with(|g| {
            if let Some(e) =
                g.echan.get_mut(replica).and_then(|q| q.pop_back())
            {
                if let Some(r) = g.log.get_mut(e.log_idx) {
                    r.voided = true;
                }
            }
            g.push(replica + 1, Ev::SendFailed { replica });
        });
    }

    // ---- pool-side receive / delivery hooks ----

    pub fn event_recv(&self, replica: usize, label: EvLabel) {
        self.with(|g| {
            let popped =
                g.echan.get_mut(replica).and_then(|q| q.pop_front());
            match popped {
                Some(e) => {
                    if e.label != label {
                        g.live_violations.push(format!(
                            "replica {replica}: event FIFO breach — \
                             worker sent {:?}, pool received {label:?}",
                            e.label
                        ));
                    }
                    g.join(POOL, &e.clock);
                }
                None => g.live_violations.push(format!(
                    "pool received {label:?} from replica {replica} \
                     with no recorded send"
                )),
            }
            g.push(POOL, Ev::EventRecv { replica, label });
        });
    }

    pub fn resolve(&self, ticket: u64, kind: ResolveKind) {
        self.with(|g| {
            g.push(POOL, Ev::Resolve { ticket, kind });
        });
    }

    pub fn quarantine(&self, replica: usize, owed: usize) {
        self.with(|g| {
            g.push(POOL, Ev::Quarantine { replica, owed });
        });
    }

    // ---- the conformance checker ----

    /// Replay the log against the fence state machine and the protocol
    /// invariants. `Ok(report)` only if every invariant held.
    pub fn check(&self) -> Result<HbReport> {
        let g = self
            .inner
            .lock()
            .map_err(|_| anyhow!("hb recorder lock poisoned"))?;
        let mut v: Vec<String> = g.live_violations.clone();
        let mut rep: Vec<ReplicaState> = (0..self.n_replicas)
            .map(|_| ReplicaState::new())
            .collect();
        // ticket -> latest (stamp, submit clock, replica)
        let mut submits: BTreeMap<u64, (u64, Vec<u64>, usize)> =
            BTreeMap::new();
        let mut resolves: BTreeMap<u64, usize> = BTreeMap::new();
        let mut n_fences = 0usize;
        for r in g.log.iter().filter(|r| !r.voided) {
            check_event(r, &mut rep, &mut submits, &mut resolves, &mut v);
            if matches!(r.ev, Ev::FenceSend { .. }) {
                n_fences += 1;
            }
        }
        // end-of-log obligations
        for (ticket, (_, _, _)) in &submits {
            match resolves.get(ticket).copied().unwrap_or(0) {
                1 => {}
                0 => v.push(format!(
                    "ticket {ticket}: submitted but never resolved"
                )),
                n => v.push(format!(
                    "ticket {ticket}: resolved {n} times"
                )),
            }
        }
        for (r, st) in rep.iter().enumerate() {
            if st.quarantined {
                continue; // its missing acks were written off
            }
            if st.acks_recvd < st.fences_sent {
                v.push(format!(
                    "replica {r}: {} fence(s) sent but only {} \
                     acknowledged (and the replica was never \
                     quarantined)",
                    st.fences_sent, st.acks_recvd
                ));
            }
        }
        if v.is_empty() {
            Ok(HbReport {
                events: g.log.len(),
                tickets: submits.len(),
                fences: n_fences,
            })
        } else {
            v.truncate(16);
            bail!(
                "hb conformance check failed ({} violation(s)):\n  {}",
                v.len(),
                v.join("\n  ")
            )
        }
    }
}

/// Summary of a clean session (for non-vacuity assertions in tests).
#[derive(Clone, Copy, Debug)]
pub struct HbReport {
    /// total recorded protocol events
    pub events: usize,
    /// distinct submitted tickets
    pub tickets: usize,
    /// fence messages sent (across all replicas)
    pub fences: usize,
}

struct ReplicaState {
    state: FenceState,
    epoch: u64,
    /// last fence target this replica parked (targets are global and
    /// broadcast, so per replica they increase by exactly one)
    last_target: u64,
    /// admitted-but-not-yet-reported tickets, with admission epoch
    inflight: BTreeMap<u64, u64>,
    /// fence targets this worker has applied (ack bookkeeping)
    applied: BTreeSet<u64>,
    acked: BTreeSet<u64>,
    /// pool-side counters for the quarantine write-off check
    fences_sent: usize,
    acks_recvd: usize,
    quarantined: bool,
}

impl ReplicaState {
    fn new() -> ReplicaState {
        ReplicaState {
            state: FenceState::Running,
            epoch: 0,
            last_target: 0,
            inflight: BTreeMap::new(),
            applied: BTreeSet::new(),
            acked: BTreeSet::new(),
            fences_sent: 0,
            acks_recvd: 0,
            quarantined: false,
        }
    }
}

fn check_event(
    rec: &Record,
    rep: &mut [ReplicaState],
    submits: &mut BTreeMap<u64, (u64, Vec<u64>, usize)>,
    resolves: &mut BTreeMap<u64, usize>,
    v: &mut Vec<String>,
) {
    match &rec.ev {
        Ev::SubmitSend { replica, ticket, stamp } => {
            submits.insert(
                *ticket,
                (*stamp, rec.clock.clone(), *replica),
            );
        }
        Ev::FenceSend { replica, target: _ } => {
            if let Some(st) = rep.get_mut(*replica) {
                st.fences_sent += 1;
            }
        }
        Ev::CtlSend { .. } | Ev::WorkerRecv { .. } | Ev::SendFailed { .. } => {}
        Ev::Admit { replica, ticket, engine_epoch } => {
            let Some(st) = rep.get_mut(*replica) else { return };
            if let FenceState::Draining { target } = st.state {
                v.push(format!(
                    "replica {replica}: admitted ticket {ticket} while \
                     draining toward fence {target} — admission must \
                     not pass a parked fence"
                ));
            }
            st.state = FenceState::Running;
            if *engine_epoch != st.epoch {
                v.push(format!(
                    "replica {replica}: admit of {ticket} reports \
                     engine epoch {engine_epoch} but fences put it at \
                     {}",
                    st.epoch
                ));
            }
            match submits.get(ticket) {
                None => v.push(format!(
                    "replica {replica}: admitted ticket {ticket} that \
                     was never submitted"
                )),
                Some((stamp, sclock, _)) => {
                    if stamp != engine_epoch {
                        v.push(format!(
                            "replica {replica}: ticket {ticket} \
                             stamped for epoch {stamp} admitted at \
                             engine epoch {engine_epoch}"
                        ));
                    }
                    if !clock_leq(sclock, &rec.clock) {
                        v.push(format!(
                            "replica {replica}: admit of {ticket} is \
                             not happens-after its submit"
                        ));
                    }
                }
            }
            st.inflight.insert(*ticket, *engine_epoch);
        }
        Ev::FencePark { replica, target } => {
            let Some(st) = rep.get_mut(*replica) else { return };
            if let FenceState::Draining { target: t } = st.state {
                v.push(format!(
                    "replica {replica}: parked fence {target} while \
                     fence {t} is still draining"
                ));
            }
            if *target != st.last_target + 1 {
                v.push(format!(
                    "replica {replica}: fence targets must be \
                     consecutive; parked {target} after {}",
                    st.last_target
                ));
            }
            st.last_target = *target;
            st.state = FenceState::Draining { target: *target };
        }
        Ev::FenceApply { replica, target, ok, engine_epoch } => {
            let Some(st) = rep.get_mut(*replica) else { return };
            if st.state != (FenceState::Draining { target: *target }) {
                v.push(format!(
                    "replica {replica}: applied fence {target} from \
                     state {:?} (must be Draining {{ {target} }})",
                    st.state
                ));
            }
            if !st.inflight.is_empty() {
                v.push(format!(
                    "replica {replica}: installed epoch {target} with \
                     {} ticket(s) still in flight — a fence may only \
                     apply on a drained engine",
                    st.inflight.len()
                ));
            }
            if *ok && *engine_epoch != *target {
                v.push(format!(
                    "replica {replica}: fence {target} reported ok \
                     but the engine is at {engine_epoch}"
                ));
            }
            st.epoch = *engine_epoch;
            st.applied.insert(*target);
            st.state = if *ok {
                FenceState::Installed { epoch: *target }
            } else {
                FenceState::Running
            };
        }
        Ev::EventSend { replica, label } => {
            let Some(st) = rep.get_mut(*replica) else { return };
            match label {
                EvLabel::Done { ticket, epoch } => {
                    match st.inflight.remove(ticket) {
                        None => v.push(format!(
                            "replica {replica}: completion for ticket \
                             {ticket} that was never admitted"
                        )),
                        Some(admit_epoch) => {
                            if *epoch != admit_epoch {
                                v.push(format!(
                                    "replica {replica}: ticket {ticket} \
                                     admitted at epoch {admit_epoch} \
                                     but completed tagged {epoch} — \
                                     the completion spans an install"
                                ));
                            }
                        }
                    }
                    if *epoch != st.epoch {
                        v.push(format!(
                            "replica {replica}: ticket {ticket} tagged \
                             epoch {epoch} but the engine is at {}",
                            st.epoch
                        ));
                    }
                    let stamp =
                        submits.get(ticket).map(|(s, _, _)| *s);
                    if stamp != Some(*epoch) {
                        v.push(format!(
                            "replica {replica}: ticket {ticket} tagged \
                             epoch {epoch} but its submit stamp is \
                             {stamp:?}"
                        ));
                    }
                }
                EvLabel::Aborted { ticket }
                | EvLabel::Failed { ticket } => {
                    // cancelled mid-flight, or never admitted
                    // (backlogged / rejected) — both legal
                    st.inflight.remove(ticket);
                }
                EvLabel::FenceAck { target, ok: _ } => {
                    if !st.applied.contains(target) {
                        v.push(format!(
                            "replica {replica}: acknowledged fence \
                             {target} without applying it"
                        ));
                    }
                    if !st.acked.insert(*target) {
                        v.push(format!(
                            "replica {replica}: fence {target} \
                             acknowledged more than once"
                        ));
                    }
                }
            }
        }
        Ev::EventRecv { replica, label } => {
            if let EvLabel::FenceAck { .. } = label {
                if let Some(st) = rep.get_mut(*replica) {
                    st.acks_recvd += 1;
                }
            }
        }
        Ev::Resolve { ticket, kind } => {
            let n = resolves.entry(*ticket).or_insert(0);
            *n += 1;
            match submits.get(ticket) {
                None => v.push(format!(
                    "ticket {ticket} resolved without a recorded \
                     submit"
                )),
                Some((stamp, sclock, _)) => {
                    if !clock_leq(sclock, &rec.clock) {
                        v.push(format!(
                            "ticket {ticket}: resolve is not \
                             happens-after its submit"
                        ));
                    }
                    if let ResolveKind::Done { epoch } = kind {
                        if epoch != stamp {
                            v.push(format!(
                                "ticket {ticket}: delivered with epoch \
                                 {epoch} but submitted under stamp \
                                 {stamp}"
                            ));
                        }
                    }
                }
            }
        }
        Ev::Quarantine { replica, owed } => {
            let Some(st) = rep.get_mut(*replica) else { return };
            st.quarantined = true;
            let expect =
                st.fences_sent.saturating_sub(st.acks_recvd);
            if *owed != expect {
                v.push(format!(
                    "replica {replica}: quarantine wrote off {owed} \
                     fence ack(s) but {expect} were owed"
                ));
            }
        }
    }
}

// ---- the pool-facing handle ----
//
// `HbHandle` is what `EnginePool` holds and threads into its workers.
// With the `hb` feature off it is an empty struct and every hook is a
// literal no-op; with it on, an untraced handle costs one branch.

/// Tracing handle attached to an [`crate::rollout::EnginePool`] at
/// construction ([`crate::rollout::EnginePool::new_traced`]).
#[derive(Clone, Default)]
pub struct HbHandle {
    #[cfg(feature = "hb")]
    rec: Option<Arc<HbRecorder>>,
}

impl HbHandle {
    /// A handle that records into `rec` (no-op if the `hb` feature is
    /// off — the recorder then simply stays empty).
    pub fn traced(rec: Arc<HbRecorder>) -> HbHandle {
        #[cfg(feature = "hb")]
        {
            HbHandle { rec: Some(rec) }
        }
        #[cfg(not(feature = "hb"))]
        {
            let _ = rec;
            HbHandle {}
        }
    }

    /// Replicas the attached recorder was sized for (None = untraced).
    pub fn traced_replicas(&self) -> Option<usize> {
        #[cfg(feature = "hb")]
        {
            self.rec.as_deref().map(HbRecorder::n_replicas)
        }
        #[cfg(not(feature = "hb"))]
        {
            None
        }
    }

    /// Run the conformance checker on the attached recorder.
    /// `Ok(None)` when untraced (or the `hb` feature is off).
    pub fn verify(&self) -> Result<Option<HbReport>> {
        #[cfg(feature = "hb")]
        {
            match self.rec.as_deref() {
                Some(r) => r.check().map(Some),
                None => Ok(None),
            }
        }
        #[cfg(not(feature = "hb"))]
        {
            Ok(None)
        }
    }
}

/// Generates the forwarding hook methods: with the `hb` feature they
/// forward to the recorder (if any); without it they compile to
/// empty inlined bodies.
macro_rules! hb_hooks {
    ($($(#[$doc:meta])* fn $name:ident($($arg:ident: $ty:ty),*);)*) => {
        impl HbHandle {
            $(
                $(#[$doc])*
                #[inline]
                pub fn $name(&self, $($arg: $ty),*) {
                    #[cfg(feature = "hb")]
                    if let Some(r) = self.rec.as_deref() {
                        r.$name($($arg),*);
                    }
                    #[cfg(not(feature = "hb"))]
                    {
                        $(let _ = $arg;)*
                    }
                }
            )*
        }
    };
}

hb_hooks! {
    /// Pool is about to send a submission to `replica`.
    fn submit_send(replica: usize, ticket: u64, stamp: u64);
    /// Pool is about to send a fence to `replica`.
    fn fence_send(replica: usize, target: u64);
    /// Pool is about to send order-insensitive control to `replica`.
    fn ctl_send(replica: usize, label: MsgLabel);
    /// The pool's last send to `replica` failed (worker gone).
    fn send_failed(replica: usize);
    /// Worker ingested one message (label derived from what arrived).
    fn worker_recv(replica: usize, label: MsgLabel);
    /// Worker admitted a submission into its engine.
    fn admit(replica: usize, ticket: u64, engine_epoch: u64);
    /// Worker parked a fence, entering `Draining`.
    fn fence_park(replica: usize, target: u64);
    /// Worker applied a parked fence.
    fn fence_apply(replica: usize, target: u64, ok: bool, engine_epoch: u64);
    /// Worker is about to send one event to the pool.
    fn event_send(replica: usize, label: EvLabel);
    /// The worker's event send failed (pool hung up).
    fn event_send_failed(replica: usize);
    /// Pool received one event off the shared channel.
    fn event_recv(replica: usize, label: EvLabel);
    /// Pool delivered a resolution to the caller.
    fn resolve(ticket: u64, kind: ResolveKind);
    /// Pool quarantined `replica`, writing off `owed` fence acks.
    fn quarantine(replica: usize, owed: usize);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the recorder through one clean single-replica session:
    /// submit → admit → done → resolve, fence → park → apply → ack,
    /// post-fence submit at the new stamp.
    fn clean_session(rec: &HbRecorder) {
        rec.submit_send(0, 1, 0);
        rec.worker_recv(0, MsgLabel::Submit { ticket: 1, stamp: 0 });
        rec.admit(0, 1, 0);
        rec.event_send(0, EvLabel::Done { ticket: 1, epoch: 0 });
        rec.event_recv(0, EvLabel::Done { ticket: 1, epoch: 0 });
        rec.resolve(1, ResolveKind::Done { epoch: 0 });
        rec.fence_send(0, 1);
        rec.worker_recv(0, MsgLabel::Fence { target: 1 });
        rec.fence_park(0, 1);
        rec.fence_apply(0, 1, true, 1);
        rec.event_send(0, EvLabel::FenceAck { target: 1, ok: true });
        rec.event_recv(0, EvLabel::FenceAck { target: 1, ok: true });
        rec.submit_send(0, 2, 1);
        rec.worker_recv(0, MsgLabel::Submit { ticket: 2, stamp: 1 });
        rec.admit(0, 2, 1);
        rec.event_send(0, EvLabel::Done { ticket: 2, epoch: 1 });
        rec.event_recv(0, EvLabel::Done { ticket: 2, epoch: 1 });
        rec.resolve(2, ResolveKind::Done { epoch: 1 });
    }

    #[test]
    fn clean_session_passes() {
        let rec = HbRecorder::new(1);
        clean_session(&rec);
        let rep = rec.check().expect("clean session must pass");
        assert_eq!(rep.tickets, 2);
        assert_eq!(rep.fences, 1);
        assert!(rep.events >= 18, "got {}", rep.events);
    }

    #[test]
    fn chaos_worker_installing_without_draining_is_flagged() {
        // the non-vacuity fixture from the issue: a broken worker that
        // applies a fence while a ticket is still in flight, then tags
        // the straggler's completion with the NEW epoch
        let rec = HbRecorder::new(1);
        rec.submit_send(0, 7, 0);
        rec.worker_recv(0, MsgLabel::Submit { ticket: 7, stamp: 0 });
        rec.admit(0, 7, 0);
        rec.fence_send(0, 1);
        rec.worker_recv(0, MsgLabel::Fence { target: 1 });
        rec.fence_park(0, 1);
        // CHAOS: install with ticket 7 still in flight
        rec.fence_apply(0, 1, true, 1);
        rec.event_send(0, EvLabel::FenceAck { target: 1, ok: true });
        rec.event_recv(0, EvLabel::FenceAck { target: 1, ok: true });
        // the straggler finishes under the torn install, mis-tagged
        rec.event_send(0, EvLabel::Done { ticket: 7, epoch: 1 });
        rec.event_recv(0, EvLabel::Done { ticket: 7, epoch: 1 });
        rec.resolve(7, ResolveKind::Done { epoch: 1 });
        let err = rec.check().expect_err("chaos must be flagged");
        let msg = err.to_string();
        assert!(msg.contains("still in flight"), "{msg}");
        assert!(msg.contains("spans an install"), "{msg}");
        assert!(msg.contains("submit stamp"), "{msg}");
    }

    #[test]
    fn admission_past_a_parked_fence_is_flagged() {
        let rec = HbRecorder::new(1);
        rec.fence_send(0, 1);
        rec.worker_recv(0, MsgLabel::Fence { target: 1 });
        rec.fence_park(0, 1);
        rec.submit_send(0, 3, 1);
        rec.worker_recv(0, MsgLabel::Submit { ticket: 3, stamp: 1 });
        // CHAOS: admitted while draining (must have been backlogged)
        rec.admit(0, 3, 0);
        let err = rec.check().expect_err("must flag");
        assert!(err.to_string().contains("parked fence"), "{err}");
    }

    #[test]
    fn double_ack_and_unapplied_ack_are_flagged() {
        let rec = HbRecorder::new(1);
        rec.fence_send(0, 1);
        rec.worker_recv(0, MsgLabel::Fence { target: 1 });
        rec.fence_park(0, 1);
        rec.fence_apply(0, 1, true, 1);
        rec.event_send(0, EvLabel::FenceAck { target: 1, ok: true });
        rec.event_send(0, EvLabel::FenceAck { target: 1, ok: true });
        rec.event_recv(0, EvLabel::FenceAck { target: 1, ok: true });
        rec.event_recv(0, EvLabel::FenceAck { target: 1, ok: true });
        let err = rec.check().expect_err("must flag the double ack");
        assert!(
            err.to_string().contains("more than once"),
            "{err}"
        );
        let rec2 = HbRecorder::new(1);
        rec2.event_send(0, EvLabel::FenceAck { target: 5, ok: true });
        let err2 = rec2.check().expect_err("ack without apply");
        assert!(
            err2.to_string().contains("without applying"),
            "{err2}"
        );
    }

    #[test]
    fn unresolved_and_double_resolved_tickets_are_flagged() {
        let rec = HbRecorder::new(1);
        rec.submit_send(0, 4, 0);
        let err = rec.check().expect_err("unresolved must flag");
        assert!(err.to_string().contains("never resolved"), "{err}");

        let rec2 = HbRecorder::new(1);
        rec2.submit_send(0, 4, 0);
        rec2.worker_recv(0, MsgLabel::Submit { ticket: 4, stamp: 0 });
        rec2.admit(0, 4, 0);
        rec2.event_send(0, EvLabel::Done { ticket: 4, epoch: 0 });
        rec2.event_recv(0, EvLabel::Done { ticket: 4, epoch: 0 });
        rec2.resolve(4, ResolveKind::Done { epoch: 0 });
        rec2.resolve(4, ResolveKind::Done { epoch: 0 });
        let err2 = rec2.check().expect_err("double resolve must flag");
        assert!(err2.to_string().contains("resolved 2 times"), "{err2}");
    }

    #[test]
    fn channel_label_mismatch_is_flagged() {
        let rec = HbRecorder::new(1);
        rec.submit_send(0, 9, 0);
        // the worker claims it received an abort: FIFO breach
        rec.worker_recv(0, MsgLabel::Abort { ticket: 9 });
        rec.worker_recv(0, MsgLabel::Shutdown); // and an unsent recv
        let err = rec.check().expect_err("must flag");
        let msg = err.to_string();
        assert!(msg.contains("FIFO breach"), "{msg}");
        assert!(msg.contains("no recorded send"), "{msg}");
    }

    #[test]
    fn quarantine_write_off_must_match_owed_acks() {
        // replica dies with one un-acked fence: writing off exactly 1
        // passes; writing off 2 is a violation
        let ok = HbRecorder::new(1);
        ok.submit_send(0, 1, 0);
        ok.fence_send(0, 1);
        ok.quarantine(0, 1);
        ok.resolve(1, ResolveKind::Failed);
        ok.check().expect("exact write-off passes");

        let bad = HbRecorder::new(1);
        bad.fence_send(0, 1);
        bad.quarantine(0, 2);
        let err = bad.check().expect_err("over-write-off must flag");
        assert!(err.to_string().contains("wrote off 2"), "{err}");
    }

    #[test]
    fn voided_sends_are_ignored_by_the_checker() {
        // a submit whose physical send failed (dead worker) is voided
        // and must not count as an unresolved ticket
        let rec = HbRecorder::new(2);
        rec.submit_send(0, 5, 0);
        rec.send_failed(0);
        rec.submit_send(1, 5, 0); // re-routed to the healthy replica
        rec.worker_recv(1, MsgLabel::Submit { ticket: 5, stamp: 0 });
        rec.admit(1, 5, 0);
        rec.event_send(1, EvLabel::Done { ticket: 5, epoch: 0 });
        rec.event_recv(1, EvLabel::Done { ticket: 5, epoch: 0 });
        rec.resolve(5, ResolveKind::Done { epoch: 0 });
        rec.check().expect("voided send must not leak obligations");
    }

    #[test]
    fn fence_state_machine_rejects_out_of_order_targets() {
        let rec = HbRecorder::new(1);
        rec.fence_send(0, 2);
        rec.worker_recv(0, MsgLabel::Fence { target: 2 });
        rec.fence_park(0, 2); // first fence must target epoch 1
        let err = rec.check().expect_err("must flag");
        assert!(err.to_string().contains("consecutive"), "{err}");
    }

    #[test]
    fn untraced_handle_is_inert() {
        let h = HbHandle::default();
        h.submit_send(0, 1, 0);
        h.resolve(1, ResolveKind::Aborted);
        assert!(h.verify().expect("inert verify").is_none());
        assert_eq!(h.traced_replicas(), None);
    }
}
