//! Deterministic-interleaving driver for the streaming pool suites.
//!
//! A [`Plan`] is a totally ordered script of session events — submit /
//! poll / weight-sync / abort — derived from a PCG64 stream, so any
//! failing interleaving is reproducible from a single `u64` seed (the
//! property tests print it on failure, like `testkit::check` prints
//! its seed). The generator enforces the well-formedness constraints a
//! real session has:
//!
//! * every request index is submitted exactly once;
//! * sync fences keep their numbering order (fence j happens before
//!   fence j+1 — they model successive RL steps' weight versions);
//! * an abort always lands after its target's submit (you cannot
//!   cancel a ticket you do not hold).
//!
//! Everything else — where the fences fall relative to submits, how
//! polls interleave, which tickets get aborted — is shuffled by the
//! seed, which is exactly the space of admission interleavings the
//! streaming pool must stay bit-identical to the sequential reference
//! over.
//!
//! [`run`] replays a plan against anything implementing
//! [`InterleaveTarget`]; `rust/tests/prop_stream.rs` implements it for
//! both the streaming `EnginePool` session and the single-engine
//! sequential reference and compares the two.

use crate::util::rng::Pcg64;

/// One session event in a deterministic interleaving.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// Submit request #i (each index appears exactly once per plan).
    Submit(usize),
    /// A non-blocking completion-drain opportunity.
    Poll,
    /// Weight-sync fence #j (numbered in plan order).
    Sync(usize),
    /// Abort request #i (always placed after `Submit(i)`).
    Abort(usize),
}

/// Shape of a session to interleave.
#[derive(Clone, Copy, Debug)]
pub struct InterleaveSpec {
    pub n_requests: usize,
    /// weight-sync fences (>= 1 gives every plan an epoch boundary)
    pub n_syncs: usize,
    /// how many distinct requests get an abort event
    pub n_aborts: usize,
    /// extra poll points scattered through the plan (drain points
    /// exist implicitly at the end of every session anyway)
    pub n_polls: usize,
}

/// A concrete, replayable event order.
#[derive(Clone, Debug)]
pub struct Plan {
    pub seed: u64,
    pub events: Vec<Event>,
}

impl InterleaveSpec {
    /// Derive the plan for `seed` — pure: the same (spec, seed) pair
    /// always yields the same event order.
    pub fn plan(&self, seed: u64) -> Plan {
        let mut rng = Pcg64::new(seed);
        let mut events: Vec<Event> =
            (0..self.n_requests).map(Event::Submit).collect();
        events.extend((0..self.n_polls).map(|_| Event::Poll));
        rng.shuffle(&mut events);
        // syncs keep their relative order: each lands at a uniform
        // position after its predecessor
        let mut min_pos = 0usize;
        for j in 0..self.n_syncs {
            let span = (events.len() - min_pos + 1) as u64;
            let pos = min_pos + rng.below(span) as usize;
            events.insert(pos, Event::Sync(j));
            min_pos = pos + 1;
        }
        // aborts target distinct requests and land after their submit
        let mut targets: Vec<usize> = (0..self.n_requests).collect();
        rng.shuffle(&mut targets);
        for &i in targets.iter().take(self.n_aborts.min(self.n_requests))
        {
            let at = events
                .iter()
                .position(|e| *e == Event::Submit(i))
                .expect("every request index has a submit");
            let pos =
                at + 1 + rng.below((events.len() - at) as u64) as usize;
            events.insert(pos, Event::Abort(i));
        }
        Plan { seed, events }
    }
}

impl Plan {
    /// Assert the well-formedness constraints the generator promises
    /// (used by the module's own tests; cheap enough to call from a
    /// property test before trusting a plan).
    pub fn check_well_formed(&self, spec: &InterleaveSpec) {
        let mut submitted = vec![false; spec.n_requests];
        let mut next_sync = 0usize;
        let mut n_aborts = 0usize;
        for ev in &self.events {
            match *ev {
                Event::Submit(i) => {
                    assert!(!submitted[i], "request {i} submitted twice");
                    submitted[i] = true;
                }
                Event::Sync(j) => {
                    assert_eq!(j, next_sync, "sync fences out of order");
                    next_sync += 1;
                }
                Event::Abort(i) => {
                    assert!(
                        submitted[i],
                        "abort of request {i} before its submit"
                    );
                    n_aborts += 1;
                }
                Event::Poll => {}
            }
        }
        assert!(
            submitted.iter().all(|&s| s),
            "every request must be submitted"
        );
        assert_eq!(next_sync, spec.n_syncs, "missing sync fences");
        assert_eq!(
            n_aborts,
            spec.n_aborts.min(spec.n_requests),
            "wrong abort count"
        );
    }
}

/// What a plan drives — implemented by the streaming-pool session and
/// the single-engine sequential reference in the property suite.
pub trait InterleaveTarget {
    type Err;
    /// Submit request #i.
    fn submit(&mut self, request: usize) -> Result<(), Self::Err>;
    /// Apply weight-sync fence #j.
    fn sync(&mut self, sync: usize) -> Result<(), Self::Err>;
    /// Non-blocking drain opportunity.
    fn poll(&mut self) -> Result<(), Self::Err>;
    /// Abort request #i (may be a no-op if it already resolved).
    fn abort(&mut self, request: usize) -> Result<(), Self::Err>;
}

/// Replay a plan's events, in order, against a target.
pub fn run<T: InterleaveTarget>(
    plan: &Plan,
    target: &mut T,
) -> Result<(), T::Err> {
    for ev in &plan.events {
        match *ev {
            Event::Submit(i) => target.submit(i)?,
            Event::Poll => target.poll()?,
            Event::Sync(j) => target.sync(j)?,
            Event::Abort(i) => target.abort(i)?,
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: InterleaveSpec = InterleaveSpec {
        n_requests: 6,
        n_syncs: 2,
        n_aborts: 2,
        n_polls: 3,
    };

    #[test]
    fn plans_are_deterministic_per_seed() {
        for seed in 0..50u64 {
            let a = SPEC.plan(seed);
            let b = SPEC.plan(seed);
            assert_eq!(a.events, b.events, "seed {seed} not reproducible");
        }
    }

    #[test]
    fn plans_are_well_formed_for_many_seeds() {
        for seed in 0..200u64 {
            SPEC.plan(seed).check_well_formed(&SPEC);
        }
    }

    #[test]
    fn seeds_explore_different_interleavings() {
        let base = SPEC.plan(0);
        let differing = (1..40u64)
            .filter(|&s| SPEC.plan(s).events != base.events)
            .count();
        assert!(
            differing > 30,
            "only {differing}/39 seeds changed the event order"
        );
    }

    #[test]
    fn degenerate_specs_work() {
        // no aborts / no polls / single request — the edges a shrunk
        // counterexample lands on
        let spec = InterleaveSpec {
            n_requests: 1,
            n_syncs: 1,
            n_aborts: 0,
            n_polls: 0,
        };
        for seed in 0..20u64 {
            spec.plan(seed).check_well_formed(&spec);
        }
        // more aborts than requests clamps instead of panicking
        let greedy = InterleaveSpec {
            n_requests: 2,
            n_syncs: 1,
            n_aborts: 5,
            n_polls: 1,
        };
        for seed in 0..20u64 {
            greedy.plan(seed).check_well_formed(&greedy);
        }
    }

    #[test]
    fn run_replays_in_order() {
        struct Tape(Vec<Event>);
        impl InterleaveTarget for Tape {
            type Err = ();
            fn submit(&mut self, i: usize) -> Result<(), ()> {
                self.0.push(Event::Submit(i));
                Ok(())
            }
            fn sync(&mut self, j: usize) -> Result<(), ()> {
                self.0.push(Event::Sync(j));
                Ok(())
            }
            fn poll(&mut self) -> Result<(), ()> {
                self.0.push(Event::Poll);
                Ok(())
            }
            fn abort(&mut self, i: usize) -> Result<(), ()> {
                self.0.push(Event::Abort(i));
                Ok(())
            }
        }
        let plan = SPEC.plan(7);
        let mut tape = Tape(Vec::new());
        run(&plan, &mut tape).unwrap();
        assert_eq!(tape.0, plan.events);
    }
}
