//! Mini property-testing framework (proptest is unavailable offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` over `cases` random inputs
//! from `gen`; on failure it performs greedy shrinking via the input's
//! `Shrink` implementation and reports the minimal counterexample with
//! the seed needed to replay it.
//!
//! Used by the coordinator/rollout invariant suites
//! (`rust/tests/prop_*.rs`).
//!
//! [`interleave`] adds the deterministic-interleaving driver the
//! streaming-pool suite replays seeded submit/poll/sync/abort event
//! orders with.
//!
//! [`hb`] is the happens-before ordering oracle + fence-protocol
//! conformance checker for the streaming engine pool (hooks compiled
//! to no-ops without the `hb` cargo feature).

pub mod hb;
pub mod interleave;

use crate::util::rng::Pcg64;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate smaller values (tried in order, first failing one wins).
    fn shrink(&self) -> Vec<Self>;
}

/// Halving-distance candidates: n-d for d = n, n/2, n/4, ..., 1. Gives
/// binary-search convergence to a failing boundary in O(log n) rounds.
fn int_candidates(n: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut d = n;
    while d > 0 {
        out.push(n - d);
        d /= 2;
    }
    out.dedup();
    out
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        int_candidates(*self as u64)
            .into_iter()
            .map(|v| v as usize)
            .collect()
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        int_candidates(*self)
    }
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        // lint: allow(D2): shrinker dedup wants exact inequality
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            out.push(self.trunc());
        }
        out.retain(|v| v != self);
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
            let mut tail = self.clone();
            tail.pop();
            out.push(tail);
            // shrink one element
            for (i, x) in self.iter().enumerate().take(4) {
                for s in x.shrink().into_iter().take(2) {
                    let mut v = self.clone();
                    v[i] = s;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Result of a property over one input.
pub type PropResult = Result<(), String>;

/// Run a property over random inputs with shrinking on failure.
///
/// Panics with the minimal counterexample (so `cargo test` reports it).
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Pcg64) -> T,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Pcg64::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (seed {seed}, case {case}):\n  \
                 input: {min_input:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: Fn(&T) -> PropResult>(
    mut cur: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    // greedy descent, bounded
    for _ in 0..200 {
        let mut advanced = false;
        for cand in cur.shrink() {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (cur, msg)
}

/// Generate a vector of length in [lo, hi] with element generator `f`.
pub fn vec_of<T>(
    rng: &mut Pcg64,
    lo: usize,
    hi: usize,
    mut f: impl FnMut(&mut Pcg64) -> T,
) -> Vec<T> {
    let n = rng.range_i64(lo as i64, hi as i64) as usize;
    (0..n).map(|_| f(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            1,
            200,
            |r| r.below(1000) as usize,
            |&n| {
                if n < 1000 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check(
                2,
                200,
                |r| r.below(1000) as usize,
                |&n| {
                    if n < 500 {
                        Ok(())
                    } else {
                        Err(format!("{n} too big"))
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // greedy shrink should land exactly on the boundary 500
        assert!(msg.contains("input: 500"), "{msg}");
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let result = std::panic::catch_unwind(|| {
            check(
                3,
                100,
                |r| vec_of(r, 0, 20, |rr| rr.below(10) as usize),
                |v: &Vec<usize>| {
                    if v.len() < 3 {
                        Ok(())
                    } else {
                        Err("long".into())
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal failing vec has exactly 3 elements
        let count = msg.matches(',').count();
        assert!(count <= 3, "not shrunk: {msg}");
    }
}
