//! Streaming statistics + simple summaries used by metrics, benches and
//! the perf model.

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Running {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a scratch copy (nearest-rank).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Exponential moving average.
#[derive(Clone, Debug)]
pub struct Ema {
    pub alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.mean(), 3.0);
        assert!((r.var() - 2.5).abs() < 1e-12);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 5.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-9);
    }
}
