//! Minimal error-context substrate (anyhow is unavailable offline).
//!
//! [`Error`] is a rendered message chain: converting a source error
//! captures its `Display` rendering (plus its `source()` chain), and
//! [`Context`] prepends a layer of human context, exactly like anyhow.
//! The crate-root `bail!` / `anyhow!` macros mirror the anyhow idiom so
//! call sites read identically; they are re-exported here so modules can
//! `use crate::util::error::{bail, Context, Result}`.

use std::fmt;

/// A rendered error: outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error {
            chain: vec![m.into()],
        }
    }

    /// Prepend a layer of context.
    pub fn wrap(mut self, c: impl Into<String>) -> Error {
        self.chain.insert(0, c.into());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// NOTE: like anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket conversion (and
// the `Context` impl pair below) coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// anyhow-style context attachment for results and options.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<S, F>(self, f: F) -> Result<T>
    where
        S: fmt::Display,
        F: FnOnce() -> S;
}

impl<T, E: std::error::Error> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(msg.to_string()))
    }

    fn with_context<S, F>(self, f: F) -> Result<T>
    where
        S: fmt::Display,
        F: FnOnce() -> S,
    {
        self.map_err(|e| Error::from(e).wrap(f().to_string()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.wrap(msg.to_string()))
    }

    fn with_context<S, F>(self, f: F) -> Result<T>
    where
        S: fmt::Display,
        F: FnOnce() -> S,
    {
        self.map_err(|e| e.wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<S, F>(self, f: F) -> Result<T>
    where
        S: fmt::Display,
        F: FnOnce() -> S,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (anyhow's `anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
    }

    #[test]
    fn context_layers() {
        let r: Result<()> = fails().context("outer");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "outer: boom 42");
        assert_eq!(e.root_cause(), "boom 42");
    }

    #[test]
    fn std_error_conversion() {
        let r: Result<usize> = "nope"
            .parse::<usize>()
            .with_context(|| format!("parsing {:?}", "nope"));
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("parsing \"nope\": "), "{msg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }
}
