//! Minimal leveled stderr logger (the log/env_logger crates are
//! unavailable offline).
//!
//! `RUST_LOG=error|warn|info|debug|trace` selects the level (default
//! info). Use the crate-root macros: `log_error!`, `log_warn!`,
//! `log_info!`, `log_debug!`, `log_trace!`.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Read `RUST_LOG` and set the level (binaries call this once).
pub fn init() {
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("trace") => Level::Trace,
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    set_level(level);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::emit(
            $crate::util::log::Level::Error,
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::emit(
            $crate::util::log::Level::Warn,
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::emit(
            $crate::util::log::Level::Info,
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::emit(
            $crate::util::log::Level::Debug,
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::log::emit(
            $crate::util::log::Level::Trace,
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore the default for other tests
    }
}
