//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers everything the artifact manifest and the config system need:
//! full JSON grammar, `\uXXXX` escapes (incl. surrogate pairs), typed
//! accessors with descriptive errors, and a stable serializer.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Parse(usize, String),
    Type {
        expected: &'static str,
        found: &'static str,
        path: String,
    },
    Missing(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse(at, msg) => {
                write!(f, "json parse error at byte {at}: {msg}")
            }
            JsonError::Type {
                expected,
                found,
                path,
            } => {
                write!(
                    f,
                    "json: expected {expected} but found {found} at {path}"
                )
            }
            JsonError::Missing(key) => write!(f, "json: missing key '{key}'"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let b = src.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(JsonError::Parse(p.i, "trailing characters".into()));
        }
        Ok(v)
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    fn type_err(&self, expected: &'static str) -> JsonError {
        JsonError::Type {
            expected,
            found: self.kind(),
            path: String::new(),
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(other.type_err("number")),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(other.type_err("bool")),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(other.type_err("string")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(other.type_err("array")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(other.type_err("object")),
        }
    }

    /// `obj["key"]` with a proper error.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    /// Optional key.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // lint: allow(D2): integer-valued check for rendering
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !o.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError::Parse(self.i, msg.into())
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(format!("unexpected '{}'", c as char))),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| self.err(format!("bad number: {e}")))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                0x10000
                                    + ((hi - 0xD800) << 10)
                                    + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte utf8: back up and take the full char
                    self.i -= 1;
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad utf8 in \\u"))?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| self.err("bad hex in \\u"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x": [1, 2.5, "s", true, null], "y": {"z": -3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn errors_are_located() {
        let err = Json::parse("{\"a\": }").unwrap_err();
        assert!(matches!(err, JsonError::Parse(6, _)));
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("[1] junk").is_err());
    }

    #[test]
    fn typed_access_errors() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(j.get("a").unwrap().as_str().is_err());
        assert!(j.get("missing").is_err());
        assert_eq!(j.get("a").unwrap().as_usize().unwrap(), 1);
    }
}
