//! Deterministic PRNG for the whole stack (no `rand` crate offline).
//!
//! PCG64 (XSL-RR 128/64) — the same generator numpy defaults to — plus
//! SplitMix64 for seeding. Every component that samples (the token
//! sampler, the task generator, the property-test framework) takes a
//! `Pcg64` so experiments are bit-reproducible from a single seed.

/// SplitMix64: seed expander (Steele et al.).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG XSL-RR 128/64.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let mut rng = Self {
            state: 0,
            inc: (inc << 1) | 1,
        };
        rng.state = rng.state.wrapping_add(state);
        rng.next_u64();
        rng
    }

    /// Derive an independent stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len() as u64) as usize;
        }
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w.max(0.0) as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg64::new(3);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.below(17);
            assert!(n < 17);
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Pcg64::new(11);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(5);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(9);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.5..3.5).contains(&ratio), "{counts:?}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Pcg64::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
