//! Substrate utilities: JSON, RNG, statistics, CLI parsing, CSV output,
//! error context, logging, and unit-typed accounting newtypes
//! (serde/clap/anyhow/log are unavailable offline — these are the
//! in-repo replacements).
pub mod cli;
pub mod clock;
pub mod csv;
pub mod error;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod units;
