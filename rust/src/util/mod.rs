//! Substrate utilities: JSON, RNG, statistics, CLI parsing, CSV output.
pub mod cli;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;
