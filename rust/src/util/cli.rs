//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and free
//! positionals. Typed getters with defaults keep call sites short.

use std::collections::BTreeMap;

use crate::util::error::{bail, Context, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn basic_forms() {
        let a = parse(&["run", "--steps", "100", "--fast", "--mode=fp8"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.bool("fast"));
        assert_eq!(a.str_or("mode", "bf16"), "fp8");
        assert_eq!(a.str_or("absent", "dflt"), "dflt");
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["--out", "dir", "cmd"]);
        assert_eq!(a.get("out"), Some("dir"));
        assert_eq!(a.positional, vec!["cmd"]);
    }

    #[test]
    fn type_errors() {
        let a = parse(&["--steps", "abc"]);
        assert!(a.usize_or("steps", 0).is_err());
    }
}
