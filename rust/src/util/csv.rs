//! CSV writer for experiment curves (`results/*.csv`) — the files the
//! figure-reproduction harness emits and EXPERIMENTS.md references.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::error::{Context, Result};

pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = File::create(&path)
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        let mut out = BufWriter::new(f);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        assert_eq!(values.len(), self.cols, "csv row width mismatch");
        let line = values
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.out, "{line}")?;
        Ok(())
    }

    pub fn row_mixed(&mut self, values: &[String]) -> Result<()> {
        assert_eq!(values.len(), self.cols, "csv row width mismatch");
        writeln!(self.out, "{}", values.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_csv() {
        let dir = std::env::temp_dir().join("fp8rl_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.flush().unwrap();
        }
        let s = std::fs::read_to_string(&path).unwrap();
        assert_eq!(s, "a,b\n1,2.5\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let dir = std::env::temp_dir().join("fp8rl_csv_test2");
        let mut w =
            CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        w.row(&[1.0]).unwrap();
    }
}
