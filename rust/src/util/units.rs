//! Unit-typed accounting newtypes: `Tokens`, `Blocks`, `Bytes`, and
//! `ScaleEpoch` — the type-system half of lint rule U1 (DESIGN.md §9).
//!
//! The newtypes are deliberately arithmetic-free: there is no
//! `Add`/`Sub` impl, so the compiler rejects `tokens + blocks`
//! outright and same-family math has to name its overflow policy
//! (`checked_*` / `saturating_*`). Cross-family conversions live as
//! named methods on the owning type (`KvGeometry::blocks_in`,
//! `KvBlockManager::blocks_for`, `QuantizedTensor::nbytes`), never as
//! bare casts at call sites. `Display` prints the bare number so log
//! and error strings stay byte-identical with pre-newtype formatting.

use std::fmt;

macro_rules! unit_newtype {
    ($(#[$doc:meta])* $name:ident, $repr:ty) => {
        $(#[$doc])*
        #[derive(
            Clone,
            Copy,
            Debug,
            Default,
            PartialEq,
            Eq,
            PartialOrd,
            Ord,
            Hash,
        )]
        pub struct $name($repr);

        impl $name {
            pub const ZERO: $name = $name(0);

            pub const fn new(v: $repr) -> $name {
                $name(v)
            }

            /// The raw count, for display-adjacent math and FFI edges.
            pub const fn get(self) -> $repr {
                self.0
            }

            pub const fn is_zero(self) -> bool {
                self.0 == 0
            }

            pub fn checked_add(self, rhs: $name) -> Option<$name> {
                self.0.checked_add(rhs.0).map($name)
            }

            pub fn checked_sub(self, rhs: $name) -> Option<$name> {
                self.0.checked_sub(rhs.0).map($name)
            }

            pub fn saturating_add(self, rhs: $name) -> $name {
                $name(self.0.saturating_add(rhs.0))
            }

            pub fn saturating_sub(self, rhs: $name) -> $name {
                $name(self.0.saturating_sub(rhs.0))
            }
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                self.0.fmt(f)
            }
        }
    };
}

unit_newtype!(
    /// A count of sequence tokens (prompt + generated).
    Tokens,
    usize
);
unit_newtype!(
    /// A count of paged-KV cache blocks.
    Blocks,
    usize
);
unit_newtype!(
    /// A byte quantity: KV budgets, weight-sync traffic accounting.
    Bytes,
    usize
);
unit_newtype!(
    /// A weight-sync epoch stamp. Carried by `fp8::ScaleSet` so that
    /// decode-side scale reads can be freshness-checked against the
    /// engine's current weight epoch (lint rule Q2).
    ScaleEpoch,
    u64
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prints_the_bare_number() {
        assert_eq!(format!("{}", Tokens::new(42)), "42");
        assert_eq!(format!("{:>4}", Blocks::new(7)), "   7");
        assert_eq!(format!("{}", ScaleEpoch::new(9)), "9");
    }

    #[test]
    fn saturating_and_checked_ops() {
        let a = Bytes::new(usize::MAX);
        assert_eq!(a.saturating_add(Bytes::new(1)), a);
        assert_eq!(Bytes::ZERO.saturating_sub(Bytes::new(3)), Bytes::ZERO);
        assert_eq!(Bytes::new(2).checked_sub(Bytes::new(3)), None);
        assert_eq!(
            Tokens::new(2).checked_add(Tokens::new(3)),
            Some(Tokens::new(5))
        );
    }

    #[test]
    fn ordering_and_zero() {
        assert!(Blocks::new(2) < Blocks::new(3));
        assert_eq!(Blocks::new(2).max(Blocks::new(3)), Blocks::new(3));
        assert!(Tokens::ZERO.is_zero());
        assert!(!Tokens::new(1).is_zero());
        assert_eq!(Bytes::default(), Bytes::ZERO);
    }
}
