//! Wall-clock timing for METRICS ONLY.
//!
//! The deterministic modules (`rollout/`, `sync/`, `coordinator/`,
//! `testkit/`, `fp8/`) are forbidden from touching `std::time::Instant`
//! directly (pallas-lint rule D1): wall-clock reads that leak into
//! control flow are exactly how replica-count-dependent behavior snuck
//! into early drafts of the pool. This wrapper is the sanctioned escape:
//! it can measure durations for reports and metrics, but its API
//! deliberately exposes no absolute time, no comparison against other
//! timers, and no "now" value that could be branched on.
//!
//! Contract: a [`WallTimer`] value may flow into `f64` metrics fields
//! and log lines. It must never influence which branch executes, which
//! request is scheduled, or what bytes end up in a completion.

use std::time::Instant;

/// A started stopwatch. See the module docs for the usage contract.
#[derive(Clone, Copy, Debug)]
pub struct WallTimer {
    t0: Instant,
}

impl WallTimer {
    /// Start timing now.
    pub fn start() -> WallTimer {
        WallTimer { t0: Instant::now() }
    }

    /// Seconds since `start()`. For metrics/reports only.
    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Milliseconds since `start()`. For metrics/reports only.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    /// Restart the stopwatch in place.
    pub fn restart(&mut self) {
        self.t0 = Instant::now();
    }
}

impl Default for WallTimer {
    fn default() -> Self {
        WallTimer::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_is_monotonic_nonnegative() {
        let t = WallTimer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(a >= 0.0);
        assert!(b >= a);
    }

    #[test]
    fn restart_resets() {
        let mut t = WallTimer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let before = t.elapsed_ms();
        t.restart();
        assert!(t.elapsed_ms() <= before + 1.0);
    }
}
