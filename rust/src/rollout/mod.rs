//! The rollout (inference) engine — the vLLM-role component: paged
//! KV-cache block manager, continuous-batching scheduler with
//! preemption, token sampler, request router, the HLO-backed
//! generation engine, and the thread-per-replica engine pool with
//! continuous streaming admission (submit/poll/drain sessions plus
//! epoch-fenced weight sync) the RL loop drives at
//! `rollout_replicas > 1` or `rollout_streaming = true`.
pub mod engine;
pub mod kvcache;
pub mod pool;
pub mod request;
pub mod router;
pub mod sampler;
pub mod scheduler;

pub use engine::{EngineConfig, EngineStats, HloEngine};
pub use kvcache::{
    prefix_hash, KvBlockManager, KvGeometry, KvGeometryError,
    KvPrecision, SharedGrant,
};
pub use pool::{
    factory_like, hermetic_runtime_factory, runtime_factory, Completed,
    EnginePool, PoolConfig, Rollout, RuntimeFactory, TicketId,
};
pub use request::{Completion, FinishReason, Request, SamplingParams};
pub use router::{RoutePolicy, Router};
pub use sampler::SampleOut;
pub use scheduler::Scheduler;
