//! The rollout (inference) engine — the vLLM-role component: paged
//! KV-cache block manager, continuous-batching scheduler with
//! preemption, token sampler, request router, and the HLO-backed
//! generation engine the RL loop drives.
pub mod engine;
pub mod kvcache;
pub mod request;
pub mod router;
pub mod sampler;
pub mod scheduler;

pub use engine::{EngineConfig, EngineStats, HloEngine};
pub use kvcache::{KvBlockManager, KvGeometry, KvPrecision};
pub use request::{Completion, FinishReason, Request, SamplingParams};
pub use router::{RoutePolicy, Router};
pub use scheduler::Scheduler;
