//! Request router across multiple rollout engines (the vllm-router-style
//! front door used by the engine pool and `examples/rollout_server.rs`).
//!
//! Policies: round-robin and least-loaded (by outstanding prompt +
//! expected decode tokens). The router only decides placement; each
//! engine runs its own scheduler.
//!
//! Load accounting is tracked **per request id**: `route` charges the
//! chosen engine, and either [`Router::complete`] or [`Router::abort`]
//! drains exactly what was charged. The old interface recomputed the
//! cost from the request at completion time and was never called on the
//! abort/drain path, so failed `generate` calls leaked phantom load
//! until `LeastLoaded` degenerated into routing everything to whichever
//! engine had failed least; a double `complete` (masked by
//! `saturating_sub`) silently skewed loads the other way. Both are
//! structurally impossible now: settling an unknown (or already
//! settled) id is an inert no-op that returns `None`.
//!
//! **Liveness.** A `LeastLoaded` pick is only as good as the charges
//! are fresh. The barrier-era pool settled a whole batch at once, so
//! within a batch the router saw a batch-time snapshot and a replica
//! stuck on a long completion looked exactly as loaded as it did at
//! fan-out time — fine under a barrier (nothing routes mid-batch),
//! WRONG under continuous admission. The streaming pool therefore
//! settles ids the moment their completion/abort crosses the event
//! channel and pumps that channel *before every `route` call*, so
//! [`Router::loads`] (outstanding + queued charge per replica) is live:
//! a replica grinding through a long completion keeps its charge and
//! stops receiving new work while its peers drain
//! (`slow_replica_stops_receiving_new_work` below).

use std::collections::BTreeMap;

use super::kvcache::prefix_hash;
use super::request::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    /// Content-addressed placement: requests hash their prompt to a
    /// home replica, so a GRPO group (G completions of one prompt)
    /// lands on ONE engine and its shared-prefix KV reuse actually
    /// fires — `LeastLoaded` would scatter the group and every replica
    /// would prefill its own copy. Placement never affects outputs
    /// (per-request RNG streams make completions placement-invariant);
    /// this is purely a cache-locality policy.
    PrefixAffinity,
}

pub struct Router {
    policy: RoutePolicy,
    n_engines: usize,
    next: usize,
    /// outstanding token load per engine (prompt + expected decode)
    load: Vec<u64>,
    /// request id -> (engine, charged cost); settling removes the entry
    /// and drains exactly the charged amount
    outstanding: BTreeMap<u64, (usize, u64)>,
    /// engines excluded from placement (dead, or stranded behind a
    /// failed weight-epoch fence). A quarantined engine still settles
    /// the charges it holds; it just receives no new work — otherwise
    /// its instantly-failing admissions keep its load near zero and
    /// `LeastLoaded` turns it into a traffic black hole.
    quarantined: Vec<bool>,
    pub completed: u64,
    pub aborted: u64,
}

impl Router {
    pub fn new(policy: RoutePolicy, n_engines: usize) -> Router {
        assert!(n_engines > 0);
        Router {
            policy,
            n_engines,
            next: 0,
            load: vec![0; n_engines],
            outstanding: BTreeMap::new(),
            quarantined: vec![false; n_engines],
            completed: 0,
            aborted: 0,
        }
    }

    /// Exclude an engine from (or readmit it to) placement.
    pub fn set_quarantined(&mut self, engine: usize, q: bool) {
        debug_assert!(engine < self.n_engines);
        if let Some(slot) = self.quarantined.get_mut(engine) {
            *slot = q;
        }
    }

    fn cost(req: &Request) -> u64 {
        (req.prompt.len() + req.params.max_new_tokens) as u64
    }

    /// Pick an engine for the request and account its load. Re-routing
    /// an id that is still outstanding (a caller re-submitting after a
    /// failure) first drains the stale charge.
    pub fn route(&mut self, req: &Request) -> usize {
        self.settle(req.id);
        let cost = Self::cost(req);
        let idx = match self.policy {
            RoutePolicy::RoundRobin => {
                // skip quarantined engines; if everything is
                // quarantined the scan wraps back to the plain pick
                // (placement must still terminate)
                let mut i = self.next;
                for _ in 0..self.n_engines {
                    let q = self.quarantined.get(i).copied();
                    if !q.unwrap_or(false) {
                        break;
                    }
                    i = (i + 1) % self.n_engines;
                }
                self.next = (i + 1) % self.n_engines;
                i
            }
            RoutePolicy::LeastLoaded => {
                let healthy = self
                    .load
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| {
                        let q = self.quarantined.get(*i).copied();
                        !q.unwrap_or(false)
                    })
                    .min_by_key(|(_, &l)| l);
                // everything quarantined: fall back to the plain
                // minimum (new() guarantees n_engines > 0, so the
                // final unwrap_or(0) is unreachable in practice)
                let any = self
                    .load
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &l)| l);
                healthy.or(any).map(|(i, _)| i).unwrap_or(0)
            }
            RoutePolicy::PrefixAffinity => {
                // home replica by prompt hash; if it is quarantined,
                // probe linearly (every member of a group probes the
                // same order from the same home, so the group stays
                // colocated on the fallback replica too). If everything
                // is quarantined the scan wraps back to the home pick
                // (placement must still terminate).
                let mut i = (prefix_hash(&req.prompt)
                    % self.n_engines as u64)
                    as usize;
                for _ in 0..self.n_engines {
                    let q = self.quarantined.get(i).copied();
                    if !q.unwrap_or(false) {
                        break;
                    }
                    i = (i + 1) % self.n_engines;
                }
                i
            }
        };
        if let Some(load) = self.load.get_mut(idx) {
            *load = load.saturating_add(cost);
        }
        self.outstanding.insert(req.id, (idx, cost));
        idx
    }

    /// Report completion so load drains. Returns the engine the request
    /// was routed to, or `None` if the id is unknown / already settled
    /// (double-complete is an inert no-op).
    pub fn complete(&mut self, id: u64) -> Option<usize> {
        let e = self.settle(id);
        if e.is_some() {
            self.completed += 1;
        }
        e
    }

    /// Drain an aborted / failed request (the `generate`-error and
    /// scheduler-drain path). Same accounting as `complete`; tracked
    /// separately for diagnostics.
    pub fn abort(&mut self, id: u64) -> Option<usize> {
        let e = self.settle(id);
        if e.is_some() {
            self.aborted += 1;
        }
        e
    }

    fn settle(&mut self, id: u64) -> Option<usize> {
        let (engine, cost) = self.outstanding.remove(&id)?;
        if let Some(load) = self.load.get_mut(engine) {
            // cannot underflow: `cost` is exactly what `route` charged,
            // and `outstanding.remove` above makes double-settle inert.
            // Kept exact (not saturating) so a broken charge pairing
            // still trips debug overflow checks instead of hiding.
            // lint: allow(A1): settle subtracts the exact charge `route` added
            *load -= cost;
        }
        Some(engine)
    }

    /// Live token-load per engine: every charge routed and not yet
    /// settled — i.e. work queued at or running on each replica. This
    /// is what `LeastLoaded` compares, so keeping it fresh (settle
    /// completions BEFORE routing) is what makes a slow replica stop
    /// receiving new work.
    pub fn loads(&self) -> &[u64] {
        &self.load
    }

    pub fn n_outstanding(&self) -> usize {
        self.outstanding.len()
    }

    /// Move `n` settlements from `completed` to `aborted` — the
    /// pool's all-or-nothing failure path, where results that crossed
    /// the event channel (and were settled as completed the moment
    /// they arrived) are dropped before delivery. Pure diagnostics:
    /// the load charges drained at settlement and stay drained.
    /// Clamped so the counters can never underflow or disagree with
    /// the number of settlements that actually happened.
    pub fn reclassify_completed_as_aborted(&mut self, n: u64) {
        let n = n.min(self.completed);
        self.completed -= n;
        self.aborted += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rollout::request::SamplingParams;
    use crate::util::rng::Pcg64;

    fn req(id: u64, plen: usize) -> Request {
        Request {
            id,
            prompt: vec![0; plen],
            params: SamplingParams::default(),
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> =
            (0..6).map(|i| r.route(&req(i, 4))).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        let a = r.route(&req(1, 100)); // heavy
        let b = r.route(&req(2, 1)); // goes to the other engine
        assert_ne!(a, b);
        let c = r.route(&req(3, 1)); // engine b still lighter
        assert_eq!(b, c);
    }

    #[test]
    fn prefix_affinity_colocates_identical_prompts() {
        let mut r = Router::new(RoutePolicy::PrefixAffinity, 4);
        let prompt: Vec<i32> = vec![5, 6, 7, 8];
        // a GRPO group: same prompt, distinct ids -> ONE replica
        let home = r.route(&Request {
            id: 0,
            prompt: prompt.clone(),
            params: SamplingParams::default(),
        });
        for id in 1..8u64 {
            let e = r.route(&Request {
                id,
                prompt: prompt.clone(),
                params: SamplingParams::default(),
            });
            assert_eq!(e, home, "group member {id} left its home");
        }
        // varied prompts spread across replicas
        let mut seen = std::collections::BTreeSet::new();
        for id in 100..132u64 {
            let q = Request {
                id,
                prompt: vec![id as i32, (id * 7) as i32, 3],
                params: SamplingParams::default(),
            };
            seen.insert(r.route(&q));
        }
        assert!(seen.len() > 1, "distinct prompts must spread");
        // quarantining the home moves the WHOLE group, together
        let mut r2 = Router::new(RoutePolicy::PrefixAffinity, 4);
        r2.set_quarantined(home, true);
        let mut fallbacks = std::collections::BTreeSet::new();
        for id in 200..208u64 {
            fallbacks.insert(r2.route(&Request {
                id,
                prompt: prompt.clone(),
                params: SamplingParams::default(),
            }));
        }
        assert_eq!(fallbacks.len(), 1, "group stays colocated");
        assert!(!fallbacks.contains(&home), "home is avoided");
    }

    #[test]
    fn completion_drains_load() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        let q = req(1, 50);
        let e = r.route(&q);
        assert!(r.loads()[e] > 0);
        assert_eq!(r.complete(q.id), Some(e));
        assert_eq!(r.loads()[e], 0);
    }

    #[test]
    fn abort_drains_like_complete() {
        // regression: the scheduler-drain path never told the router,
        // so failed generates accumulated phantom load forever
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        let q = req(7, 30);
        let e = r.route(&q);
        assert!(r.loads()[e] > 0);
        assert_eq!(r.abort(q.id), Some(e));
        assert_eq!(r.loads(), &[0, 0]);
        assert_eq!(r.aborted, 1);
        assert_eq!(r.completed, 0);
    }

    #[test]
    fn double_settle_is_inert() {
        // regression: a second complete used to subtract the cost again
        // (masked by saturating_sub), so the engine looked idle while
        // it still carried work
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        let a = req(1, 50);
        let b = req(2, 50);
        let ea = r.route(&a);
        let eb = r.route(&b);
        assert_ne!(ea, eb);
        assert_eq!(r.complete(a.id), Some(ea));
        assert_eq!(r.complete(a.id), None); // double complete
        assert_eq!(r.abort(a.id), None); // complete-then-abort
        assert_eq!(r.loads()[ea], 0);
        assert!(r.loads()[eb] > 0, "b's load must survive a's double");
        assert_eq!(r.completed, 1);
        assert_eq!(r.aborted, 0);
    }

    #[test]
    fn reroute_of_outstanding_id_drains_stale_charge() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2);
        let q = req(5, 40);
        r.route(&q); // engine 0
        r.route(&q); // resubmitted: engine 1, stale charge drained
        assert_eq!(r.loads()[0], 0);
        assert!(r.loads()[1] > 0);
        assert_eq!(r.n_outstanding(), 1);
        r.complete(q.id);
        assert_eq!(r.loads(), &[0, 0]);
    }

    #[test]
    fn quarantined_engine_stops_receiving_placements() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_quarantined(0, true);
        for id in 0..10u64 {
            assert_eq!(r.route(&req(id, 4)), 1, "placement on healthy");
        }
        // everything quarantined: placement falls back rather than
        // panicking (degraded, but still terminates)
        r.set_quarantined(1, true);
        assert!(r.route(&req(100, 4)) < 2);
        // round-robin skips quarantined engines too
        let mut rr = Router::new(RoutePolicy::RoundRobin, 3);
        rr.set_quarantined(1, true);
        let picks: Vec<usize> =
            (0..4).map(|i| rr.route(&req(200 + i, 4))).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn reclassify_moves_completed_to_aborted_without_touching_load() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2);
        for id in 0..3u64 {
            r.route(&req(id, 4));
            r.complete(id);
        }
        assert_eq!((r.completed, r.aborted), (3, 0));
        r.reclassify_completed_as_aborted(2);
        assert_eq!((r.completed, r.aborted), (1, 2));
        // clamped: can't reclassify settlements that never happened
        r.reclassify_completed_as_aborted(10);
        assert_eq!((r.completed, r.aborted), (0, 3));
        assert_eq!(r.loads(), &[0, 0], "loads untouched");
    }

    #[test]
    fn slow_replica_stops_receiving_new_work() {
        // the streaming-admission liveness property: with completions
        // settled as they arrive (live depth), a replica stuck on one
        // long completion receives NO new work while its peer keeps
        // absorbing the stream. Under the old batch-time snapshot
        // (settle everything at the end), the fast replica's charges
        // piled up un-drained until it looked MORE loaded than the
        // stuck one, and new work started landing behind the straggler.
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        let mut slow_req = req(0, 8);
        slow_req.params.max_new_tokens = 500; // a long completion
        let slow = r.route(&slow_req);
        let fast = 1 - slow;
        // a stream of short requests, each completing before the next
        // arrives (the live-settlement regime)
        for id in 1..=50u64 {
            let q = req(id, 8);
            assert_eq!(
                r.route(&q),
                fast,
                "request {id} must avoid the stuck replica"
            );
            assert_eq!(r.complete(id), Some(fast));
        }
        assert_eq!(r.loads()[fast], 0, "fast replica drains live");
        assert!(r.loads()[slow] > 0, "straggler keeps its charge");
        // demonstrate the stale-snapshot failure mode the streaming
        // pool must avoid: stop settling, and the fast replica's
        // accumulated charges eventually exceed the straggler's
        let mut sent_to_slow = false;
        for id in 100..200u64 {
            sent_to_slow |= r.route(&req(id, 8)) == slow;
        }
        assert!(
            sent_to_slow,
            "without live settlement the straggler would attract work \
             again — the property the streaming pump exists to prevent"
        );
    }

    #[test]
    fn prop_loads_return_to_zero_under_any_settle_mix() {
        // property: after ANY interleaving of route / complete / abort /
        // double-settle / unknown-settle, per-engine load equals the sum
        // of outstanding charges, and settling everything returns every
        // engine to exactly zero
        for seed in 0..20u64 {
            let mut rng = Pcg64::new(0xA0B0 + seed);
            let n_engines = 1 + (seed as usize % 4);
            let policy = if seed % 2 == 0 {
                RoutePolicy::RoundRobin
            } else {
                RoutePolicy::LeastLoaded
            };
            let mut r = Router::new(policy, n_engines);
            // model: id -> (engine, cost) for outstanding requests
            let mut model: BTreeMap<u64, (usize, u64)> = BTreeMap::new();
            let mut next_id = 0u64;
            for _ in 0..300 {
                match rng.below(10) {
                    0..=4 => {
                        next_id += 1;
                        let q = req(next_id, 1 + rng.below(64) as usize);
                        let e = r.route(&q);
                        assert!(e < n_engines);
                        model.insert(next_id, (e, Router::cost(&q)));
                    }
                    5..=6 => {
                        // settle a random outstanding id (complete)
                        if let Some(&id) =
                            model.keys().next()
                        {
                            let (e, _) = model.remove(&id).unwrap();
                            assert_eq!(r.complete(id), Some(e));
                        }
                    }
                    7 => {
                        // settle a random outstanding id (abort)
                        if let Some(&id) = model.keys().last() {
                            let (e, _) = model.remove(&id).unwrap();
                            assert_eq!(r.abort(id), Some(e));
                        }
                    }
                    _ => {
                        // unknown / already-settled ids are inert
                        assert_eq!(r.complete(next_id + 1000), None);
                        assert_eq!(r.abort(u64::MAX), None);
                    }
                }
                // invariant: router load == sum of model costs per engine
                let mut want = vec![0u64; n_engines];
                for (e, c) in model.values() {
                    want[*e] += c;
                }
                assert_eq!(r.loads(), &want[..], "seed {seed}");
                assert_eq!(r.n_outstanding(), model.len());
            }
            // drain everything: loads must return to exactly zero
            let ids: Vec<u64> = model.keys().copied().collect();
            for (i, id) in ids.iter().enumerate() {
                if i % 2 == 0 {
                    assert!(r.complete(*id).is_some());
                } else {
                    assert!(r.abort(*id).is_some());
                }
            }
            assert_eq!(r.loads(), &vec![0u64; n_engines][..]);
            assert_eq!(r.n_outstanding(), 0);
        }
    }
}
