//! Request router across multiple rollout engines (the vllm-router-style
//! front door used by `examples/rollout_server.rs`).
//!
//! Policies: round-robin and least-loaded (by queued prompt tokens). The
//! router only decides placement; each engine runs its own scheduler.

use super::request::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

pub struct Router {
    policy: RoutePolicy,
    n_engines: usize,
    next: usize,
    /// outstanding token load per engine (prompt + expected decode)
    load: Vec<u64>,
}

impl Router {
    pub fn new(policy: RoutePolicy, n_engines: usize) -> Router {
        assert!(n_engines > 0);
        Router {
            policy,
            n_engines,
            next: 0,
            load: vec![0; n_engines],
        }
    }

    /// Pick an engine for the request and account its load.
    pub fn route(&mut self, req: &Request) -> usize {
        let cost =
            (req.prompt.len() + req.params.max_new_tokens) as u64;
        let idx = match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.next;
                self.next = (self.next + 1) % self.n_engines;
                i
            }
            RoutePolicy::LeastLoaded => {
                let (i, _) = self
                    .load
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &l)| l)
                    .unwrap();
                i
            }
        };
        self.load[idx] += cost;
        idx
    }

    /// Report completion so load drains.
    pub fn complete(&mut self, engine: usize, req: &Request) {
        let cost =
            (req.prompt.len() + req.params.max_new_tokens) as u64;
        self.load[engine] = self.load[engine].saturating_sub(cost);
    }

    pub fn loads(&self) -> &[u64] {
        &self.load
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rollout::request::SamplingParams;

    fn req(id: u64, plen: usize) -> Request {
        Request {
            id,
            prompt: vec![0; plen],
            params: SamplingParams::default(),
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> =
            (0..6).map(|i| r.route(&req(i, 4))).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        let a = r.route(&req(1, 100)); // heavy
        let b = r.route(&req(2, 1)); // goes to the other engine
        assert_ne!(a, b);
        let c = r.route(&req(3, 1)); // engine b still lighter
        assert_eq!(b, c);
    }

    #[test]
    fn completion_drains_load() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        let q = req(1, 50);
        let e = r.route(&q);
        assert!(r.loads()[e] > 0);
        r.complete(e, &q);
        assert_eq!(r.loads()[e], 0);
    }
}
