//! Token sampler: temperature / top-k / top-p over a logit row.
//!
//! ## Behavior-policy logprob convention (the TIS/MIS denominator)
//!
//! [`sample`] returns the sampled token together with TWO logprobs:
//!
//! * `logprob` — the probability of the token under the distribution it
//!   was **actually drawn from**: temperature-scaled, top-k/top-p
//!   truncated, renormalized. This is pi_fp8 in paper eq. (2) — the
//!   quantity the trainer's TIS/MIS correction divides by. Returning
//!   anything else biases every importance weight whenever truncation
//!   is active: the old code returned the full-vocabulary temperature-1
//!   log-softmax, so with top-k/top-p on, `pi_theta / pi_fp8` collapsed
//!   to 1 for kept tokens instead of `pi_theta / (pi / kept_mass)`,
//!   silently under-correcting exactly the rollouts truncation skews
//!   most. For greedy decoding (temperature <= 0) the sampling law is a
//!   point mass, so `logprob` is 0.
//! * `logprob_full` — the full-vocabulary temperature-1 log-softmax at
//!   the sampled token, i.e. the same convention the trainer evaluates
//!   pi_theta in. Kept as a diagnostic companion; when sampling is
//!   untruncated at temperature 1 (the RL loop's default) `logprob` is
//!   evaluated through the same log-softmax route and is BIT-equal to
//!   it (and to the pre-fix convention) — for a given sampled token the
//!   convention change is invisible on that path. (Same-seed runs still
//!   produce different token *sequences* than pre-PR builds, because
//!   sampling also moved onto per-request RNG streams — see below.)
//!
//! ## Robustness
//!
//! `sample` is total over garbage logits: NaN / +inf rows (a broken
//! upstream kernel) surface as an `Err` instead of the old
//! `partial_cmp().unwrap()` panic in the greedy path.
//!
//! ## Determinism
//!
//! [`request_seed`] derives the per-request RNG stream the engine
//! samples with: a pure function of (engine seed, request id), so a
//! request's samples do not depend on batch composition, replica
//! assignment, or recompute preemption — the invariant that makes an
//! N-replica pool bit-identical to a single engine.

use crate::util::error::{bail, Context, Result};
use crate::util::rng::{Pcg64, SplitMix64};

use super::request::SamplingParams;

/// One sampled token with its logprob under the distribution it was
/// actually drawn from (`logprob`) and under the full-vocabulary
/// temperature-1 softmax (`logprob_full`) — see the module docs.
#[derive(Clone, Copy, Debug)]
pub struct SampleOut {
    pub token: i32,
    /// behavior-policy logprob: truncated + temperature-scaled +
    /// renormalized (pi_fp8, the TIS/MIS denominator)
    pub logprob: f32,
    /// full-vocab temperature-1 log-softmax at `token` (the trainer's
    /// pi_theta convention; diagnostic)
    pub logprob_full: f32,
}

/// Seed for a request's private sampling stream — pure in
/// (engine seed, request id), so every replica derives the same stream
/// for the same request.
pub fn request_seed(engine_seed: u64, request_id: u64) -> u64 {
    let mut sm = SplitMix64::new(
        engine_seed ^ request_id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    sm.next_u64()
}

/// log-softmax value of index `idx` under logits (natural log).
pub fn log_softmax_at(logits: &[f32], idx: usize) -> f32 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let z: f64 = logits.iter().map(|&l| ((l - m) as f64).exp()).sum();
    // out-of-range index reads as probability 0 (log -inf)
    let li = logits.get(idx).copied().unwrap_or(f32::NEG_INFINITY);
    (li - m) as f64 as f32 - (z.ln() as f32)
}

/// Reject logit rows no sampling law can be defined over.
fn check_logits(logits: &[f32]) -> Result<()> {
    if logits.is_empty() {
        bail!("sampler: empty logit row");
    }
    if let Some((i, l)) = logits.iter().enumerate().find(|(_, l)| {
        l.is_nan() || (l.is_infinite() && l.is_sign_positive())
    }) {
        bail!(
            "sampler: non-finite logit {l} at index {i} — upstream \
             kernel produced garbage"
        );
    }
    if logits
        .iter()
        .all(|&l| l.is_infinite() && l.is_sign_negative())
    {
        bail!("sampler: every logit is -inf (empty support)");
    }
    Ok(())
}

/// Sample one token. See the module docs for the logprob convention.
pub fn sample(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Pcg64,
) -> Result<SampleOut> {
    check_logits(logits)?;
    if params.temperature <= 0.0 {
        // greedy: a point mass — the token's probability under the
        // sampling law is exactly 1. check_logits rejected the empty
        // row, so the fallback index is unreachable.
        let idx = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        return Ok(SampleOut {
            token: idx as i32,
            logprob: 0.0,
            logprob_full: log_softmax_at(logits, idx),
        });
    }
    let scaled: Vec<f32> =
        logits.iter().map(|&l| l / params.temperature).collect();

    // candidate set after top-k / top-p truncation. Sorting
    // (index, value) pairs keeps every later lookup index-free.
    let mut order: Vec<(usize, f32)> =
        scaled.iter().copied().enumerate().collect();
    order.sort_by(|a, b| b.1.total_cmp(&a.1));
    let mut keep = order.len();
    if params.top_k > 0 {
        keep = keep.min(params.top_k);
    }
    // the max scaled logit (head of the descending order)
    let m = order.first().map(|&(_, v)| v).unwrap_or(0.0);
    if params.top_p < 1.0 {
        let exps: Vec<f64> = order
            .iter()
            .map(|&(_, v)| ((v - m) as f64).exp())
            .collect();
        let total: f64 = exps.iter().sum();
        let mut acc = 0.0;
        let mut np = 0;
        for e in exps.iter().take(keep) {
            acc += e / total;
            np += 1;
            if acc >= params.top_p as f64 {
                break;
            }
        }
        keep = np.max(1);
    }

    // sample within the kept set; the behavior logprob is evaluated
    // against the SAME weights the draw uses, so it is exactly
    // log(weight_i / sum(kept weights)) for the categorical below
    let kept = order.get(..keep).context("kept set exceeds order")?;
    let weights: Vec<f32> = kept
        .iter()
        .map(|&(_, v)| ((v - m) as f64).exp() as f32)
        .collect();
    let pick = rng.categorical(&weights);
    let &(idx, _) = kept
        .get(pick)
        .context("categorical pick out of kept range")?;
    let logprob_full = log_softmax_at(logits, idx);
    // untruncated at temperature 1, renormalization is the identity:
    // evaluate through the same log-softmax route as the full-vocab
    // diagnostic so the two are BIT-equal — the RL-loop default path
    // stays bit-identical to the pre-fix convention
    // lint: allow(D2): exact ==1.0 gates the bit-equality fast path
    let logprob = if keep == scaled.len() && params.temperature == 1.0 {
        logprob_full
    } else {
        let z: f64 = weights.iter().map(|&w| w as f64).sum();
        let w = weights.get(pick).copied().unwrap_or(0.0);
        let wi = (w as f64).max(f64::MIN_POSITIVE);
        (wi.ln() - z.ln()) as f32
    };
    Ok(SampleOut {
        token: idx as i32,
        logprob,
        logprob_full,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(temp: f32) -> SamplingParams {
        SamplingParams {
            temperature: temp,
            ..Default::default()
        }
    }

    #[test]
    fn greedy_picks_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Pcg64::new(1);
        let s = sample(&logits, &params(0.0), &mut rng).unwrap();
        assert_eq!(s.token, 1);
        // point mass: probability 1 under the actual sampling law
        assert_eq!(s.logprob, 0.0);
        assert!(s.logprob_full < 0.0);
    }

    #[test]
    fn logprob_is_log_softmax() {
        let logits = vec![1.0, 1.0, 1.0, 1.0];
        let lp = log_softmax_at(&logits, 2);
        assert!((lp - (0.25f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn sampling_matches_distribution() {
        let logits = vec![0.0, (2.0f32).ln(), (4.0f32).ln()]; // p = 1:2:4
        let mut rng = Pcg64::new(2);
        let mut counts = [0usize; 3];
        for _ in 0..70_000 {
            let s = sample(&logits, &params(1.0), &mut rng).unwrap();
            counts[s.token as usize] += 1;
        }
        let total = 70_000f64;
        assert!((counts[0] as f64 / total - 1.0 / 7.0).abs() < 0.01);
        assert!((counts[2] as f64 / total - 4.0 / 7.0).abs() < 0.01);
    }

    #[test]
    fn top_k_truncates() {
        let logits = vec![5.0, 4.0, -100.0, -100.0];
        let mut rng = Pcg64::new(3);
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 2,
            ..Default::default()
        };
        for _ in 0..200 {
            let s = sample(&logits, &p, &mut rng).unwrap();
            assert!(s.token == 0 || s.token == 1);
        }
    }

    #[test]
    fn top_p_keeps_head() {
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        let p = SamplingParams {
            temperature: 1.0,
            top_p: 0.9,
            ..Default::default()
        };
        let mut rng = Pcg64::new(4);
        for _ in 0..200 {
            let s = sample(&logits, &p, &mut rng).unwrap();
            assert_eq!(s.token, 0); // head token alone has >90% mass
            // nucleus of one: the behavior distribution is a point mass
            assert!(s.logprob.abs() < 1e-6);
            assert!(s.logprob_full < 0.0);
        }
    }

    #[test]
    fn truncated_logprob_is_renormalized() {
        // regression (the headline PR-3 bugfix): after top-k truncation
        // the returned behavior logprob must be
        // log(weight_i / sum(kept weights)) under the temperature-scaled
        // weights the categorical draw used — NOT the full-vocabulary
        // log-softmax the old code returned
        let logits = vec![2.0f32, 1.0, 0.0, -1.0];
        let temp = 0.7f32;
        let p = SamplingParams {
            temperature: temp,
            top_k: 2,
            ..Default::default()
        };
        let mut rng = Pcg64::new(21);
        for _ in 0..200 {
            let s = sample(&logits, &p, &mut rng).unwrap();
            assert!(s.token == 0 || s.token == 1);
            // recompute the exact kept-set weights the sampler used
            let scaled: Vec<f32> =
                logits.iter().map(|&l| l / temp).collect();
            let m = scaled[0];
            let w: Vec<f64> = [0usize, 1]
                .iter()
                .map(|&i| (((scaled[i] - m) as f64).exp() as f32) as f64)
                .collect();
            let want =
                ((w[s.token as usize] / (w[0] + w[1])).ln()) as f32;
            assert!(
                (s.logprob - want).abs() < 1e-5,
                "behavior logprob {} != renormalized {}",
                s.logprob,
                want
            );
            let full = log_softmax_at(&logits, s.token as usize);
            assert!((s.logprob_full - full).abs() < 1e-6);
            assert!(
                (s.logprob - full).abs() > 1e-3,
                "truncated logprob must differ from the full-vocab one"
            );
        }
    }

    #[test]
    fn tis_weights_unbiased_under_truncation() {
        // importance-sampling identity: drawing from the truncated
        // distribution q with weights w = pi_full/q, E_q[w] must equal
        // the kept-set mass under pi_full (sum over supp(q) of pi).
        // With the old full-vocab behavior logprob every weight was
        // exactly 1 and the estimate degenerated to 1.0 — the bias that
        // skewed every TIS/MIS correction under truncation.
        let logits = vec![1.5f32, 0.7, 0.2, -0.4, -1.0];
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 2,
            ..Default::default()
        };
        let mut rng = Pcg64::new(31);
        let n = 50_000;
        let mut sum_w = 0.0f64;
        for _ in 0..n {
            let s = sample(&logits, &p, &mut rng).unwrap();
            sum_w += ((s.logprob_full - s.logprob) as f64).exp();
        }
        let est = sum_w / n as f64;
        let z: f64 = logits.iter().map(|&l| (l as f64).exp()).sum();
        let kept = ((1.5f64).exp() + (0.7f64).exp()) / z;
        assert!(
            (est - kept).abs() < 1e-3,
            "IS estimate {est} vs true kept mass {kept}"
        );
        assert!(
            (est - 1.0).abs() > 0.05,
            "weights degenerate to 1: behavior logprob is not the \
             sampling distribution"
        );
    }

    #[test]
    fn untruncated_temp1_behavior_equals_full_bitwise() {
        // the RL loop samples at temperature 1 with no truncation:
        // there the behavior logprob is routed through the same
        // log-softmax computation as the full-vocab diagnostic, so TIS
        // is BIT-identical for the paper's training runs (every weight
        // exactly exp(0) = 1 on-policy)
        let logits = vec![2.0, 0.5, -1.0, 0.0];
        let mut rng = Pcg64::new(11);
        for _ in 0..200 {
            let s = sample(&logits, &params(1.0), &mut rng).unwrap();
            assert_eq!(
                s.logprob, s.logprob_full,
                "untruncated temp-1 must share the log-softmax route"
            );
            let want = log_softmax_at(&logits, s.token as usize);
            assert_eq!(s.logprob, want, "pre-fix convention preserved");
        }
    }

    #[test]
    fn nan_logits_error_instead_of_panic() {
        // regression: the greedy path used to panic inside
        // partial_cmp().unwrap() on a NaN logit
        let nan = vec![0.0f32, f32::NAN, 1.0];
        let mut rng = Pcg64::new(41);
        assert!(sample(&nan, &params(0.0), &mut rng).is_err());
        assert!(sample(&nan, &params(1.0), &mut rng).is_err());
        let inf = vec![0.0f32, f32::INFINITY];
        assert!(sample(&inf, &params(1.0), &mut rng).is_err());
        let empty: Vec<f32> = Vec::new();
        assert!(sample(&empty, &params(1.0), &mut rng).is_err());
        let all_masked = vec![f32::NEG_INFINITY; 4];
        assert!(sample(&all_masked, &params(1.0), &mut rng).is_err());
        // -inf mixed with finite logits is a legal mask, not an error
        let masked = vec![f32::NEG_INFINITY, 1.0, 0.0];
        let s = sample(&masked, &params(1.0), &mut rng).unwrap();
        assert!(s.token == 1 || s.token == 2);
    }

    #[test]
    fn request_seed_is_pure_and_spreads() {
        assert_eq!(request_seed(7, 42), request_seed(7, 42));
        assert_ne!(request_seed(7, 42), request_seed(7, 43));
        assert_ne!(request_seed(7, 42), request_seed(8, 42));
        // consecutive ids must yield decorrelated streams
        let mut a = Pcg64::new(request_seed(1234, 1));
        let mut b = Pcg64::new(request_seed(1234, 2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn temperature_sharpens() {
        let logits = vec![1.0, 0.0];
        let mut rng = Pcg64::new(5);
        let mut hot = 0;
        let mut cold = 0;
        for _ in 0..20_000 {
            if sample(&logits, &params(2.0), &mut rng).unwrap().token == 0
            {
                hot += 1;
            }
            if sample(&logits, &params(0.25), &mut rng).unwrap().token
                == 0
            {
                cold += 1;
            }
        }
        assert!(cold > hot, "low temperature must concentrate: {cold} vs {hot}");
    }
}
