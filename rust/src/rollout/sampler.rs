//! Token sampler: temperature / top-k / top-p over a logit row, returning
//! the sampled token AND its logprob under the *untruncated* softmax of
//! the **raw** (temperature-free) logits — the rollout-policy logprob
//! pi_fp8 that the trainer's TIS/MIS correction consumes.
//!
//! Convention: temperature/top-k/top-p shape the *exploration*
//! distribution only. The returned logprob is always evaluated at
//! temperature 1 over the full vocabulary, because the trainer's
//! logprobs path evaluates pi_theta the same way and the TIS ratio
//! pi_theta/pi_fp8 must compare same-temperature quantities. (verl
//! computes pi_fp8 identically: full-vocabulary log-softmax of the
//! engine logits at the sampled token.) The greedy and sampled paths
//! used to disagree here — greedy returned raw-logit logprobs while
//! sampling returned temperature-scaled ones, silently skewing TIS.

use crate::util::rng::Pcg64;

use super::request::SamplingParams;

/// log-softmax value of index `idx` under logits (natural log).
pub fn log_softmax_at(logits: &[f32], idx: usize) -> f32 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let z: f64 = logits.iter().map(|&l| ((l - m) as f64).exp()).sum();
    (logits[idx] - m) as f64 as f32 - (z.ln() as f32)
}

/// Sample one token. Returns (token, logprob under the full softmax of
/// the raw logits — see the module docs for the convention).
pub fn sample(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Pcg64,
) -> (i32, f32) {
    if params.temperature <= 0.0 {
        // greedy
        let (idx, _) = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        return (idx as i32, log_softmax_at(logits, idx));
    }
    let scaled: Vec<f32> =
        logits.iter().map(|&l| l / params.temperature).collect();

    // candidate set after top-k / top-p truncation
    let mut order: Vec<usize> = (0..scaled.len()).collect();
    order.sort_by(|&a, &b| scaled[b].partial_cmp(&scaled[a]).unwrap());
    let mut keep = order.len();
    if params.top_k > 0 {
        keep = keep.min(params.top_k);
    }
    if params.top_p < 1.0 {
        let m = scaled[order[0]];
        let exps: Vec<f64> = order
            .iter()
            .map(|&i| ((scaled[i] - m) as f64).exp())
            .collect();
        let total: f64 = exps.iter().sum();
        let mut acc = 0.0;
        let mut np = 0;
        for e in exps.iter().take(keep) {
            acc += e / total;
            np += 1;
            if acc >= params.top_p as f64 {
                break;
            }
        }
        keep = np.max(1);
    }

    // sample within the kept set
    let m = scaled[order[0]];
    let weights: Vec<f32> = order[..keep]
        .iter()
        .map(|&i| ((scaled[i] - m) as f64).exp() as f32)
        .collect();
    let pick = rng.categorical(&weights);
    let idx = order[pick];
    (idx as i32, log_softmax_at(logits, idx))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(temp: f32) -> SamplingParams {
        SamplingParams {
            temperature: temp,
            ..Default::default()
        }
    }

    #[test]
    fn greedy_picks_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Pcg64::new(1);
        let (tok, lp) = sample(&logits, &params(0.0), &mut rng);
        assert_eq!(tok, 1);
        assert!(lp < 0.0);
    }

    #[test]
    fn logprob_is_log_softmax() {
        let logits = vec![1.0, 1.0, 1.0, 1.0];
        let lp = log_softmax_at(&logits, 2);
        assert!((lp - (0.25f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn sampling_matches_distribution() {
        let logits = vec![0.0, (2.0f32).ln(), (4.0f32).ln()]; // p = 1:2:4
        let mut rng = Pcg64::new(2);
        let mut counts = [0usize; 3];
        for _ in 0..70_000 {
            let (t, _) = sample(&logits, &params(1.0), &mut rng);
            counts[t as usize] += 1;
        }
        let total = 70_000f64;
        assert!((counts[0] as f64 / total - 1.0 / 7.0).abs() < 0.01);
        assert!((counts[2] as f64 / total - 4.0 / 7.0).abs() < 0.01);
    }

    #[test]
    fn top_k_truncates() {
        let logits = vec![5.0, 4.0, -100.0, -100.0];
        let mut rng = Pcg64::new(3);
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 2,
            ..Default::default()
        };
        for _ in 0..200 {
            let (t, _) = sample(&logits, &p, &mut rng);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn top_p_keeps_head() {
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        let p = SamplingParams {
            temperature: 1.0,
            top_p: 0.9,
            ..Default::default()
        };
        let mut rng = Pcg64::new(4);
        for _ in 0..200 {
            let (t, _) = sample(&logits, &p, &mut rng);
            assert_eq!(t, 0); // head token alone has >90% mass
        }
    }

    #[test]
    fn logprob_convention_is_temperature_free() {
        // regression: the sampled path used to return the log-softmax
        // of the temperature-SCALED logits while greedy used the raw
        // ones; both must report pi at temperature 1
        let logits = vec![2.0, 0.5, -1.0, 0.0];
        let mut rng = Pcg64::new(11);
        for temp in [0.0f32, 0.25, 1.0, 4.0] {
            for _ in 0..50 {
                let (tok, lp) = sample(&logits, &params(temp), &mut rng);
                let want = log_softmax_at(&logits, tok as usize);
                assert!(
                    (lp - want).abs() < 1e-6,
                    "temp {temp}: token {tok} logprob {lp} != {want}"
                );
            }
        }
    }

    #[test]
    fn greedy_and_sampled_paths_agree() {
        // a near-deterministic distribution: the low-temperature sample
        // picks the argmax, and its logprob must equal the greedy one
        let logits = vec![8.0, 0.0, 0.0, 0.0];
        let mut rng = Pcg64::new(12);
        let (g_tok, g_lp) = sample(&logits, &params(0.0), &mut rng);
        let (s_tok, s_lp) = sample(&logits, &params(0.05), &mut rng);
        assert_eq!(g_tok, s_tok);
        assert!(
            (g_lp - s_lp).abs() < 1e-6,
            "paths disagree: {g_lp} vs {s_lp}"
        );
    }

    #[test]
    fn temperature_sharpens() {
        let logits = vec![1.0, 0.0];
        let mut rng = Pcg64::new(5);
        let mut hot = 0;
        let mut cold = 0;
        for _ in 0..20_000 {
            if sample(&logits, &params(2.0), &mut rng).0 == 0 {
                hot += 1;
            }
            if sample(&logits, &params(0.25), &mut rng).0 == 0 {
                cold += 1;
            }
        }
        assert!(cold > hot, "low temperature must concentrate: {cold} vs {hot}");
    }
}
