//! Continuous-batching scheduler with recompute-style preemption — the
//! vLLM-like admission/eviction policy shared by the real HLO engine and
//! the H100 cost-model simulator (so the perf figures' preemption
//! dynamics come from the same code the live engine runs).
//!
//! Policy (vLLM defaults):
//! * admission: FCFS while a batch slot AND enough KV blocks for the
//!   prompt are available;
//! * growth: every running sequence appends one token per decode step;
//! * preemption: on block exhaustion evict the *newest* running sequence
//!   (recompute style — its blocks are released and the request requeued
//!   at the front of the waiting queue with generation restarted).

use std::collections::{BTreeMap, VecDeque};

use super::kvcache::KvBlockManager;
use super::request::Request;

#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    pub admitted: u64,
    pub finished: u64,
    pub preemptions: u64,
}

pub struct Scheduler {
    pub kv: KvBlockManager,
    pub max_batch: usize,
    waiting: VecDeque<Request>,
    /// running seq ids in admission order (newest last)
    running: Vec<u64>,
    /// request bodies for requeue-on-preemption
    bodies: BTreeMap<u64, Request>,
    pub stats: SchedulerStats,
}

pub struct ExtendReport {
    /// sequences preempted during this step (engine must clear them)
    pub preempted: Vec<u64>,
}

impl Scheduler {
    pub fn new(kv: KvBlockManager, max_batch: usize) -> Scheduler {
        Scheduler {
            kv,
            max_batch,
            waiting: VecDeque::new(),
            running: Vec::new(),
            bodies: BTreeMap::new(),
            stats: SchedulerStats::default(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    pub fn running_ids(&self) -> &[u64] {
        &self.running
    }

    /// Peek the head-of-line waiting request (FCFS order) — the engine
    /// uses it to diagnose permanently-stuck admissions.
    pub fn head_of_line(&self) -> Option<&Request> {
        self.waiting.front()
    }

    /// Admit as many waiting requests as fit. Returns the newly admitted
    /// requests (the engine assigns them to slots and starts prefill).
    pub fn admit(&mut self) -> Vec<Request> {
        self.admit_with(|_| 0)
    }

    /// Admission with per-request extra token reservations — recompute
    /// re-admission reserves (prompt + preserved generation) atomically,
    /// so a preempted sequence waits at the queue head until its whole
    /// footprint fits (no admit/evict thrash).
    pub fn admit_with<F: Fn(u64) -> usize>(
        &mut self,
        extra: F,
    ) -> Vec<Request> {
        let mut out = Vec::new();
        while self.running.len() < self.max_batch {
            let Some(front) = self.waiting.front() else { break };
            // +1 growth reserve so a fresh admission can't instantly
            // deadlock the running set
            let tokens = front.prompt.len() + extra(front.id);
            if !self.kv.can_allocate(tokens + 1) {
                break;
            }
            let req = self.waiting.pop_front().unwrap();
            assert!(self.kv.allocate(req.id, tokens));
            self.running.push(req.id);
            self.bodies.insert(req.id, req.clone());
            self.stats.admitted += 1;
            out.push(req);
        }
        out
    }

    /// Grow the given running sequences by one token each, preempting
    /// (newest first) when blocks run out. Callers pass only sequences
    /// that consumed a *new* (non-preallocated-prompt) token this step.
    pub fn extend_all(&mut self, ids: &[u64]) -> ExtendReport {
        let mut preempted = Vec::new();
        for &id in ids {
            // may already have been preempted this step
            if !self.kv.has_seq(id) {
                continue;
            }
            loop {
                if self.kv.append_token(id) {
                    break;
                }
                // out of blocks: evict the newest running seq
                let victim = *self.running.last().unwrap();
                self.preempt(victim);
                preempted.push(victim);
                if victim == id {
                    break; // the extending seq itself was evicted
                }
            }
        }
        ExtendReport { preempted }
    }

    /// Evict the newest running sequence (used by callers that need to
    /// make room outside the extend path, e.g. readmission top-up).
    /// Returns the victim id.
    pub fn preempt_newest(&mut self) -> Option<u64> {
        let victim = *self.running.last()?;
        self.preempt(victim);
        Some(victim)
    }

    fn preempt(&mut self, id: u64) {
        self.kv.release(id);
        self.running.retain(|&r| r != id);
        let body = self.bodies.remove(&id).expect("preempting unknown seq");
        self.waiting.push_front(body);
        self.stats.preemptions += 1;
    }

    /// Mark a sequence finished and release its blocks.
    pub fn finish(&mut self, id: u64) {
        self.kv.release(id);
        self.running.retain(|&r| r != id);
        self.bodies.remove(&id);
        self.stats.finished += 1;
    }

    /// Invariants for the property suite.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()?;
        if self.running.len() > self.max_batch {
            return Err("running set exceeds max batch".into());
        }
        for id in &self.running {
            if !self.bodies.contains_key(id) {
                return Err(format!("running seq {id} has no body"));
            }
            if !self.kv.has_seq(*id) {
                return Err(format!("running seq {id} has no kv alloc"));
            }
        }
        if self.bodies.len() != self.running.len() {
            return Err("body map out of sync with running set".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rollout::kvcache::{KvGeometry, KvPrecision};
    use crate::rollout::request::SamplingParams;

    fn mk(blocks: usize, max_batch: usize) -> Scheduler {
        let geo = KvGeometry {
            n_layers: 2,
            n_kv_heads: 2,
            d_head: 8,
            block_tokens: 4,
            precision: KvPrecision::Bf16,
        };
        Scheduler::new(KvBlockManager::new(geo, blocks), max_batch)
    }

    fn req(id: u64, plen: usize) -> Request {
        Request {
            id,
            prompt: vec![1; plen],
            params: SamplingParams::default(),
        }
    }

    #[test]
    fn fcfs_admission() {
        let mut s = mk(100, 2);
        s.submit(req(1, 4));
        s.submit(req(2, 4));
        s.submit(req(3, 4));
        let admitted = s.admit();
        assert_eq!(
            admitted.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![1, 2]
        );
        assert_eq!(s.n_waiting(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn admission_blocked_by_kv() {
        let mut s = mk(2, 8); // 2 blocks = 8 tokens
        s.submit(req(1, 4)); // 1 block + growth reserve
        s.submit(req(2, 8)); // needs 2 blocks + growth: can't fit
        let admitted = s.admit();
        assert_eq!(admitted.len(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn preemption_evicts_newest_and_requeues() {
        let mut s = mk(4, 4); // 16 tokens total
        s.submit(req(1, 4));
        s.submit(req(2, 4));
        s.submit(req(3, 4));
        assert_eq!(s.admit().len(), 3); // 3 blocks used, 1 free
        // grow until exhaustion: each seq fills its block after 0 appends
        // (4-token prompts exactly fill blocks), so extends need blocks
        let ids = s.running_ids().to_vec();
        let rep = s.extend_all(&ids);
        // seq1 takes the last free block; seq2's extend evicts newest (3);
        // seq2 takes the freed block; seq3 is gone.
        assert_eq!(rep.preempted, vec![3]);
        assert_eq!(s.n_running(), 2);
        assert_eq!(s.n_waiting(), 1);
        assert_eq!(s.stats.preemptions, 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn self_preemption_when_alone() {
        let mut s = mk(1, 2); // 4 tokens
        s.submit(req(1, 4)); // exactly fills the only block...
        let admitted = s.admit();
        // needs prompt+1 growable -> cannot admit at all
        assert!(admitted.is_empty());
        s.check_invariants().unwrap();
    }

    #[test]
    fn finish_releases_capacity() {
        let mut s = mk(2, 2);
        s.submit(req(1, 4));
        s.submit(req(2, 4));
        assert_eq!(s.admit().len(), 1); // only one fits with reserve
        s.finish(1);
        assert_eq!(s.admit().len(), 1);
        s.check_invariants().unwrap();
    }
}
