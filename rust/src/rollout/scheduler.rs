//! Continuous-batching scheduler with recompute-style preemption — the
//! vLLM-like admission/eviction policy shared by the real HLO engine and
//! the H100 cost-model simulator (so the perf figures' preemption
//! dynamics come from the same code the live engine runs).
//!
//! Policy (vLLM defaults):
//! * admission: FCFS while a batch slot AND enough KV blocks for the
//!   prompt are available;
//! * growth: every running sequence appends one token per decode step;
//! * preemption: on block exhaustion evict the *newest* running sequence
//!   (recompute style — its blocks are released and the request requeued
//!   at the front of the waiting queue with generation restarted).

use std::collections::{BTreeMap, VecDeque};

use super::kvcache::KvBlockManager;
use super::request::Request;
use crate::util::error::{bail, Context, Result};
use crate::util::units::{Blocks, Tokens};

#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    pub admitted: u64,
    pub finished: u64,
    pub preemptions: u64,
}

pub struct Scheduler {
    pub kv: KvBlockManager,
    pub max_batch: usize,
    waiting: VecDeque<Request>,
    /// running seq ids in admission order (newest last)
    running: Vec<u64>,
    /// request bodies for requeue-on-preemption
    bodies: BTreeMap<u64, Request>,
    /// admit via `allocate_shared` (charge only incremental blocks)
    prefix_sharing: bool,
    pub stats: SchedulerStats,
}

pub struct ExtendReport {
    /// sequences preempted during this step (engine must clear them)
    pub preempted: Vec<u64>,
}

impl Scheduler {
    pub fn new(kv: KvBlockManager, max_batch: usize) -> Scheduler {
        Scheduler {
            kv,
            max_batch,
            waiting: VecDeque::new(),
            running: Vec::new(),
            bodies: BTreeMap::new(),
            prefix_sharing: false,
            stats: SchedulerStats::default(),
        }
    }

    /// Route admissions through `KvBlockManager::allocate_shared`:
    /// a request is charged only the blocks its prompt prefix does
    /// NOT already share. Off by default; sharing is a pure memory
    /// optimization (DESIGN.md §10), so outputs are bit-identical
    /// either way.
    pub fn set_prefix_sharing(&mut self, on: bool) {
        self.prefix_sharing = on;
    }

    pub fn prefix_sharing(&self) -> bool {
        self.prefix_sharing
    }

    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    pub fn running_ids(&self) -> &[u64] {
        &self.running
    }

    /// Peek the head-of-line waiting request (FCFS order) — the engine
    /// uses it to diagnose permanently-stuck admissions.
    pub fn head_of_line(&self) -> Option<&Request> {
        self.waiting.front()
    }

    /// Admit as many waiting requests as fit. Returns the newly admitted
    /// requests (the engine assigns them to slots and starts prefill).
    pub fn admit(&mut self) -> Vec<Request> {
        self.admit_with(|_| Tokens::ZERO)
    }

    /// Admission with per-request extra token reservations — recompute
    /// re-admission reserves (prompt + preserved generation) atomically,
    /// so a preempted sequence waits at the queue head until its whole
    /// footprint fits (no admit/evict thrash).
    ///
    /// Growth reserves are CUMULATIVE across the whole admission round:
    /// the old `can_allocate(tokens + 1)` check was per-request, so two
    /// same-round admissions could each consume the other's +1 growth
    /// block and preempt-thrash on their very first generated token.
    /// The reserve also covers every already-running sequence whose
    /// allocation is exactly full (it takes a fresh block on its next
    /// append), so a sequence admitted in round N is never preempted by
    /// the `extend_all` of round N. This is deliberately pessimistic
    /// about non-growing runners: a re-admitted sequence still
    /// replaying its prompt sits at a boundary without appending for a
    /// few rounds, and we reserve for it anyway — a small throughput
    /// cost for a thrash-freedom guarantee that needs no caller hints.
    ///
    /// With prefix sharing on, the same cumulative structure holds
    /// over free-list blocks: a candidate is charged only its
    /// *incremental* need (`shared_admission_need`), and the running-
    /// set reserve counts `append_needs_block` — boundary growth OR a
    /// shared tail whose next append takes a copy-on-write block. G
    /// same-round sharers of one partial tail consume exactly G-1 COW
    /// blocks on their first appends, matching the G-1 growth deltas
    /// accumulated here, so the no-same-round-preemption guarantee is
    /// preserved (DESIGN.md §10).
    pub fn admit_with<F: Fn(u64) -> Tokens>(
        &mut self,
        extra: F,
    ) -> Vec<Request> {
        let mut out = Vec::new();
        let mut reserve: Blocks = Blocks::new(
            self.running
                .iter()
                .filter(|id| self.kv.append_needs_block(**id))
                .count(),
        );
        while self.running.len() < self.max_batch {
            let Some(front) = self.waiting.front() else { break };
            let tokens = Tokens::new(front.prompt.len())
                .saturating_add(extra(front.id))
                .max(Tokens::new(1));
            // +1 growth reserve (need_grown vs need_now) so a fresh
            // admission can't instantly deadlock the running set
            let (need_now, need_grown) = if self.prefix_sharing {
                self.kv.shared_admission_need(tokens, &front.prompt)
            } else {
                (
                    self.kv.blocks_for(tokens),
                    self.kv.blocks_for(
                        tokens.saturating_add(Tokens::new(1)),
                    ),
                )
            };
            if need_grown.saturating_add(reserve) > self.kv.free_blocks() {
                break;
            }
            let Some(req) = self.waiting.pop_front() else { break };
            if self.prefix_sharing {
                assert!(self
                    .kv
                    .allocate_shared(req.id, tokens, &req.prompt)
                    .is_some());
            } else {
                assert!(self.kv.allocate(req.id, tokens));
            }
            // blocks_for is monotone in tokens, so the growth delta is
            // >= 0; saturate both steps so a future geometry change
            // can't turn this into a silent wrap
            reserve =
                reserve.saturating_add(need_grown.saturating_sub(need_now));
            self.running.push(req.id);
            self.bodies.insert(req.id, req.clone());
            self.stats.admitted += 1;
            out.push(req);
        }
        out
    }

    /// Drop every queued and running request (the engine's error
    /// path): KV blocks are released, bodies cleared, the waiting
    /// queue emptied. Drained work counts as neither finished nor
    /// preempted.
    pub fn drain(&mut self) {
        for id in std::mem::take(&mut self.running) {
            self.kv.release(id);
        }
        self.bodies.clear();
        self.waiting.clear();
    }

    /// Grow the given running sequences by one token each, preempting
    /// (newest first) when blocks run out. Callers pass only sequences
    /// that consumed a *new* (non-preallocated-prompt) token this step.
    pub fn extend_all(&mut self, ids: &[u64]) -> Result<ExtendReport> {
        let mut preempted = Vec::new();
        for &id in ids {
            // may already have been preempted this step
            if !self.kv.has_seq(id) {
                continue;
            }
            loop {
                if self.kv.append_token(id)? {
                    break;
                }
                // out of blocks: evict the newest running seq. The
                // extending seq itself is running, so the set can't be
                // empty here — an empty set means corrupt bookkeeping.
                let Some(&victim) = self.running.last() else {
                    bail!(
                        "seq {id} needs a block but the running set \
                         is empty"
                    );
                };
                self.preempt(victim)?;
                preempted.push(victim);
                if victim == id {
                    break; // the extending seq itself was evicted
                }
            }
        }
        Ok(ExtendReport { preempted })
    }

    /// Evict the newest running sequence (used by callers that need to
    /// make room outside the extend path, e.g. readmission top-up).
    /// Returns the victim id.
    pub fn preempt_newest(&mut self) -> Result<Option<u64>> {
        let Some(&victim) = self.running.last() else {
            return Ok(None);
        };
        self.preempt(victim)?;
        Ok(Some(victim))
    }

    fn preempt(&mut self, id: u64) -> Result<()> {
        self.kv.release(id);
        self.running.retain(|&r| r != id);
        let body = self
            .bodies
            .remove(&id)
            .with_context(|| format!("preempting unknown seq {id}"))?;
        self.waiting.push_front(body);
        self.stats.preemptions += 1;
        Ok(())
    }

    /// Remove one queued or running request entirely (the streaming
    /// abort path): KV blocks released, body dropped, waiting entry
    /// removed. Cancelled work counts as neither finished nor
    /// preempted. Returns `false` when the id is unknown (it already
    /// finished or was never submitted).
    pub fn cancel(&mut self, id: u64) -> bool {
        if self.running.contains(&id) {
            self.kv.release(id);
            self.running.retain(|&r| r != id);
            self.bodies.remove(&id);
            return true;
        }
        let before = self.waiting.len();
        self.waiting.retain(|r| r.id != id);
        before != self.waiting.len()
    }

    /// Every id the scheduler still owes a completion for: waiting
    /// (including preempted-and-requeued) plus running. The streaming
    /// worker reports these as failed when a step errors out.
    pub fn outstanding_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> =
            self.waiting.iter().map(|r| r.id).collect();
        ids.extend_from_slice(&self.running);
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Mark a sequence finished and release its blocks. Returns
    /// `false` for an unknown (never-admitted or already-finished)
    /// id: the old version unconditionally bumped `stats.finished`
    /// and issued a no-op release, so a double-finish inflated the
    /// finished counter the CSV metrics report.
    pub fn finish(&mut self, id: u64) -> bool {
        if !self.kv.has_seq(id) {
            return false;
        }
        self.kv.release(id);
        self.running.retain(|&r| r != id);
        self.bodies.remove(&id);
        self.stats.finished += 1;
        true
    }

    /// Invariants for the property suite.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()?;
        if self.running.len() > self.max_batch {
            return Err("running set exceeds max batch".into());
        }
        for id in &self.running {
            if !self.bodies.contains_key(id) {
                return Err(format!("running seq {id} has no body"));
            }
            if !self.kv.has_seq(*id) {
                return Err(format!("running seq {id} has no kv alloc"));
            }
        }
        if self.bodies.len() != self.running.len() {
            return Err("body map out of sync with running set".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rollout::kvcache::{KvGeometry, KvPrecision};
    use crate::rollout::request::SamplingParams;

    fn mk(blocks: usize, max_batch: usize) -> Scheduler {
        let geo = KvGeometry {
            n_layers: 2,
            n_kv_heads: 2,
            d_head: 8,
            block_tokens: 4,
            precision: KvPrecision::Bf16,
        };
        Scheduler::new(
            KvBlockManager::new(geo, crate::util::units::Blocks::new(blocks))
                .unwrap(),
            max_batch,
        )
    }

    fn req(id: u64, plen: usize) -> Request {
        Request {
            id,
            prompt: vec![1; plen],
            params: SamplingParams::default(),
        }
    }

    #[test]
    fn fcfs_admission() {
        let mut s = mk(100, 2);
        s.submit(req(1, 4));
        s.submit(req(2, 4));
        s.submit(req(3, 4));
        let admitted = s.admit();
        assert_eq!(
            admitted.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![1, 2]
        );
        assert_eq!(s.n_waiting(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn admission_blocked_by_kv() {
        let mut s = mk(2, 8); // 2 blocks = 8 tokens
        s.submit(req(1, 4)); // 1 block + growth reserve
        s.submit(req(2, 8)); // needs 2 blocks + growth: can't fit
        let admitted = s.admit();
        assert_eq!(admitted.len(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn preemption_evicts_newest_and_requeues() {
        let mut s = mk(4, 4); // 16 tokens total
        s.submit(req(1, 4));
        s.submit(req(2, 4));
        s.submit(req(3, 4));
        // the cumulative growth reserve admits only 2 of the 3
        // block-filling prompts: 2 prompt blocks + 2 reserved = 4
        assert_eq!(s.admit().len(), 2);
        let ids = s.running_ids().to_vec();
        // first extend consumes exactly the reserved blocks: no thrash
        let rep = s.extend_all(&ids).unwrap();
        assert!(rep.preempted.is_empty());
        s.check_invariants().unwrap();
        // grow until exhaustion (cache full at 8 tokens each): the
        // NEWEST sequence is evicted and requeued at the front
        let mut preempted = Vec::new();
        for _ in 0..4 {
            preempted.extend(s.extend_all(&ids).unwrap().preempted);
        }
        assert_eq!(preempted, vec![2]);
        assert_eq!(s.n_running(), 1);
        assert_eq!(s.n_waiting(), 2);
        assert_eq!(s.stats.preemptions, 1);
        assert_eq!(s.head_of_line().unwrap().id, 2, "requeued at front");
        s.check_invariants().unwrap();
    }

    #[test]
    fn same_round_admissions_reserve_growth_cumulatively() {
        // regression: `admit` used to check can_allocate(tokens + 1)
        // per request but allocate only `tokens`, so two exactly-
        // block-filling prompts admitted in the same round shared ONE
        // free growth block and preempt-thrashed on their first
        // generated token. With the cumulative reserve the second
        // admission waits; nobody is preempted in its admission round.
        let mut s = mk(3, 4); // 3 blocks of 4 tokens
        s.submit(req(1, 4)); // exactly fills a block
        s.submit(req(2, 4)); // exactly fills a block
        let admitted = s.admit();
        assert_eq!(admitted.len(), 1, "one growth block can't serve two");
        let ids = s.running_ids().to_vec();
        let rep = s.extend_all(&ids).unwrap();
        assert!(
            rep.preempted.is_empty(),
            "no same-step preemption after admission"
        );
        s.check_invariants().unwrap();
        // the head-of-line request is admitted once capacity frees up
        s.finish(1);
        assert_eq!(s.admit().len(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn drain_clears_queued_and_running() {
        let mut s = mk(100, 2);
        s.submit(req(1, 4));
        s.submit(req(2, 4));
        s.submit(req(3, 4));
        assert_eq!(s.admit().len(), 2);
        s.drain();
        assert!(s.is_idle());
        assert_eq!(s.kv.used_blocks(), crate::util::units::Blocks::ZERO);
        s.check_invariants().unwrap();
        // the scheduler is immediately reusable
        s.submit(req(4, 4));
        assert_eq!(s.admit().len(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn self_preemption_when_alone() {
        let mut s = mk(1, 2); // 4 tokens
        s.submit(req(1, 4)); // exactly fills the only block...
        let admitted = s.admit();
        // needs prompt+1 growable -> cannot admit at all
        assert!(admitted.is_empty());
        s.check_invariants().unwrap();
    }

    #[test]
    fn cancel_removes_queued_and_running_without_stats() {
        let mut s = mk(100, 2);
        s.submit(req(1, 4));
        s.submit(req(2, 4));
        s.submit(req(3, 4)); // stays waiting (max_batch 2)
        assert_eq!(s.admit().len(), 2);
        assert_eq!(s.outstanding_ids(), vec![1, 2, 3]);
        assert!(s.cancel(1), "running request cancels");
        assert!(s.cancel(3), "waiting request cancels");
        assert!(!s.cancel(99), "unknown id is inert");
        assert_eq!(s.outstanding_ids(), vec![2]);
        assert_eq!(s.stats.finished, 0);
        assert_eq!(s.stats.preemptions, 0);
        s.check_invariants().unwrap();
        // capacity came back: a fresh request admits immediately
        s.submit(req(4, 4));
        assert_eq!(s.admit().len(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn cancel_reaches_a_preempted_and_requeued_request() {
        // abort propagation must cover every place a request can live:
        // a preempted sequence sits requeued at the FRONT of the
        // waiting queue (not in `running`), and cancelling it there
        // must free its place so the fence/drain it was blocking can
        // proceed
        let mut s = mk(4, 4);
        s.submit(req(1, 4));
        s.submit(req(2, 4));
        assert_eq!(s.admit().len(), 2);
        let ids = s.running_ids().to_vec();
        // grow until the newest (2) is evicted and requeued
        let mut preempted = Vec::new();
        for _ in 0..5 {
            preempted.extend(s.extend_all(&ids).unwrap().preempted);
        }
        assert_eq!(preempted, vec![2]);
        assert_eq!(s.head_of_line().unwrap().id, 2);
        assert!(s.cancel(2), "preempted request must cancel");
        assert_eq!(s.outstanding_ids(), vec![1]);
        s.check_invariants().unwrap();
        // and the engine can run dry without ever re-admitting 2
        s.finish(1);
        assert!(s.is_idle());
        s.check_invariants().unwrap();
    }

    #[test]
    fn finish_releases_capacity() {
        let mut s = mk(2, 2);
        s.submit(req(1, 4));
        s.submit(req(2, 4));
        assert_eq!(s.admit().len(), 1); // only one fits with reserve
        assert!(s.finish(1));
        assert_eq!(s.admit().len(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn double_finish_does_not_inflate_stats() {
        // regression: finish() used to bump stats.finished and release
        // unconditionally, so finishing an unknown or already-finished
        // id corrupted the CSV metrics
        let mut s = mk(100, 2);
        s.submit(req(1, 4));
        assert_eq!(s.admit().len(), 1);
        assert!(s.finish(1), "first finish succeeds");
        assert!(!s.finish(1), "second finish is rejected");
        assert!(!s.finish(99), "never-admitted id is rejected");
        assert_eq!(s.stats.finished, 1);
        s.check_invariants().unwrap();
    }

    fn shared_req(id: u64, prompt: &[i32]) -> Request {
        Request {
            id,
            prompt: prompt.to_vec(),
            params: SamplingParams::default(),
        }
    }

    #[test]
    fn shared_admission_charges_only_incremental_blocks() {
        // a GRPO group of 4 over one 8-token prompt (2 blocks of 4):
        // unshared needs 4x(2+1 growth); shared needs 2 unique prompt
        // blocks + per-member COW/growth reserve
        let prompt: Vec<i32> = (10..18).collect();
        let mut s = mk(6, 8); // far too small for 4 private copies
        s.set_prefix_sharing(true);
        assert!(s.prefix_sharing());
        for id in 0..4 {
            s.submit(shared_req(id, &prompt));
        }
        let admitted = s.admit();
        assert_eq!(
            admitted.len(),
            4,
            "sharing admits the whole group into 6 blocks"
        );
        assert_eq!(s.kv.used_blocks(), Blocks::new(2), "one prompt copy");
        s.check_invariants().unwrap();
        // every member grows one token: each needs its own block past
        // the shared boundary, covered by the admission reserve
        let ids = s.running_ids().to_vec();
        let rep = s.extend_all(&ids).unwrap();
        assert!(rep.preempted.is_empty(), "reserve covered group growth");
        s.check_invariants().unwrap();
        // the same workload without sharing admits at most 2 members
        let mut u = mk(6, 8);
        for id in 0..4 {
            u.submit(shared_req(id, &prompt));
        }
        assert!(u.admit().len() < 4, "private copies must not all fit");
        u.check_invariants().unwrap();
    }

    #[test]
    fn shared_group_growth_reserve_is_cumulative_over_cow_blocks() {
        // 5-token prompt: 1 full block + a shared partial tail. Each
        // sharer's first append copy-on-writes the tail, so G sharers
        // need G-1 extra blocks (the last owns the tail at rc 1) —
        // admission must reserve them cumulatively or the group
        // thrashes on its first decode step.
        let prompt = [7, 8, 9, 10, 11];
        let mut s = mk(4, 8); // 2 prompt + 2 spare
        s.set_prefix_sharing(true);
        for id in 0..3 {
            s.submit(shared_req(id, &prompt));
        }
        // member 0 takes 2 blocks; members 1,2 are fully shared but
        // each adds a +1 growth delta; 2 spares cover only one of them
        // plus member 0's in-place tail headroom
        let admitted = s.admit();
        assert!(
            admitted.len() >= 2,
            "at least two members fit with reserve"
        );
        let ids = s.running_ids().to_vec();
        let rep = s.extend_all(&ids).unwrap();
        assert!(
            rep.preempted.is_empty(),
            "no same-round preemption with COW-aware reserve"
        );
        s.check_invariants().unwrap();
    }
}
