//! Data-parallel rollout pool: one OS thread per engine replica behind
//! the [`Router`] — the serving-shaped, multicore-scaling front end the
//! ROADMAP's multi-engine item asks for.
//!
//! ## Threading model
//!
//! The RefBackend's device buffers are `Rc<RefCell<_>>` cells, so a
//! `Runtime` (and everything holding its buffers) is deliberately
//! **not** `Send`. The pool therefore never moves an engine between
//! threads: each worker thread calls the [`RuntimeFactory`] and builds
//! its own `Runtime` + [`HloEngine`] *inside* the thread, and all
//! coordination happens over `mpsc` channels carrying only `Send` data
//! (requests, completions, host arrays, stats). Engines are
//! thread-confined for their whole life.
//!
//! ## Determinism
//!
//! N-replica output is bit-identical to a single engine with the same
//! seed, for any routing policy and any replica count:
//!
//! * every request samples from its own RNG stream derived purely from
//!   (engine seed, request id) — see `sampler::request_seed` — so the
//!   stream does not depend on which replica, batch, or slot the
//!   request lands in;
//! * the RefBackend computes each batch row independently and its
//!   chunked prefill reproduces the wave bit-exactly, so logits for a
//!   request do not depend on its batch neighbors;
//! * results are merged by sorting on request id — the same stable
//!   order a single engine returns.
//!
//! ## Weight sync
//!
//! `install_weights` broadcasts ONE `Arc`'d quantized parameter list to
//! every replica (see `WeightSync::run_shared`): quantization happens
//! once per sync regardless of replica count; each worker then uploads
//! into its own persistent device buffers. `install_kv_scales`
//! broadcasts the recalibrated scales the same way. Channel FIFO order
//! guarantees a subsequent `generate` on any replica sees the install.
//!
//! ## Failure semantics
//!
//! `generate` is all-or-nothing, matching `HloEngine::generate`: if any
//! replica fails, the pool drains EVERY routed id from the router as
//! aborted — including ids a healthy replica completed, since their
//! results are dropped with the batch (a failed engine already drained
//! its own scheduler) — tells those replicas to count the dropped
//! tokens as discarded (preserving the "tokens_generated counts only
//! delivered tokens" invariant), and returns the first error. Router
//! settlement happens only once the batch outcome is known, so the
//! `completed`/`aborted` counters describe what the caller actually
//! received.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::runtime::{HostArray, Runtime};
use crate::util::error::{anyhow, bail, Context, Error, Result};

use super::engine::{EngineConfig, EngineStats, HloEngine};
use super::request::{Completion, Request};
use super::router::{RoutePolicy, Router};

/// Builds one thread-confined `Runtime` per replica, called inside the
/// worker thread (runtimes are not `Send` — see the module docs).
pub type RuntimeFactory = Arc<dyn Fn() -> Result<Runtime> + Send + Sync>;

/// The default factory: real artifacts when `<dir>/manifest.json`
/// exists, else the hermetic synthetic runtime — `Runtime::new_quiet`,
/// so N replicas don't log N missing-manifest warnings.
pub fn runtime_factory(artifacts_dir: impl Into<String>) -> RuntimeFactory {
    let dir: String = artifacts_dir.into();
    Arc::new(move || Runtime::new_quiet(dir.clone()))
}

/// A factory that always builds the hermetic synthetic runtime,
/// independent of what exists on disk (what the test suites use).
pub fn hermetic_runtime_factory() -> RuntimeFactory {
    Arc::new(|| Ok(Runtime::hermetic()))
}

/// A factory mirroring an existing runtime's manifest source: replicas
/// load the same artifacts directory the caller's runtime did, or get
/// the hermetic synthetic runtime when that manifest was built
/// in-process (`Manifest::is_synthetic`). This is what keeps pool
/// replicas and the
/// trainer serving the SAME model — never derive the replica source
/// from a second, separately-configured path. If the on-disk manifest
/// has vanished since the parent runtime loaded it, replica
/// construction FAILS instead of silently falling back to the
/// synthetic toy model (which would be exactly the
/// train-one-model/sample-another divergence this factory prevents).
pub fn factory_like(rt: &Runtime) -> RuntimeFactory {
    let dir = rt.manifest.dir.clone();
    if rt.manifest.is_synthetic() {
        hermetic_runtime_factory()
    } else {
        Arc::new(move || {
            if !dir.join("manifest.json").exists() {
                bail!(
                    "replica runtime source {dir:?} has no \
                     manifest.json (it existed when the parent \
                     runtime loaded) — refusing the synthetic fallback"
                );
            }
            Runtime::new_quiet(dir.clone())
        })
    }
}

#[derive(Clone, Debug)]
pub struct PoolConfig {
    pub n_replicas: usize,
    pub policy: RoutePolicy,
    /// per-replica engine configuration (every replica gets the same
    /// seed — request streams are keyed by request id, not replica)
    pub engine: EngineConfig,
}

enum ToWorker {
    Generate(Vec<Request>, Sender<(usize, Result<Vec<Completion>>)>),
    InstallWeights(Arc<Vec<HostArray>>, Sender<(usize, Result<()>)>),
    InstallKvScales(f32, f32),
    /// Count `n` delivered-then-dropped tokens as discarded (pool-level
    /// all-or-nothing failure).
    Discard(u64),
    Stats(Sender<(usize, EngineStats)>),
    Shutdown,
}

fn worker_main(
    replica: usize,
    cfg: EngineConfig,
    factory: RuntimeFactory,
    rx: Receiver<ToWorker>,
    init: Sender<(usize, Result<()>)>,
) {
    let built =
        factory().and_then(|rt| HloEngine::new(Arc::new(rt), cfg));
    let mut engine = match built {
        Ok(e) => {
            let _ = init.send((replica, Ok(())));
            e
        }
        Err(e) => {
            let _ = init.send((replica, Err(e)));
            return;
        }
    };
    drop(init);
    while let Ok(msg) = rx.recv() {
        match msg {
            ToWorker::Generate(reqs, reply) => {
                let res = engine.generate(reqs);
                let _ = reply.send((replica, res));
            }
            ToWorker::InstallWeights(w, reply) => {
                let _ = reply.send((replica, engine.install_weights(&w)));
            }
            ToWorker::InstallKvScales(k, v) => {
                engine.install_kv_scales(k, v);
            }
            ToWorker::Discard(n) => {
                engine.stats.discard_tokens(n);
            }
            ToWorker::Stats(reply) => {
                let _ = reply.send((replica, engine.stats.clone()));
            }
            ToWorker::Shutdown => break,
        }
    }
}

pub struct EnginePool {
    cfg: PoolConfig,
    router: Router,
    workers: Vec<Sender<ToWorker>>,
    handles: Vec<Option<JoinHandle<()>>>,
}

impl EnginePool {
    pub fn new(cfg: PoolConfig, factory: RuntimeFactory) -> Result<Self> {
        if cfg.n_replicas == 0 {
            bail!("engine pool needs at least one replica");
        }
        let mut workers = Vec::with_capacity(cfg.n_replicas);
        let mut handles = Vec::with_capacity(cfg.n_replicas);
        let (init_tx, init_rx) = channel();
        for replica in 0..cfg.n_replicas {
            let (tx, rx) = channel::<ToWorker>();
            let f = factory.clone();
            let ecfg = cfg.engine.clone();
            let itx = init_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("engine-pool-{replica}"))
                .spawn(move || worker_main(replica, ecfg, f, rx, itx));
            let handle = match spawned {
                Ok(h) => h,
                Err(e) => {
                    // same cleanup as the init-failure path below:
                    // closing the channels unblocks the workers we
                    // already spawned, and joining bounds their life
                    drop(workers);
                    drop(init_tx);
                    for h in handles.iter_mut() {
                        if let Some(h) = h.take() {
                            let _ = h.join();
                        }
                    }
                    return Err(Error::from(e).wrap(format!(
                        "spawning pool worker {replica}"
                    )));
                }
            };
            workers.push(tx);
            handles.push(Some(handle));
        }
        drop(init_tx);
        let mut failed: Option<Error> = None;
        for _ in 0..cfg.n_replicas {
            match init_rx.recv() {
                Ok((_, Ok(()))) => {}
                Ok((replica, Err(e))) => {
                    failed.get_or_insert(
                        e.wrap(format!("replica {replica} failed to start")),
                    );
                }
                Err(_) => {
                    failed.get_or_insert_with(|| {
                        anyhow!("a pool worker died during startup")
                    });
                    break;
                }
            }
        }
        if let Some(e) = failed {
            // closing the channels unblocks surviving workers' recv
            drop(workers);
            for h in handles.iter_mut() {
                if let Some(h) = h.take() {
                    let _ = h.join();
                }
            }
            return Err(e);
        }
        let router = Router::new(cfg.policy, cfg.n_replicas);
        Ok(EnginePool {
            cfg,
            router,
            workers,
            handles,
        })
    }

    pub fn n_replicas(&self) -> usize {
        self.workers.len()
    }

    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    /// Outstanding router load per replica (drains to zero once every
    /// request has completed or been aborted).
    pub fn loads(&self) -> &[u64] {
        self.router.loads()
    }

    /// Generate completions for a batch: route every request through
    /// the router, fan the shards out to the worker threads, run them
    /// concurrently, and merge deterministically by request id.
    pub fn generate(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<Vec<Completion>> {
        if requests.is_empty() {
            return Ok(Vec::new());
        }
        let n = self.workers.len();
        let mut shards: Vec<Vec<Request>> =
            (0..n).map(|_| Vec::new()).collect();
        for r in requests {
            let e = self.router.route(&r);
            shards[e].push(r);
        }
        let (tx, rx) = channel();
        // ids routed to each replica but not yet settled with the router
        let mut pending: Vec<Vec<u64>> = vec![Vec::new(); n];
        let mut delivered: Vec<u64> = vec![0; n];
        let mut dispatched = 0usize;
        let mut first_err: Option<Error> = None;
        for (e, shard) in shards.into_iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            pending[e] = shard.iter().map(|r| r.id).collect();
            if self.workers[e]
                .send(ToWorker::Generate(shard, tx.clone()))
                .is_err()
            {
                first_err.get_or_insert_with(|| {
                    anyhow!("replica {e} worker thread is gone")
                });
                continue; // its pending ids are aborted below
            }
            dispatched += 1;
        }
        drop(tx);
        let mut out: Vec<Completion> = Vec::new();
        for _ in 0..dispatched {
            match rx.recv() {
                Ok((replica, Ok(cs))) => {
                    delivered[replica] =
                        cs.iter().map(|c| c.tokens.len() as u64).sum();
                    out.extend(cs);
                }
                Ok((replica, Err(e))) => {
                    first_err.get_or_insert_with(|| {
                        e.wrap(format!("replica {replica} generate failed"))
                    });
                }
                Err(_) => {
                    first_err.get_or_insert_with(|| {
                        anyhow!("a pool worker exited mid-generate")
                    });
                    break;
                }
            }
        }
        // settle the router only once the batch OUTCOME is known, so
        // the completed/aborted diagnostics describe what the caller
        // actually received: all-or-nothing means a failed batch
        // counts every id as aborted — including ids a successful
        // replica generated but whose results we are about to drop.
        // Either way the charge drains fully: phantom load must never
        // leak into the next least-loaded pick.
        if let Some(e) = first_err {
            for ids in &pending {
                for id in ids {
                    self.router.abort(*id);
                }
            }
            // keep the delivered-tokens invariant honest on the
            // replicas whose work we are discarding
            for (replica, &tokens) in delivered.iter().enumerate() {
                if tokens > 0 {
                    let _ = self.workers[replica]
                        .send(ToWorker::Discard(tokens));
                }
            }
            return Err(e);
        }
        for ids in &pending {
            for id in ids {
                self.router.complete(*id);
            }
        }
        out.sort_by_key(|c| c.id);
        Ok(out)
    }

    /// Send one message (built per replica) to every worker, failing
    /// loudly if a worker thread has died.
    fn broadcast<F: Fn() -> ToWorker>(&self, mk: F) -> Result<()> {
        for (e, w) in self.workers.iter().enumerate() {
            w.send(mk()).map_err(|_| {
                anyhow!("replica {e} worker thread is gone")
            })?;
        }
        Ok(())
    }

    /// Install one quantized parameter set into every replica (the
    /// weight-sync broadcast: quantize once, upload per replica).
    pub fn install_weights(
        &mut self,
        weights: Arc<Vec<HostArray>>,
    ) -> Result<()> {
        let (tx, rx) = channel();
        self.broadcast(|| {
            ToWorker::InstallWeights(weights.clone(), tx.clone())
        })?;
        drop(tx);
        self.collect_acks(rx, "weight install")
    }

    /// Broadcast recalibrated KV scales to every replica. Channel FIFO
    /// order guarantees the next `generate` sees them.
    pub fn install_kv_scales(&mut self, k: f32, v: f32) -> Result<()> {
        self.broadcast(|| ToWorker::InstallKvScales(k, v))
    }

    /// Aggregate engine counters across all replicas.
    pub fn stats(&self) -> Result<EngineStats> {
        let mut total = EngineStats::default();
        for s in self.per_replica_stats()? {
            total.merge(&s);
        }
        Ok(total)
    }

    /// Per-replica engine counters, indexed by replica.
    pub fn per_replica_stats(&self) -> Result<Vec<EngineStats>> {
        let (tx, rx) = channel();
        self.broadcast(|| ToWorker::Stats(tx.clone()))?;
        drop(tx);
        let n = self.workers.len();
        let mut out = vec![EngineStats::default(); n];
        let mut got = 0usize;
        while let Ok((replica, s)) = rx.recv() {
            out[replica] = s;
            got += 1;
        }
        if got != n {
            bail!("only {got}/{n} replicas reported stats");
        }
        Ok(out)
    }

    fn collect_acks(
        &self,
        rx: Receiver<(usize, Result<()>)>,
        what: &str,
    ) -> Result<()> {
        let n = self.workers.len();
        let mut got = 0usize;
        while let Ok((replica, res)) = rx.recv() {
            res.with_context(|| format!("replica {replica} {what}"))?;
            got += 1;
        }
        if got != n {
            bail!("only {got}/{n} replicas acknowledged {what}");
        }
        Ok(())
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.send(ToWorker::Shutdown);
        }
        for h in self.handles.iter_mut() {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

/// The RL loop's rollout backend: a single in-process engine (the
/// default) or the thread-per-replica pool, behind one surface so the
/// coordinator is agnostic to the serving topology.
pub enum Rollout {
    Single(Box<HloEngine>),
    Pool(EnginePool),
}

impl Rollout {
    pub fn generate(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<Vec<Completion>> {
        match self {
            Rollout::Single(e) => e.generate(requests),
            Rollout::Pool(p) => p.generate(requests),
        }
    }

    /// Install synced weights; the pool broadcasts the shared list to
    /// every replica (quantized once upstream).
    pub fn install_weights(
        &mut self,
        weights: Arc<Vec<HostArray>>,
    ) -> Result<()> {
        match self {
            Rollout::Single(e) => e.install_weights(&weights),
            Rollout::Pool(p) => p.install_weights(weights),
        }
    }

    pub fn install_kv_scales(&mut self, k: f32, v: f32) -> Result<()> {
        match self {
            Rollout::Single(e) => {
                e.install_kv_scales(k, v);
                Ok(())
            }
            Rollout::Pool(p) => p.install_kv_scales(k, v),
        }
    }

    /// Aggregate engine counters (summed across replicas for a pool).
    pub fn stats(&self) -> Result<EngineStats> {
        match self {
            Rollout::Single(e) => Ok(e.stats.clone()),
            Rollout::Pool(p) => p.stats(),
        }
    }

    pub fn n_replicas(&self) -> usize {
        match self {
            Rollout::Single(_) => 1,
            Rollout::Pool(p) => p.n_replicas(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rollout::request::SamplingParams;

    fn reqs(lo: u64, hi: u64) -> Vec<Request> {
        (lo..hi)
            .map(|i| Request {
                id: i,
                prompt: vec![12, (i % 10) as i32, 10, 3, 11],
                params: SamplingParams {
                    temperature: 1.0,
                    max_new_tokens: 4,
                    ..Default::default()
                },
            })
            .collect()
    }

    fn pool(n: usize) -> EnginePool {
        EnginePool::new(
            PoolConfig {
                n_replicas: n,
                policy: RoutePolicy::RoundRobin,
                engine: EngineConfig::new("dense", "bf16"),
            },
            hermetic_runtime_factory(),
        )
        .unwrap()
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut p = pool(2);
        assert!(p.generate(Vec::new()).unwrap().is_empty());
        assert_eq!(p.loads(), &[0, 0]);
    }

    #[test]
    fn merge_is_sorted_by_id_and_loads_drain() {
        let mut p = pool(3);
        let done = p.generate(reqs(0, 9)).unwrap();
        assert_eq!(done.len(), 9);
        let ids: Vec<u64> = done.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
        assert_eq!(p.loads(), &[0, 0, 0], "router load must drain");
        let stats = p.stats().unwrap();
        let delivered: usize =
            done.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(stats.tokens_generated, delivered as u64);
    }

    #[test]
    fn failed_shard_fails_the_call_but_leaks_nothing() {
        let mut p = pool(2);
        let mut batch = reqs(0, 3);
        // prompt_len is 16 in the synthetic manifest: a 64-token prompt
        // can never be admitted, so its replica's generate fails
        batch.push(Request {
            id: 99,
            prompt: vec![1; 64],
            params: SamplingParams::default(),
        });
        assert!(p.generate(batch).is_err());
        assert_eq!(p.loads(), &[0, 0], "no phantom router load");
        // the delivered-tokens invariant survives the dropped results
        let stats = p.stats().unwrap();
        assert_eq!(stats.tokens_generated, 0);
        // the pool stays serviceable
        let done = p.generate(reqs(10, 14)).unwrap();
        assert_eq!(done.len(), 4);
        let delivered: usize =
            done.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(
            p.stats().unwrap().tokens_generated,
            delivered as u64
        );
    }

    #[test]
    fn bad_replica_count_is_rejected() {
        let r = EnginePool::new(
            PoolConfig {
                n_replicas: 0,
                policy: RoutePolicy::RoundRobin,
                engine: EngineConfig::new("dense", "bf16"),
            },
            hermetic_runtime_factory(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn startup_failure_names_the_replica() {
        let err = EnginePool::new(
            PoolConfig {
                n_replicas: 2,
                policy: RoutePolicy::RoundRobin,
                engine: EngineConfig::new("dense", "no_such_variant"),
            },
            hermetic_runtime_factory(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("failed to start"), "{err}");
    }
}
