//! Data-parallel rollout pool with **continuous streaming admission**:
//! one OS thread per engine replica behind the [`Router`], each running
//! a persistent scheduler loop that admits new requests *between decode
//! steps* — no batch barriers, the serving-shaped front end the
//! ROADMAP's streaming-admission item asks for.
//!
//! ## Threading model
//!
//! The RefBackend's device buffers are `Rc<RefCell<_>>` cells, so a
//! `Runtime` (and everything holding its buffers) is deliberately
//! **not** `Send`. The pool therefore never moves an engine between
//! threads: each worker thread calls the [`RuntimeFactory`] and builds
//! its own `Runtime` + [`HloEngine`] *inside* the thread, and all
//! coordination happens over `mpsc` channels carrying only `Send` data
//! (requests, completions, host arrays, stats). Engines are
//! thread-confined for their whole life.
//!
//! ## Streaming protocol
//!
//! The caller drives a session API: [`EnginePool::submit`] routes one
//! request (on LIVE per-replica queue depth — completions are pumped
//! off the event channel before every pick) and returns its
//! [`TicketId`]; [`EnginePool::poll`] / [`EnginePool::recv`] /
//! [`EnginePool::next_resolved`] deliver results ([`Completed`]) as
//! replicas finish them (`next_resolved` is the run-to-dry loop);
//! [`EnginePool::drain`] runs the pool dry and returns everything
//! id-sorted; [`EnginePool::abort`] cancels an in-flight ticket. Each
//! worker loop: pull every queued message (admitting requests into the
//! running engine mid-decode), run ONE engine step, ship finished
//! completions, repeat; it blocks only when idle. Aborts jump pending
//! fences both ways: cancelling the straggler a fence is draining
//! lets the fence apply immediately, and cancelling a submission
//! still parked BEHIND a fence resolves it `Aborted` without it ever
//! decoding.
//!
//! ## Epoch fences
//!
//! Weight syncs and KV-scale installs are **epoch-fenced control
//! messages** ([`EnginePool::sync_weights`] /
//! [`EnginePool::sync_kv_scales`]): the fence rides the per-replica
//! FIFO channel behind every already-submitted request, and a worker
//! applies it only once its engine is idle — in-flight sequences
//! finish under the OLD weights, requests submitted after the fence
//! run entirely under the NEW ones, and no completion ever spans an
//! install (no torn-weights generation). Every completion is tagged
//! with the weight epoch it ran under (`Completion::epoch`), which is
//! deterministic: the pool stamps submissions with its epoch counter,
//! and channel FIFO order makes the stamp equal the engine's epoch at
//! admission (checked — a replica left behind by a failed install
//! fails subsequent submissions loudly instead of mis-tagging them).
//! The trainer uses the tag to match behavior-policy logprobs (pi_fp8,
//! the TIS/MIS denominator) to the right policy version.
//!
//! ## Determinism
//!
//! N-replica streaming output is bit-identical to sequential
//! single-engine execution with the same seed, for any routing policy,
//! replica count, and admission interleaving:
//!
//! * every request samples from its own RNG stream derived purely from
//!   (engine seed, request id) — see `sampler::request_seed`;
//! * the RefBackend computes each batch row independently and chunked
//!   prefill reproduces the wave bit-exactly, so logits do not depend
//!   on batch neighbors or on WHEN a request was admitted;
//! * weights are piecewise-constant in epochs and the fence pins every
//!   request to the epoch it was submitted under;
//! * `drain` merges by sorting on request id.
//!
//! `rust/tests/prop_stream.rs` replays 256+ seeded interleavings
//! (submit / poll / weight-sync / abort, via `testkit::interleave`)
//! against the sequential reference to prove it.
//!
//! ## Failure semantics
//!
//! Failures are per-ticket in streaming mode: a rejected admission or
//! a failed engine step resolves the affected tickets as
//! [`Completed::Failed`] (the step's other, already-finished
//! completions are real and still delivered); the router settles every
//! charge either way, so loads always drain to zero. A replica that
//! fails a fence or whose thread dies is QUARANTINED from placement
//! (its instantly-failing admissions would otherwise keep its load
//! near zero and make `LeastLoaded` funnel traffic into it); a dead
//! replica's owed fence acks are written off by a reaper so blocking
//! waits terminate, and its unresolved tickets are RE-ROUTED to a
//! surviving replica at the current pool epoch (failing only the ones
//! nobody can take). The barrier-era
//! [`EnginePool::generate`] survives as submit-all + drain with
//! all-or-nothing semantics: any failed ticket fails the call, drops
//! the delivered results, and tells their replicas to count the
//! dropped tokens as discarded (preserving the "tokens_generated
//! counts only delivered tokens" invariant).
//!
//! ## Protocol conformance (hb tracing)
//!
//! Every channel send/recv, fence park/apply/ack, admission,
//! quarantine write-off, and ticket resolution runs through an
//! [`HbHandle`] hook (`testkit::hb`) — a literal no-op unless a test
//! attaches a recorder via [`EnginePool::new_traced`], in which case
//! the whole session is logged with vector-clock stamps and
//! [`EnginePool::hb_verify`] replays it through the fence-protocol
//! conformance checker. Worker-bound sends go through [`WorkerLink`]
//! (`send_ordered` / `send_ctl`), the only place a raw channel send
//! of a `ToWorker::` value may appear — lint rule C2 flags any other,
//! so no future code path can bypass the fence FIFO ordering.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::mpsc::{
    channel, Receiver, RecvTimeoutError, SendError, Sender, TryRecvError,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::runtime::{HostArray, Runtime};
use crate::testkit::hb::{EvLabel, HbHandle, HbReport, MsgLabel, ResolveKind};
use crate::util::error::{anyhow, bail, Error, Result};

use super::engine::{EngineConfig, EngineStats, HloEngine};
use super::request::{Completion, Request};
use super::router::{RoutePolicy, Router};

/// Builds one thread-confined `Runtime` per replica, called inside the
/// worker thread (runtimes are not `Send` — see the module docs).
pub type RuntimeFactory = Arc<dyn Fn() -> Result<Runtime> + Send + Sync>;

/// The default factory: real artifacts when `<dir>/manifest.json`
/// exists, else the hermetic synthetic runtime — `Runtime::new_quiet`,
/// so N replicas don't log N missing-manifest warnings.
pub fn runtime_factory(artifacts_dir: impl Into<String>) -> RuntimeFactory {
    let dir: String = artifacts_dir.into();
    Arc::new(move || Runtime::new_quiet(dir.clone()))
}

/// A factory that always builds the hermetic synthetic runtime,
/// independent of what exists on disk (what the test suites use).
pub fn hermetic_runtime_factory() -> RuntimeFactory {
    Arc::new(|| Ok(Runtime::hermetic()))
}

/// A factory mirroring an existing runtime's manifest source: replicas
/// load the same artifacts directory the caller's runtime did, or get
/// the hermetic synthetic runtime when that manifest was built
/// in-process (`Manifest::is_synthetic`). This is what keeps pool
/// replicas and the
/// trainer serving the SAME model — never derive the replica source
/// from a second, separately-configured path. If the on-disk manifest
/// has vanished since the parent runtime loaded it, replica
/// construction FAILS instead of silently falling back to the
/// synthetic toy model (which would be exactly the
/// train-one-model/sample-another divergence this factory prevents).
pub fn factory_like(rt: &Runtime) -> RuntimeFactory {
    let dir = rt.manifest.dir.clone();
    if rt.manifest.is_synthetic() {
        hermetic_runtime_factory()
    } else {
        Arc::new(move || {
            if !dir.join("manifest.json").exists() {
                bail!(
                    "replica runtime source {dir:?} has no \
                     manifest.json (it existed when the parent \
                     runtime loaded) — refusing the synthetic fallback"
                );
            }
            Runtime::new_quiet(dir.clone())
        })
    }
}

#[derive(Clone, Debug)]
pub struct PoolConfig {
    pub n_replicas: usize,
    pub policy: RoutePolicy,
    /// per-replica engine configuration (every replica gets the same
    /// seed — request streams are keyed by request id, not replica)
    pub engine: EngineConfig,
}

/// Handle for one streamed request (== its request id).
pub type TicketId = u64;

/// One resolved ticket from the streaming pool: every submitted
/// request resolves exactly once as one of these.
#[derive(Debug)]
pub enum Completed {
    /// A finished, epoch-tagged completion.
    Done(Completion),
    /// The ticket was cancelled by [`EnginePool::abort`] before it
    /// finished (a ticket whose abort lost the race resolves as
    /// `Done` instead).
    Aborted(TicketId),
    /// The replica failed this ticket: an admission rejection, or an
    /// engine-step error that dropped it mid-flight.
    Failed(TicketId, String),
}

/// Order-INSENSITIVE worker control: handled at ingest even while a
/// fence is parked (an abort must be able to cancel the straggler a
/// fence is draining; stats must not stall behind it).
enum Ctl {
    /// Cancel a streamed request if it has not completed yet.
    Abort(u64),
    /// Count `n` delivered-then-dropped tokens as discarded (the
    /// barrier `generate`'s all-or-nothing failure path).
    Discard(u64),
    Stats(Sender<(usize, EngineStats)>),
    Shutdown,
}

/// The worker wire protocol: every message is either epoch-ORDERED
/// (its channel position defines which weights a request runs under)
/// or plain control. Constructed ONLY inside [`WorkerLink`] — lint
/// rule C2 flags any raw `send` of a `ToWorker::` value, so a future
/// code path cannot bypass the fence FIFO by smuggling an ordered
/// message around the wrapper.
enum ToWorker {
    Ordered(Ordered),
    Ctl(Ctl),
}

/// Worker -> pool notifications, merged over one shared channel.
enum Event {
    Done(usize, Completion),
    Aborted(usize, u64),
    Failed(usize, u64, String),
    /// Fence acknowledgement: (replica, target epoch, install result).
    Fence(usize, u64, Result<()>),
}

/// A pending epoch fence, parked worker-side until the engine drains.
/// Worker-side this is the `Draining(target)` state of the fence
/// state machine (`Running → Draining → Installed`; see
/// `testkit::hb::FenceState`, which the conformance checker validates
/// event-by-event against the recorded park/apply/ack trace).
enum Fence {
    Weights(Arc<Vec<HostArray>>, u64),
    KvScales(f32, f32, u64),
}

impl Fence {
    fn target(&self) -> u64 {
        match self {
            Fence::Weights(_, t) | Fence::KvScales(_, _, t) => *t,
        }
    }
}

/// The epoch-ORDERED subset of worker messages: the ones whose
/// relative order defines which weights a request runs under.
/// Order-insensitive control (abort/stats/discard/shutdown) never
/// takes this form.
enum Ordered {
    Submit(Request, u64),
    Fence(Fence),
}

/// The pool's handle to one worker channel — the ONLY place a raw
/// channel send of a `ToWorker::` value may appear (each carries an
/// audited C2 allow). Everything else goes through `send_ordered` /
/// `send_ctl`, so no code path can bypass the fence FIFO ordering.
struct WorkerLink {
    tx: Sender<ToWorker>,
}

impl WorkerLink {
    /// Send an epoch-ORDERED message (submission or fence).
    fn send_ordered(
        &self,
        m: Ordered,
    ) -> std::result::Result<(), SendError<ToWorker>> {
        // lint: allow(C2): WorkerLink IS the audited Ordered wrapper
        self.tx.send(ToWorker::Ordered(m))
    }

    /// Send order-insensitive control.
    fn send_ctl(
        &self,
        m: Ctl,
    ) -> std::result::Result<(), SendError<ToWorker>> {
        // lint: allow(C2): WorkerLink IS the audited Ordered wrapper
        self.tx.send(ToWorker::Ctl(m))
    }
}

/// hb label for a control message (what the pool claims it sent).
fn ctl_label(c: &Ctl) -> MsgLabel {
    match c {
        Ctl::Abort(id) => MsgLabel::Abort { ticket: *id },
        Ctl::Discard(_) => MsgLabel::Discard,
        Ctl::Stats(_) => MsgLabel::Stats,
        Ctl::Shutdown => MsgLabel::Shutdown,
    }
}

/// hb label for any worker-bound message (what the worker actually
/// received — the recorder cross-checks the two, so the channel FIFO
/// itself is under test).
fn msg_label(m: &ToWorker) -> MsgLabel {
    match m {
        ToWorker::Ordered(Ordered::Submit(r, stamp)) => {
            MsgLabel::Submit { ticket: r.id, stamp: *stamp }
        }
        ToWorker::Ordered(Ordered::Fence(f)) => {
            MsgLabel::Fence { target: f.target() }
        }
        ToWorker::Ctl(c) => ctl_label(c),
    }
}

/// hb metadata for a worker event (replica + label).
fn ev_meta(ev: &Event) -> (usize, EvLabel) {
    match ev {
        Event::Done(r, c) => {
            (*r, EvLabel::Done { ticket: c.id, epoch: c.epoch })
        }
        Event::Aborted(r, id) => (*r, EvLabel::Aborted { ticket: *id }),
        Event::Failed(r, id, _) => (*r, EvLabel::Failed { ticket: *id }),
        Event::Fence(r, t, res) => {
            (*r, EvLabel::FenceAck { target: *t, ok: res.is_ok() })
        }
    }
}

/// The pool hung up its event receiver (dropped mid-session): the
/// worker has nobody to report to and must exit its serve loop.
struct PoolGone;

fn emit(
    hb: &HbHandle,
    events: &Sender<Event>,
    ev: Event,
) -> Result<(), PoolGone> {
    let (replica, label) = ev_meta(&ev);
    hb.event_send(replica, label);
    match events.send(ev) {
        Ok(()) => Ok(()),
        Err(_) => {
            hb.event_send_failed(replica);
            Err(PoolGone)
        }
    }
}

struct FenceAck {
    replica: usize,
    epoch: u64,
    result: Result<()>,
}

struct ReadyItem {
    replica: usize,
    item: Completed,
}

impl ReadyItem {
    fn ticket(&self) -> u64 {
        match &self.item {
            Completed::Done(c) => c.id,
            Completed::Aborted(id) | Completed::Failed(id, _) => *id,
        }
    }
}

/// Apply a deferred epoch fence on an idle engine and acknowledge it.
/// A successful install must land exactly on the target epoch (the
/// engine bumps once per install); drift means the fence protocol was
/// violated and is reported as an error rather than papered over.
fn apply_fence(
    replica: usize,
    engine: &mut HloEngine,
    fence: Fence,
    events: &Sender<Event>,
    hb: &HbHandle,
) -> Result<(), PoolGone> {
    let (target, mut res) = match fence {
        Fence::Weights(w, target) => {
            (target, engine.install_weights(&w))
        }
        Fence::KvScales(k, v, target) => {
            engine.install_kv_scales(k, v);
            (target, Ok(()))
        }
    };
    if res.is_ok() && engine.weight_epoch() != target {
        res = Err(anyhow!(
            "weight-epoch drift: engine at {} after a fence to {target}",
            engine.weight_epoch()
        ));
    }
    hb.fence_apply(replica, target, res.is_ok(), engine.weight_epoch());
    emit(hb, events, Event::Fence(replica, target, res))
}

/// Process one epoch-ORDERED message (a submission or a fence). These
/// are the messages whose relative order defines which weights a
/// request runs under; order-insensitive control never comes here.
fn handle_ordered(
    replica: usize,
    engine: &mut HloEngine,
    msg: Ordered,
    fence: &mut Option<Fence>,
    events: &Sender<Event>,
    hb: &HbHandle,
) -> Result<(), PoolGone> {
    match msg {
        Ordered::Submit(req, epoch) => {
            let id = req.id;
            if epoch != engine.weight_epoch() {
                emit(
                    hb,
                    events,
                    Event::Failed(
                        replica,
                        id,
                        format!(
                            "stamped for weight epoch {epoch} but the \
                             engine is at {} (a failed install left \
                             this replica behind the fence)",
                            engine.weight_epoch()
                        ),
                    ),
                )?;
            } else {
                match engine.enqueue(req) {
                    Ok(_) => hb.admit(replica, id, epoch),
                    Err(e) => emit(
                        hb,
                        events,
                        Event::Failed(replica, id, e.to_string()),
                    )?,
                }
            }
        }
        Ordered::Fence(f) => {
            hb.fence_park(replica, f.target());
            *fence = Some(f);
        }
    }
    Ok(())
}

fn worker_main(
    replica: usize,
    cfg: EngineConfig,
    factory: RuntimeFactory,
    rx: Receiver<ToWorker>,
    events: Sender<Event>,
    init: Sender<(usize, Result<()>)>,
    hb: HbHandle,
) {
    let built =
        factory().and_then(|rt| HloEngine::new(Arc::new(rt), cfg));
    let mut engine = match built {
        Ok(e) => {
            if init.send((replica, Ok(()))).is_err() {
                return; // the pool constructor already bailed
            }
            e
        }
        Err(e) => {
            // this worker is exiting either way; a constructor that
            // already bailed just misses the failure report
            // lint: allow(C1): init ack on the worker-exit path
            let _ = init.send((replica, Err(e)));
            return;
        }
    };
    drop(init);
    let mut done: Vec<Completion> = Vec::new();
    // a fence waiting for the engine to drain. While it is pending,
    // epoch-ordered messages (submits, further fences) are parked in
    // `backlog` in arrival order — they belong to the post-fence
    // epochs — but order-insensitive control (abort/stats/discard/
    // shutdown) is still handled immediately: an abort must be able
    // to cancel the very straggler a fence is waiting on, and stats
    // must not stall behind an in-flight drain.
    let mut fence: Option<Fence> = None;
    let mut backlog: VecDeque<Ordered> = VecDeque::new();
    'serve: loop {
        // ---- ingest the channel ----
        loop {
            let blocked_on_new_work = engine.is_idle()
                && fence.is_none()
                && backlog.is_empty();
            let msg = if blocked_on_new_work {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => break 'serve,
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break 'serve,
                }
            };
            hb.worker_recv(replica, msg_label(&msg));
            let ordered = match msg {
                ToWorker::Ctl(Ctl::Abort(id)) => {
                    // jumps any pending fence: cancelling propagates
                    // straight into the scheduler, so aborting the
                    // very straggler a fence is blocked on frees the
                    // engine and lets the fence apply immediately
                    // instead of waiting out max_new_tokens. A target
                    // still PARKED in the backlog (submitted behind a
                    // pending fence) never reached the engine — pull
                    // it out of the backlog and resolve it Aborted
                    // right away rather than letting a cancelled
                    // request decode its full budget under the
                    // post-fence epoch. Unknown ids: the completion
                    // already crossed (or is about to cross) the
                    // event channel — exactly-once either way.
                    if engine.cancel(id) {
                        if emit(&hb, &events, Event::Aborted(replica, id))
                            .is_err()
                        {
                            break 'serve;
                        }
                    } else if let Some(pos) =
                        backlog.iter().position(|m| {
                            matches!(m, Ordered::Submit(r, _)
                                if r.id == id)
                        })
                    {
                        let _ = backlog.remove(pos);
                        if emit(&hb, &events, Event::Aborted(replica, id))
                            .is_err()
                        {
                            break 'serve;
                        }
                    }
                    continue;
                }
                ToWorker::Ctl(Ctl::Discard(n)) => {
                    engine.stats.discard_tokens(n);
                    continue;
                }
                ToWorker::Ctl(Ctl::Stats(reply)) => {
                    // a requester that timed out and dropped its
                    // receiver just misses the snapshot
                    // lint: allow(C1): reply to a gone requester
                    let _ = reply.send((replica, engine.stats.clone()));
                    continue;
                }
                ToWorker::Ctl(Ctl::Shutdown) => break 'serve,
                ToWorker::Ordered(m) => m,
            };
            if fence.is_some() {
                backlog.push_back(ordered);
            } else if handle_ordered(
                replica,
                &mut engine,
                ordered,
                &mut fence,
                &events,
                &hb,
            )
            .is_err()
            {
                break 'serve;
            }
        }
        // ---- apply a due fence, then replay the parked backlog ----
        if engine.is_idle() {
            if let Some(f) = fence.take() {
                if apply_fence(replica, &mut engine, f, &events, &hb)
                    .is_err()
                {
                    break 'serve;
                }
            }
            while fence.is_none() {
                let Some(m) = backlog.pop_front() else { break };
                if handle_ordered(
                    replica,
                    &mut engine,
                    m,
                    &mut fence,
                    &events,
                    &hb,
                )
                .is_err()
                {
                    break 'serve;
                }
            }
            continue;
        }
        // ---- one admission + decode round; completions stream out
        // as they finish instead of waiting for a batch to drain ----
        match engine.step(&mut done) {
            Ok(()) => {
                for c in done.drain(..) {
                    if emit(&hb, &events, Event::Done(replica, c))
                        .is_err()
                    {
                        break 'serve;
                    }
                }
            }
            Err(e) => {
                // completions that finished before the error are real
                // and already counted as delivered — ship them
                for c in done.drain(..) {
                    if emit(&hb, &events, Event::Done(replica, c))
                        .is_err()
                    {
                        break 'serve;
                    }
                }
                let failed = engine.outstanding_ids();
                engine.abort_in_flight();
                let msg = e.to_string();
                for id in failed {
                    if emit(
                        &hb,
                        &events,
                        Event::Failed(replica, id, msg.clone()),
                    )
                    .is_err()
                    {
                        break 'serve;
                    }
                }
            }
        }
    }
}

pub struct EnginePool {
    cfg: PoolConfig,
    router: Router,
    workers: Vec<WorkerLink>,
    handles: Vec<Option<JoinHandle<()>>>,
    events: Receiver<Event>,
    /// results pumped off the event channel, awaiting the caller
    ready: VecDeque<ReadyItem>,
    /// tickets of the `ready` items (submit's O(log n) duplicate-id
    /// guard — the whole queue is never scanned on the hot path)
    ready_ids: BTreeSet<u64>,
    /// ticket -> (replica, request) for unresolved streamed requests:
    /// the abort / discard targeting map (the router holds the load
    /// charges). The request itself is retained so the reaper can
    /// RE-ROUTE a dead replica's unstarted tickets to a survivor
    /// instead of failing them outright.
    outstanding: BTreeMap<u64, (usize, Request)>,
    /// pool weight epoch: bumped by every sync fence; submissions are
    /// stamped with it
    epoch: u64,
    /// fence acknowledgements each replica still owes (incremented
    /// per fence sent, decremented per ack) — `drain` waits for this
    /// debt too, so an un-awaited fence cannot fail silently; a dead
    /// replica's debt is written off by the reaper as a fence failure
    fence_acks_owed: Vec<usize>,
    /// replicas the reaper has already written off (the reaper runs
    /// on every timeout tick; a corpse must be settled exactly once —
    /// double write-offs would double-count quarantine events)
    reaped: Vec<bool>,
    /// first failure reported by an un-awaited (streaming) fence;
    /// surfaced by the next `drain` / fence wait
    fence_failure: Option<Error>,
    /// happens-before recorder handle (inert unless a test attached a
    /// recorder via [`EnginePool::new_traced`])
    hb: HbHandle,
}

impl EnginePool {
    pub fn new(cfg: PoolConfig, factory: RuntimeFactory) -> Result<Self> {
        Self::new_traced(cfg, factory, HbHandle::default())
    }

    /// Build a pool with a happens-before recorder attached: every
    /// channel send/recv, fence park/apply/ack, admission, quarantine
    /// write-off, and ticket resolution is logged with a vector-clock
    /// stamp, and `recorder.check()` (or [`EnginePool::hb_verify`])
    /// replays the log through the fence-protocol conformance checker.
    /// With the default inert handle this is exactly [`EnginePool::new`].
    pub fn new_traced(
        cfg: PoolConfig,
        factory: RuntimeFactory,
        hb: HbHandle,
    ) -> Result<Self> {
        if cfg.n_replicas == 0 {
            bail!("engine pool needs at least one replica");
        }
        if let Some(n) = hb.traced_replicas() {
            if n != cfg.n_replicas {
                bail!(
                    "hb recorder sized for {n} replicas attached to a \
                     pool of {}",
                    cfg.n_replicas
                );
            }
        }
        let mut workers = Vec::with_capacity(cfg.n_replicas);
        let mut handles = Vec::with_capacity(cfg.n_replicas);
        let (init_tx, init_rx) = channel();
        let (event_tx, event_rx) = channel();
        for replica in 0..cfg.n_replicas {
            let (tx, rx) = channel::<ToWorker>();
            let f = factory.clone();
            let ecfg = cfg.engine.clone();
            let itx = init_tx.clone();
            let etx = event_tx.clone();
            let hbw = hb.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("engine-pool-{replica}"))
                .spawn(move || {
                    worker_main(replica, ecfg, f, rx, etx, itx, hbw)
                });
            let handle = match spawned {
                Ok(h) => h,
                Err(e) => {
                    // same cleanup as the init-failure path below:
                    // closing the channels unblocks the workers we
                    // already spawned, and joining bounds their life
                    drop(workers);
                    drop(init_tx);
                    for h in handles.iter_mut() {
                        if let Some(h) = h.take() {
                            let _ = h.join();
                        }
                    }
                    return Err(Error::from(e).wrap(format!(
                        "spawning pool worker {replica}"
                    )));
                }
            };
            workers.push(WorkerLink { tx });
            handles.push(Some(handle));
        }
        drop(init_tx);
        let mut failed: Option<Error> = None;
        for _ in 0..cfg.n_replicas {
            match init_rx.recv() {
                Ok((_, Ok(()))) => {}
                Ok((replica, Err(e))) => {
                    failed.get_or_insert(
                        e.wrap(format!("replica {replica} failed to start")),
                    );
                }
                Err(_) => {
                    failed.get_or_insert_with(|| {
                        anyhow!("a pool worker died during startup")
                    });
                    break;
                }
            }
        }
        if let Some(e) = failed {
            // closing the channels unblocks surviving workers' recv
            drop(workers);
            for h in handles.iter_mut() {
                if let Some(h) = h.take() {
                    let _ = h.join();
                }
            }
            return Err(e);
        }
        let router = Router::new(cfg.policy, cfg.n_replicas);
        let n = cfg.n_replicas;
        Ok(EnginePool {
            cfg,
            router,
            workers,
            handles,
            events: event_rx,
            ready: VecDeque::new(),
            ready_ids: BTreeSet::new(),
            outstanding: BTreeMap::new(),
            epoch: 0,
            fence_acks_owed: vec![0; n],
            reaped: vec![false; n],
            fence_failure: None,
            hb,
        })
    }

    /// Replay the attached happens-before log through the conformance
    /// checker (see `testkit::hb`). `Ok(None)` when the pool is
    /// untraced. Meaningful once the session is quiescent — every
    /// submitted ticket resolved and every fence acked or written off.
    pub fn hb_verify(&self) -> Result<Option<HbReport>> {
        self.hb.verify()
    }

    pub fn n_replicas(&self) -> usize {
        self.workers.len()
    }

    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    /// Outstanding router load per replica (drains to zero once every
    /// request has completed or been aborted). Pump first if you need
    /// it live mid-stream — `submit` does.
    pub fn loads(&self) -> &[u64] {
        self.router.loads()
    }

    /// Streamed tickets not yet resolved (results already pumped into
    /// the ready queue count as resolved).
    pub fn n_outstanding(&self) -> usize {
        self.outstanding.len()
    }

    /// The pool's current weight epoch (== every replica's, once its
    /// fences drain).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    // ---- event plumbing ----

    /// Queue a resolved ticket for the caller (tracking its id for
    /// the duplicate-submit guard). This is THE resolution point —
    /// exactly-once delivery to the caller — so the hb resolve hook
    /// lives here.
    fn push_ready(&mut self, item: ReadyItem) {
        let kind = match &item.item {
            Completed::Done(c) => ResolveKind::Done { epoch: c.epoch },
            Completed::Aborted(_) => ResolveKind::Aborted,
            Completed::Failed(_, _) => ResolveKind::Failed,
        };
        self.hb.resolve(item.ticket(), kind);
        // exactly-once: a ticket already sitting in the ready queue
        // being resolved AGAIN means the outstanding-gating upstream
        // (handle_event / the reaper) let a duplicate through
        let fresh = self.ready_ids.insert(item.ticket());
        assert!(fresh, "ticket {} resolved twice", item.ticket());
        self.ready.push_back(item);
    }

    /// Hand the next resolved ticket to the caller.
    fn pop_ready(&mut self) -> Option<ReadyItem> {
        let item = self.ready.pop_front()?;
        self.ready_ids.remove(&item.ticket());
        Some(item)
    }

    /// Settle one worker event against the router / outstanding map;
    /// fence acks are returned to the caller instead of queued.
    /// Resolution events are gated on the ticket still being
    /// outstanding: a worker that sends its last event and THEN
    /// panics can race the reaper (which already settled the ticket
    /// as failed), and tickets must resolve exactly once.
    fn handle_event(&mut self, ev: Event) -> Option<FenceAck> {
        {
            let (replica, label) = ev_meta(&ev);
            self.hb.event_recv(replica, label);
        }
        match ev {
            Event::Done(replica, c) => {
                if self.outstanding.remove(&c.id).is_none() {
                    return None; // already resolved (reap race)
                }
                self.router.complete(c.id);
                self.push_ready(ReadyItem {
                    replica,
                    item: Completed::Done(c),
                });
                None
            }
            Event::Aborted(replica, id) => {
                if self.outstanding.remove(&id).is_none() {
                    return None; // already resolved (reap race)
                }
                self.router.abort(id);
                self.push_ready(ReadyItem {
                    replica,
                    item: Completed::Aborted(id),
                });
                None
            }
            Event::Failed(replica, id, msg) => {
                if self.outstanding.remove(&id).is_none() {
                    return None; // already resolved (reap race)
                }
                self.router.abort(id);
                self.push_ready(ReadyItem {
                    replica,
                    item: Completed::Failed(
                        id,
                        format!("replica {replica}: {msg}"),
                    ),
                });
                None
            }
            Event::Fence(replica, epoch, result) => {
                if let Some(owed) =
                    self.fence_acks_owed.get_mut(replica)
                {
                    *owed = owed.saturating_sub(1);
                }
                Some(FenceAck { replica, epoch, result })
            }
        }
    }

    fn note_fence(&mut self, ack: FenceAck) {
        if let Err(e) = ack.result {
            // the replica is stranded on old weights: new submissions
            // to it would fail the epoch check instantly, and those
            // instant failures would keep its router load near zero —
            // under LeastLoaded it would become a traffic black hole.
            // Quarantine it from placement (it still settles what it
            // owes); there is no un-quarantine: later fences land it
            // one epoch short again by construction.
            self.router.set_quarantined(ack.replica, true);
            self.fence_failure.get_or_insert(e.wrap(format!(
                "replica {} failed the epoch-{} fence",
                ack.replica, ack.epoch
            )));
        }
    }

    /// Non-blocking: settle everything already on the event channel,
    /// so routing decisions and `loads()` reads are live.
    fn pump(&mut self) {
        while let Ok(ev) = self.events.try_recv() {
            if let Some(ack) = self.handle_event(ev) {
                self.note_fence(ack);
            }
        }
    }

    /// A worker thread only exits during pool teardown, so a finished
    /// handle mid-session means the thread PANICKED. Its outstanding
    /// tickets would otherwise never resolve (the shared event channel
    /// stays open while any sibling lives), hanging every blocking
    /// wait — resolve them as failed instead. Returns true if anything
    /// was reaped. Callers pump first, so resolutions the thread DID
    /// send before dying are honored.
    fn reap_dead_workers(&mut self) -> bool {
        let dead: Vec<usize> = (0..self.handles.len())
            .filter(|&r| {
                !self.reaped.get(r).copied().unwrap_or(true)
                    && self
                        .handles
                        .get(r)
                        .and_then(|h| h.as_ref())
                        .map_or(true, |h| h.is_finished())
            })
            .collect();
        if dead.is_empty() {
            return false;
        }
        // a dead thread's sends happen-before its exit: pump once so
        // every resolution the corpse DID report is honored before we
        // write anything off
        self.pump();
        let mut reaped = false;
        for r in dead {
            if let Some(flag) = self.reaped.get_mut(r) {
                *flag = true;
            }
            // a dead replica must stop attracting placements
            self.router.set_quarantined(r, true);
            // write off its fence debt (it can never ack) so drains
            // don't wait forever, and record the broken fence
            let owed = self
                .fence_acks_owed
                .get_mut(r)
                .map(std::mem::take)
                .unwrap_or(0);
            self.hb.quarantine(r, owed);
            if owed > 0 {
                self.fence_failure.get_or_insert(anyhow!(
                    "replica {r} worker thread died before \
                     acknowledging a fence"
                ));
                reaped = true;
            }
            // its unresolved tickets never started (or died mid-step
            // with no event sent): re-route each to a surviving
            // replica at the CURRENT pool epoch, failing only the
            // ones nobody can take
            let orphans: Vec<u64> = self
                .outstanding
                .iter()
                .filter(|&(_, &(rep, _))| rep == r)
                .map(|(&id, _)| id)
                .collect();
            for id in orphans {
                let Some((_, req)) = self.outstanding.remove(&id)
                else {
                    continue;
                };
                self.router.abort(id);
                if !self.place(req) {
                    self.push_ready(ReadyItem {
                        replica: r,
                        item: Completed::Failed(
                            id,
                            format!(
                                "replica {r} worker thread died and \
                                 no live replica could take over"
                            ),
                        ),
                    });
                }
                reaped = true;
            }
        }
        reaped
    }

    /// Test hook: shut one worker down and JOIN it, so the next reap
    /// deterministically observes the death (the failure-path suites
    /// need a corpse without racing `is_finished`). The shutdown rides
    /// the ctl path like any other, so hb traces stay conformant.
    #[doc(hidden)]
    pub fn kill_worker_for_test(&mut self, replica: usize) {
        if let Some(w) = self.workers.get(replica) {
            self.hb.ctl_send(replica, MsgLabel::Shutdown);
            if w.send_ctl(Ctl::Shutdown).is_err() {
                self.hb.send_failed(replica);
            }
        }
        if let Some(h) =
            self.handles.get_mut(replica).and_then(|h| h.take())
        {
            // a panicked worker is exactly what this simulates
            let _ = h.join();
        }
    }

    // ---- streaming session API ----

    /// Admit one request into the running pool: picks the replica with
    /// the lowest LIVE queue depth (completions already reported are
    /// settled first), stamps the request with the current weight
    /// epoch, and returns its ticket (== the request id). The request
    /// starts decoding mid-flight on the replica's next step — no
    /// batch boundary involved.
    /// Route + send one request, retrying past dead replicas: a send
    /// failure means the routed replica's thread is dead, so it is
    /// quarantined and the request re-routed — the pool keeps limping
    /// on its healthy replicas instead of failing every placement at
    /// the first corpse (bounded: each retry disqualifies one replica
    /// from placement). On success the ticket is tracked in
    /// `outstanding` with the request retained for reaper failover.
    /// `false` means no live replica accepted it; the router charge is
    /// settled either way.
    fn place(&mut self, mut req: Request) -> bool {
        let id = req.id;
        for _ in 0..self.workers.len() {
            let replica = self.router.route(&req);
            let Some(w) = self.workers.get(replica) else {
                self.router.abort(id);
                return false;
            };
            let retained = req.clone();
            self.hb.submit_send(replica, id, self.epoch);
            match w.send_ordered(Ordered::Submit(req, self.epoch)) {
                Ok(()) => {
                    self.outstanding.insert(id, (replica, retained));
                    return true;
                }
                Err(e) => {
                    self.hb.send_failed(replica);
                    // the request rides the SendError back out, so the
                    // common path moves it — the clone above is the
                    // failover-retention copy, not a retry copy
                    let ToWorker::Ordered(Ordered::Submit(r, _)) = e.0
                    else {
                        self.router.abort(id);
                        return false;
                    };
                    req = r;
                    self.router.abort(id);
                    self.router.set_quarantined(replica, true);
                }
            }
        }
        false
    }

    pub fn submit(&mut self, req: Request) -> Result<TicketId> {
        self.pump();
        // a duplicate of an unresolved ticket would corrupt the
        // id-keyed merge — and "unresolved" includes results already
        // pumped into the ready queue but not yet consumed
        if self.outstanding.contains_key(&req.id)
            || self.ready_ids.contains(&req.id)
        {
            bail!(
                "request id {} is already in flight or awaiting \
                 consumption — streamed ids must be unique",
                req.id
            );
        }
        let id = req.id;
        if self.place(req) {
            return Ok(id);
        }
        // settle the corpses' tickets before reporting total loss
        self.reap_dead_workers();
        bail!("no live replica accepted request {id}");
    }

    /// Block (bounded) for ONE worker event: ticket resolutions are
    /// settled into the ready queue, a fence ack is handed back to
    /// the caller. `Ok(None)` is an inconclusive timeout tick — a
    /// panicked worker is reaped there so its tickets resolve as
    /// `Failed` instead of hanging the wait. `Err` means every worker
    /// is gone (all remaining tickets settled as aborted first).
    fn wait_event(&mut self) -> Result<Option<FenceAck>> {
        match self.events.recv_timeout(Duration::from_millis(50)) {
            Ok(ev) => Ok(self.handle_event(ev)),
            Err(RecvTimeoutError::Timeout) => {
                self.reap_dead_workers();
                Ok(None)
            }
            Err(RecvTimeoutError::Disconnected) => {
                let n = self.settle_all_as_aborted();
                bail!(
                    "every pool worker exited with {n} tickets \
                     outstanding"
                );
            }
        }
    }

    /// Non-blocking: the next resolved ticket, if any replica has
    /// finished one.
    pub fn poll(&mut self) -> Option<Completed> {
        self.pump();
        self.pop_ready().map(|r| r.item)
    }

    /// Blocking iterator-style receive: the next resolved ticket, or
    /// `None` once the stream is dry (nothing outstanding AND nothing
    /// waiting in the ready queue). This is the run-to-dry loop —
    /// `while let Some(c) = pool.next_resolved()? { ... }` — without
    /// the footgun of polling `n_outstanding` yourself: a blocking
    /// receive's internal pump can resolve the LAST tickets into the
    /// ready queue before the caller re-checks the count, and a
    /// count-guarded loop then exits with results unconsumed. Also
    /// surfaces streaming fence failures (a degraded pool must not
    /// look like a successful session to poll/recv-style consumers).
    pub fn next_resolved(&mut self) -> Result<Option<Completed>> {
        loop {
            self.pump();
            if let Some(e) = self.fence_failure.take() {
                return Err(e.wrap(
                    "a weight-sync fence failed (pool degraded)",
                ));
            }
            if let Some(r) = self.pop_ready() {
                return Ok(Some(r.item));
            }
            // "dry" = no unresolved tickets AND no fence acks still
            // owed (mirrors drain): returning None while an async
            // fence is mid-apply would let a failed install slip out
            // as a clean-looking session
            let fence_debt: usize =
                self.fence_acks_owed.iter().sum();
            if self.outstanding.is_empty() && fence_debt == 0 {
                return Ok(None);
            }
            if let Some(ack) = self.wait_event()? {
                self.note_fence(ack);
            }
        }
    }

    /// Block until the next ticket resolves. Errors when nothing is
    /// outstanding (nothing can ever arrive), when every worker is
    /// gone, or when a streaming fence has failed.
    pub fn recv(&mut self) -> Result<Completed> {
        match self.next_resolved()? {
            Some(c) => Ok(c),
            None => bail!("recv with no outstanding tickets"),
        }
    }

    /// Cancel an outstanding ticket. Resolution still arrives through
    /// `poll`/`recv`/`drain`: as [`Completed::Aborted`], or as
    /// [`Completed::Done`] if the completion won the race. Unknown /
    /// already-resolved tickets are an inert no-op.
    pub fn abort(&mut self, ticket: TicketId) -> Result<()> {
        // two passes: a send failure means the ticket's replica died,
        // and reaping re-routes the ticket to a survivor (or settles
        // it as failed) — the retry targets its NEW placement instead
        // of erroring on a ticket the pool can still cancel
        for attempt in 0..2 {
            let Some(&(replica, _)) = self.outstanding.get(&ticket)
            else {
                return Ok(()); // already resolved (or reaped) — inert
            };
            let w = self.workers.get(replica).ok_or_else(|| {
                anyhow!(
                    "ticket {ticket} maps to replica {replica} \
                     out of range"
                )
            })?;
            self.hb.ctl_send(replica, MsgLabel::Abort { ticket });
            if w.send_ctl(Ctl::Abort(ticket)).is_ok() {
                return Ok(());
            }
            self.hb.send_failed(replica);
            if attempt == 0 {
                self.reap_dead_workers();
            }
        }
        bail!("abort of ticket {ticket} found no live replica");
    }

    /// Run the pool dry: block until every outstanding ticket
    /// resolves, then return all completions sorted by request id
    /// (aborted tickets are simply absent). Any failed ticket or fence
    /// failure turns the whole call into an `Err` — after everything
    /// has settled, with delivered results dropped and their tokens
    /// discarded, preserving the barrier `generate`'s all-or-nothing
    /// accounting.
    pub fn drain(&mut self) -> Result<Vec<Completion>> {
        self.drain_with(None)
    }

    fn drain_with(
        &mut self,
        mut first_err: Option<Error>,
    ) -> Result<Vec<Completion>> {
        let mut out: Vec<(usize, Completion)> = Vec::new();
        loop {
            self.pump();
            while let Some(r) = self.pop_ready() {
                match r.item {
                    Completed::Done(c) => out.push((r.replica, c)),
                    Completed::Aborted(_) => {}
                    Completed::Failed(id, msg) => {
                        first_err.get_or_insert(anyhow!(
                            "request {id} failed: {msg}"
                        ));
                    }
                }
            }
            // run dry = no unresolved tickets AND no fence acks still
            // owed: an un-awaited sync fence must not be able to fail
            // after drain reported success
            let fence_debt: usize =
                self.fence_acks_owed.iter().sum();
            if self.outstanding.is_empty() && fence_debt == 0 {
                break;
            }
            match self.wait_event() {
                Ok(Some(ack)) => self.note_fence(ack),
                Ok(None) => {}
                Err(e) => {
                    first_err.get_or_insert(e);
                    break;
                }
            }
        }
        if first_err.is_none() {
            first_err = self.fence_failure.take();
        }
        if let Some(e) = first_err {
            // all-or-nothing: the delivered results are dropped with
            // the error, so their replicas must stop counting those
            // tokens as generated — and the router's diagnostics must
            // keep describing what the caller actually received
            // (everything aborted), not what crossed the channel
            for (replica, c) in &out {
                if let Some(w) = self.workers.get(*replica) {
                    let n = c.tokens.len() as u64;
                    self.hb.ctl_send(*replica, MsgLabel::Discard);
                    if w.send_ctl(Ctl::Discard(n)).is_err() {
                        // a dead replica's counters died with it
                        self.hb.send_failed(*replica);
                    }
                }
            }
            self.router
                .reclassify_completed_as_aborted(out.len() as u64);
            return Err(e);
        }
        let mut done: Vec<Completion> =
            out.into_iter().map(|(_, c)| c).collect();
        done.sort_by_key(|c| c.id);
        Ok(done)
    }

    /// Settle every outstanding ticket as aborted (worker-death path)
    /// so router loads cannot leak; returns how many there were.
    fn settle_all_as_aborted(&mut self) -> usize {
        let ids: Vec<u64> = self.outstanding.keys().copied().collect();
        for id in &ids {
            self.router.abort(*id);
        }
        self.outstanding.clear();
        ids.len()
    }

    // ---- barrier compatibility ----

    /// Generate completions for a batch with barrier semantics:
    /// submit everything, run the pool dry, merge by request id.
    /// All-or-nothing like `HloEngine::generate` — any failed request
    /// fails the call and the delivered results are dropped (and
    /// discounted). Mixing with an in-progress streaming session is
    /// rejected: drain first.
    pub fn generate(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<Vec<Completion>> {
        if requests.is_empty() {
            return Ok(Vec::new());
        }
        self.pump();
        if !self.outstanding.is_empty() || !self.ready.is_empty() {
            bail!(
                "barrier generate on a pool with {} streamed tickets \
                 unresolved — drain first",
                self.outstanding.len() + self.ready.len()
            );
        }
        let mut first_err: Option<Error> = None;
        for r in requests {
            if let Err(e) = self.submit(r) {
                first_err = Some(e);
                break;
            }
        }
        self.drain_with(first_err)
    }

    /// Send one control message (built per replica) to every worker,
    /// failing loudly if a worker thread has died.
    fn broadcast<F: Fn() -> Ctl>(&self, mk: F) -> Result<()> {
        for (e, w) in self.workers.iter().enumerate() {
            let m = mk();
            self.hb.ctl_send(e, ctl_label(&m));
            if w.send_ctl(m).is_err() {
                self.hb.send_failed(e);
                bail!("replica {e} worker thread is gone");
            }
        }
        Ok(())
    }

    // ---- epoch-fenced installs ----

    /// Asynchronous weight-sync fence (the streaming path): broadcast
    /// one `Arc`'d quantized parameter list (quantize once, upload per
    /// replica) and return the NEW epoch immediately. Each replica
    /// finishes its in-flight sequences under the old weights first;
    /// requests submitted from now on run under the new ones. Fence
    /// failures surface on the next `drain` / awaited install.
    pub fn sync_weights(
        &mut self,
        weights: Arc<Vec<HostArray>>,
    ) -> Result<u64> {
        self.send_fence(|target| Fence::Weights(weights.clone(), target))
            .map(|_| self.epoch)
    }

    /// Asynchronous KV-scale fence (recalibration broadcast), same
    /// epoch semantics as [`EnginePool::sync_weights`].
    pub fn sync_kv_scales(&mut self, k: f32, v: f32) -> Result<u64> {
        self.send_fence(|target| Fence::KvScales(k, v, target))
            .map(|_| self.epoch)
    }

    /// Broadcast one fence message and advance the pool epoch —
    /// UNCONDITIONALLY, and to every replica a send can still reach:
    /// replicas that receive the fence move to the new epoch, so the
    /// pool's submission stamp must move with them even if a dead
    /// replica makes the broadcast partial (bailing between the two
    /// would permanently desync the HEALTHY replicas from the stamp,
    /// wedging every later submission). A dead replica owes no ack
    /// (the reaper writes off its tickets) and is reported as the
    /// error, but the pool keeps limping per-ticket.
    fn send_fence<F: Fn(u64) -> Fence>(&mut self, mk: F) -> Result<()> {
        let target = self.epoch + 1;
        self.epoch = target;
        let mut first_err: Option<Error> = None;
        for (r, w) in self.workers.iter().enumerate() {
            self.hb.fence_send(r, target);
            if w.send_ordered(Ordered::Fence(mk(target))).is_err() {
                self.hb.send_failed(r);
                first_err.get_or_insert(anyhow!(
                    "replica {r} worker thread is gone"
                ));
                continue;
            }
            if let Some(owed) = self.fence_acks_owed.get_mut(r) {
                *owed += 1;
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Install one quantized parameter set into every replica and WAIT
    /// for every fence to apply (the barrier-mode weight sync; workers
    /// still drain their in-flight work first).
    pub fn install_weights(
        &mut self,
        weights: Arc<Vec<HostArray>>,
    ) -> Result<()> {
        let epoch = self.sync_weights(weights)?;
        self.wait_fences(epoch, "weight install")
    }

    /// Broadcast recalibrated KV scales to every replica and wait for
    /// the fences (barrier mode).
    pub fn install_kv_scales(&mut self, k: f32, v: f32) -> Result<()> {
        let epoch = self.sync_kv_scales(k, v)?;
        self.wait_fences(epoch, "kv-scale install")
    }

    /// Block until every replica acknowledges the given fence epoch,
    /// settling streamed completions that arrive in the meantime.
    fn wait_fences(&mut self, epoch: u64, what: &str) -> Result<()> {
        let n = self.workers.len();
        let mut got = 0usize;
        while got < n {
            match self.wait_event() {
                Ok(Some(ack)) => {
                    if ack.epoch == epoch {
                        if let Err(e) = ack.result {
                            self.router
                                .set_quarantined(ack.replica, true);
                            return Err(e.wrap(format!(
                                "replica {} {what}",
                                ack.replica
                            )));
                        }
                        got += 1;
                    } else {
                        self.note_fence(ack);
                    }
                }
                Ok(None) => {
                    // a replica that died with this fence's ack still
                    // owed had its debt written off by the reaper
                    // (inside wait_event), recording a failure — that
                    // is the ONLY dead-worker case that can block this
                    // wait; one that already acknowledged blocks
                    // nothing and must not fail a successful install
                    if let Some(e) = self.fence_failure.take() {
                        return Err(e.wrap(format!(
                            "while waiting for {what} acks"
                        )));
                    }
                }
                Err(e) => {
                    return Err(e.wrap(format!(
                        "only {got}/{n} replicas acknowledged {what}"
                    )))
                }
            }
        }
        // a previously un-awaited fence that failed surfaces here too
        // (the field's contract: next drain OR fence wait reports it)
        if let Some(e) = self.fence_failure.take() {
            return Err(e.wrap(format!(
                "an earlier fence had failed (noticed while waiting \
                 for {what})"
            )));
        }
        Ok(())
    }

    // ---- stats ----

    /// Aggregate engine counters across all replicas.
    pub fn stats(&self) -> Result<EngineStats> {
        let mut total = EngineStats::default();
        for s in self.per_replica_stats()? {
            total.merge(&s);
        }
        Ok(total)
    }

    /// Per-replica engine counters, indexed by replica. (Stats
    /// requests jump pending fences — they never stall behind an
    /// in-flight drain — so mid-stream reads are snapshots; for exact
    /// end-of-stream numbers, drain first.)
    pub fn per_replica_stats(&self) -> Result<Vec<EngineStats>> {
        let (tx, rx) = channel();
        self.broadcast(|| Ctl::Stats(tx.clone()))?;
        drop(tx);
        let n = self.workers.len();
        let mut out = vec![EngineStats::default(); n];
        let mut got = 0usize;
        while let Ok((replica, s)) = rx.recv() {
            let Some(slot) = out.get_mut(replica) else {
                bail!("stats reply from unknown replica {replica}");
            };
            *slot = s;
            got += 1;
        }
        if got != n {
            bail!("only {got}/{n} replicas reported stats");
        }
        Ok(out)
    }
}

impl std::fmt::Debug for EnginePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EnginePool")
            .field("n_replicas", &self.workers.len())
            .field("epoch", &self.epoch)
            .field("outstanding", &self.outstanding.len())
            .field("ready", &self.ready.len())
            .finish_non_exhaustive()
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        for (r, w) in self.workers.iter().enumerate() {
            // an already-dead worker needs no shutdown; the join
            // below still bounds its lifetime
            self.hb.ctl_send(r, MsgLabel::Shutdown);
            if w.send_ctl(Ctl::Shutdown).is_err() {
                self.hb.send_failed(r);
            }
        }
        for h in self.handles.iter_mut() {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

/// The RL loop's rollout backend: a single in-process engine (the
/// default) or the streaming engine pool, behind one surface so the
/// coordinator is agnostic to the serving topology.
pub enum Rollout {
    Single(Box<HloEngine>),
    Pool(EnginePool),
}

impl Rollout {
    pub fn generate(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<Vec<Completion>> {
        match self {
            Rollout::Single(e) => e.generate(requests),
            Rollout::Pool(p) => p.generate(requests),
        }
    }

    /// Install synced weights with barrier semantics; the pool fences
    /// every replica and waits (quantized once upstream, `Arc`'d out).
    pub fn install_weights(
        &mut self,
        weights: Arc<Vec<HostArray>>,
    ) -> Result<()> {
        match self {
            Rollout::Single(e) => e.install_weights(&weights),
            Rollout::Pool(p) => p.install_weights(weights),
        }
    }

    pub fn install_kv_scales(&mut self, k: f32, v: f32) -> Result<()> {
        match self {
            Rollout::Single(e) => {
                e.install_kv_scales(k, v);
                Ok(())
            }
            Rollout::Pool(p) => p.install_kv_scales(k, v),
        }
    }

    /// The current weight epoch (bumped by every weight / KV-scale
    /// install; completions are tagged with it).
    pub fn epoch(&self) -> u64 {
        match self {
            Rollout::Single(e) => e.weight_epoch(),
            Rollout::Pool(p) => p.epoch(),
        }
    }

    /// Aggregate engine counters (summed across replicas for a pool).
    pub fn stats(&self) -> Result<EngineStats> {
        match self {
            Rollout::Single(e) => Ok(e.stats.clone()),
            Rollout::Pool(p) => p.stats(),
        }
    }

    pub fn n_replicas(&self) -> usize {
        match self {
            Rollout::Single(_) => 1,
            Rollout::Pool(p) => p.n_replicas(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rollout::request::SamplingParams;
    use std::collections::BTreeSet;

    fn reqs(lo: u64, hi: u64) -> Vec<Request> {
        (lo..hi)
            .map(|i| Request {
                id: i,
                prompt: vec![12, (i % 10) as i32, 10, 3, 11],
                params: SamplingParams {
                    temperature: 1.0,
                    max_new_tokens: 4,
                    ..Default::default()
                },
            })
            .collect()
    }

    fn pool(n: usize) -> EnginePool {
        EnginePool::new(
            PoolConfig {
                n_replicas: n,
                policy: RoutePolicy::RoundRobin,
                engine: EngineConfig::new("dense", "bf16"),
            },
            hermetic_runtime_factory(),
        )
        .unwrap()
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut p = pool(2);
        assert!(p.generate(Vec::new()).unwrap().is_empty());
        assert_eq!(p.loads(), &[0, 0]);
    }

    #[test]
    fn merge_is_sorted_by_id_and_loads_drain() {
        let mut p = pool(3);
        let done = p.generate(reqs(0, 9)).unwrap();
        assert_eq!(done.len(), 9);
        let ids: Vec<u64> = done.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
        assert_eq!(p.loads(), &[0, 0, 0], "router load must drain");
        let stats = p.stats().unwrap();
        let delivered: usize =
            done.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(stats.tokens_generated, delivered as u64);
    }

    #[test]
    fn streaming_tickets_resolve_exactly_once() {
        let mut p = pool(2);
        let mut tickets = BTreeSet::new();
        for r in reqs(0, 6) {
            tickets.insert(p.submit(r).unwrap());
        }
        assert_eq!(tickets.len(), 6);
        let mut resolved = BTreeSet::new();
        while resolved.len() < 6 {
            match p.recv().unwrap() {
                Completed::Done(c) => {
                    assert!(resolved.insert(c.id), "double-resolve");
                    assert_eq!(c.epoch, 0);
                }
                Completed::Aborted(id) => panic!("spurious abort of {id}"),
                Completed::Failed(id, msg) => {
                    panic!("ticket {id} failed: {msg}")
                }
            }
        }
        assert_eq!(resolved, tickets);
        assert_eq!(p.n_outstanding(), 0);
        assert_eq!(p.loads(), &[0, 0], "live settlement drains loads");
        assert!(
            p.recv().is_err(),
            "recv with nothing outstanding must error, not hang"
        );
    }

    #[test]
    fn abort_resolves_tickets_without_leaking_load() {
        let mut p = pool(2);
        let tickets: Vec<u64> = reqs(0, 6)
            .into_iter()
            .map(|r| p.submit(r).unwrap())
            .collect();
        for t in &tickets {
            p.abort(*t).unwrap();
        }
        let mut resolved = BTreeSet::new();
        while resolved.len() < tickets.len() {
            match p.recv().unwrap() {
                // an abort can lose the race to a real completion;
                // either way the ticket resolves exactly once
                Completed::Done(c) => assert!(resolved.insert(c.id)),
                Completed::Aborted(id) => assert!(resolved.insert(id)),
                Completed::Failed(id, msg) => {
                    panic!("ticket {id} failed: {msg}")
                }
            }
        }
        assert_eq!(p.n_outstanding(), 0);
        assert_eq!(p.loads(), &[0, 0], "aborts must settle the router");
        // the pool stays serviceable after a fully-aborted stream
        assert_eq!(p.generate(reqs(10, 14)).unwrap().len(), 4);
    }

    #[test]
    fn barrier_generate_rejects_mixing_with_live_stream() {
        let mut p = pool(2);
        p.submit(reqs(0, 1).pop().unwrap()).unwrap();
        let err = p.generate(reqs(1, 3)).unwrap_err().to_string();
        assert!(err.contains("drain first"), "{err}");
        // the streamed ticket still resolves
        let done = p.drain().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 0);
    }

    #[test]
    fn failed_shard_fails_the_call_but_leaks_nothing() {
        let mut p = pool(2);
        let mut batch = reqs(0, 3);
        // prompt_len is 16 in the synthetic manifest: a 64-token prompt
        // can never be admitted, so its replica rejects the enqueue
        batch.push(Request {
            id: 99,
            prompt: vec![1; 64],
            params: SamplingParams::default(),
        });
        assert!(p.generate(batch).is_err());
        assert_eq!(p.loads(), &[0, 0], "no phantom router load");
        // the delivered-tokens invariant survives the dropped results
        let stats = p.stats().unwrap();
        assert_eq!(stats.tokens_generated, 0);
        // the pool stays serviceable
        let done = p.generate(reqs(10, 14)).unwrap();
        assert_eq!(done.len(), 4);
        let delivered: usize =
            done.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(
            p.stats().unwrap().tokens_generated,
            delivered as u64
        );
    }

    #[test]
    fn duplicate_outstanding_id_is_rejected() {
        let mut p = pool(2);
        let r = reqs(0, 1).pop().unwrap();
        p.submit(r.clone()).unwrap();
        assert!(p.submit(r).is_err(), "dup id would corrupt the merge");
        let done = p.drain().unwrap();
        assert_eq!(done.len(), 1);
    }

    #[cfg(feature = "hb")]
    #[test]
    fn traced_session_passes_the_conformance_checker() {
        use crate::testkit::hb::HbRecorder;
        let rec = HbRecorder::new(2);
        let mut p = EnginePool::new_traced(
            PoolConfig {
                n_replicas: 2,
                policy: RoutePolicy::RoundRobin,
                engine: EngineConfig::new("dense", "bf16"),
            },
            hermetic_runtime_factory(),
            HbHandle::traced(rec.clone()),
        )
        .unwrap();
        for r in reqs(0, 4) {
            p.submit(r).unwrap();
        }
        p.install_kv_scales(1.0, 1.0).unwrap();
        for r in reqs(4, 8) {
            p.submit(r).unwrap();
        }
        let done = p.drain().unwrap();
        assert_eq!(done.len(), 8);
        let report = p
            .hb_verify()
            .expect("conformant session")
            .expect("pool is traced");
        assert_eq!(report.tickets, 8);
        assert_eq!(report.fences, 2, "one fence per replica");
        // epochs split across the install
        for c in &done {
            assert_eq!(c.epoch, u64::from(c.id >= 4));
        }
        drop(p);
        rec.check().expect("teardown stays conformant");
    }

    #[cfg(feature = "hb")]
    #[test]
    fn mis_sized_recorder_is_rejected() {
        use crate::testkit::hb::HbRecorder;
        let err = EnginePool::new_traced(
            PoolConfig {
                n_replicas: 2,
                policy: RoutePolicy::RoundRobin,
                engine: EngineConfig::new("dense", "bf16"),
            },
            hermetic_runtime_factory(),
            HbHandle::traced(HbRecorder::new(3)),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("sized for 3"), "{err}");
    }

    #[test]
    fn bad_replica_count_is_rejected() {
        let r = EnginePool::new(
            PoolConfig {
                n_replicas: 0,
                policy: RoutePolicy::RoundRobin,
                engine: EngineConfig::new("dense", "bf16"),
            },
            hermetic_runtime_factory(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn startup_failure_names_the_replica() {
        let err = EnginePool::new(
            PoolConfig {
                n_replicas: 2,
                policy: RoutePolicy::RoundRobin,
                engine: EngineConfig::new("dense", "no_such_variant"),
            },
            hermetic_runtime_factory(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("failed to start"), "{err}");
    }
}
