//! Request types flowing through the rollout engine.

/// Sampling parameters for one request.
#[derive(Clone, Debug)]
pub struct SamplingParams {
    /// softmax temperature; 0.0 means greedy (argmax)
    pub temperature: f32,
    /// top-k truncation (0 = disabled)
    pub top_k: usize,
    /// nucleus truncation (1.0 = disabled)
    pub top_p: f32,
    pub max_new_tokens: usize,
    /// stop token (EOS)
    pub eos: i32,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
            max_new_tokens: 32,
            eos: 13,
        }
    }
}

/// A generation request submitted to the engine.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    /// hit the model's max_seq capacity
    CacheLimit,
}

/// Completed request output.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub tokens: Vec<i32>,
    /// behavior-policy logprob of each generated token (pi_fp8 in the
    /// paper's eq. 2 — measured from the engine's own logits): the
    /// probability under the distribution the token was ACTUALLY drawn
    /// from (temperature-scaled, top-k/top-p truncated, renormalized).
    /// This is the TIS/MIS denominator.
    pub logprobs: Vec<f32>,
    /// full-vocabulary temperature-1 log-softmax at each generated
    /// token — the convention the trainer evaluates pi_theta in.
    /// Identical to `logprobs` when sampling is untruncated at
    /// temperature 1 (the RL-loop default); kept separately so the
    /// trainer can diagnose truncation skew.
    pub logprobs_full: Vec<f32>,
    pub finish: FinishReason,
    /// decode steps this request waited due to preemption
    pub preemptions: u32,
    /// weight epoch the whole completion was generated under (bumped by
    /// every weight / KV-scale install — see `HloEngine::weight_epoch`).
    /// The streaming pool's epoch fence guarantees no completion spans
    /// an install, so this single tag identifies the behavior policy
    /// (pi_fp8) its `logprobs` were measured from — the TIS/MIS
    /// denominator the trainer must match.
    pub epoch: u64,
}
