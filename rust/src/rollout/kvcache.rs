//! Paged KV-cache block manager (the vLLM-style allocator), with
//! refcounted copy-on-write prefix sharing.
//!
//! This is the substrate behind the paper's §2.3 performance analysis:
//! KV-cache *capacity* bounds concurrency, and when the active set's
//! context grows past capacity the scheduler must preempt sequences
//! (recompute-style eviction), wasting work. FP8 KV storage halves
//! bytes/token, doubling capacity — the mechanism behind the 38% gain.
//!
//! RL rollouts are the best possible case for prefix reuse on top of
//! that: a DAPO/GRPO group samples G completions from the *same*
//! prompt. [`KvBlockManager::allocate_shared`] looks the prompt up in
//! a prefix-hash registry, bumps refcounts on the blocks already
//! holding that prefix's KV, and takes only the tail from the free
//! list — so a group of G pays ~1/G of the prompt KV, multiplicative
//! with the FP8 halving. Appending into a shared block triggers
//! copy-on-write; a block returns to the free list only when its
//! refcount hits zero, so evicting one group member can never free a
//! block another member still references. See DESIGN.md §10.
//!
//! The manager is *accounting-only*: the engine's device cache is a
//! dense per-row tensor, and the row-aliasing fast path (engine.rs)
//! moves the actual KV bytes. The block tables here model capacity,
//! drive admission/preemption, and carry the sharing bookkeeping the
//! engine's counters are derived from.
//!
//! Used by both the real HLO-backed engine (tiny models) and the H100
//! cost-model simulator (8B/30B descriptors), so preemption dynamics in
//! the perf figures come from a real allocator, not a formula.

use std::collections::BTreeMap;

use crate::util::error::{bail, Result};
use crate::util::units::{Blocks, Bytes, Tokens};

/// Bytes per KV element for each storage precision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvPrecision {
    Bf16,
    Fp8,
}

impl KvPrecision {
    pub fn bytes_per_elem(self) -> usize {
        match self {
            KvPrecision::Bf16 => 2,
            KvPrecision::Fp8 => 1,
        }
    }
}

/// A zero-sized cache geometry. Every constructor validates up front
/// so `blocks_in` / `from_budget` return this typed error instead of
/// panicking on the divide by `bytes_per_block() == 0`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvGeometryError {
    ZeroLayers,
    ZeroKvHeads,
    ZeroHeadDim,
    ZeroBlockTokens,
}

impl std::fmt::Display for KvGeometryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let what = match self {
            KvGeometryError::ZeroLayers => "n_layers",
            KvGeometryError::ZeroKvHeads => "n_kv_heads",
            KvGeometryError::ZeroHeadDim => "d_head",
            KvGeometryError::ZeroBlockTokens => "block_tokens",
        };
        write!(f, "invalid KV geometry: {what} must be non-zero")
    }
}

impl std::error::Error for KvGeometryError {}

/// Static geometry of the cache.
#[derive(Clone, Copy, Debug)]
pub struct KvGeometry {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    /// tokens per block (vLLM default 16)
    pub block_tokens: usize,
    pub precision: KvPrecision,
}

impl KvGeometry {
    /// Reject zero-sized geometries (0 layers/heads/head-dim or
    /// `block_tokens == 0`): every dimension participates in a
    /// divisor somewhere downstream.
    pub fn validate(&self) -> Result<(), KvGeometryError> {
        if self.n_layers == 0 {
            return Err(KvGeometryError::ZeroLayers);
        }
        if self.n_kv_heads == 0 {
            return Err(KvGeometryError::ZeroKvHeads);
        }
        if self.d_head == 0 {
            return Err(KvGeometryError::ZeroHeadDim);
        }
        if self.block_tokens == 0 {
            return Err(KvGeometryError::ZeroBlockTokens);
        }
        Ok(())
    }

    /// Bytes of K+V for one token across all layers.
    pub fn bytes_per_token(&self) -> Bytes {
        Bytes::new(
            2 * self.n_layers
                * self.n_kv_heads
                * self.d_head
                * self.precision.bytes_per_elem(),
        )
    }

    pub fn bytes_per_block(&self) -> Bytes {
        Bytes::new(self.bytes_per_token().get() * self.block_tokens)
    }

    /// How many blocks fit in a byte budget (the bytes -> blocks
    /// conversion point for rule U1). Errors on a zero-sized geometry
    /// instead of panicking on the division.
    pub fn blocks_in(
        &self,
        budget: Bytes,
    ) -> Result<Blocks, KvGeometryError> {
        self.validate()?;
        Ok(Blocks::new(budget.get() / self.bytes_per_block().get()))
    }
}

/// FNV-1a over a token stream — the prefix-registry key. Stable
/// across runs and processes (no `RandomState`), cheap, and good
/// enough for a registry whose lookups are verified token-by-token
/// (a hash collision only costs a missed share, never a wrong one).
/// Also used by the router's prefix-affinity placement.
pub fn prefix_hash(tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[derive(Debug)]
struct SeqAlloc {
    blocks: Vec<usize>,
    tokens: usize,
}

/// A registered shareable prefix: the exact tokens (lookups verify
/// against them — the hash only routes) and the blocks holding their
/// KV, in prefix order.
#[derive(Debug)]
struct PrefixEntry {
    tokens: Vec<i32>,
    blocks: Vec<usize>,
}

/// What a shared allocation was served from: blocks taken by bumping
/// registry refcounts vs. blocks taken from the free list, and how
/// many prompt tokens the shared blocks cover.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SharedGrant {
    pub shared_blocks: Blocks,
    pub new_blocks: Blocks,
    pub shared_tokens: Tokens,
}

/// Block allocator with per-sequence block tables and refcounted
/// copy-on-write prefix sharing.
pub struct KvBlockManager {
    pub geometry: KvGeometry,
    total_blocks: usize,
    free: Vec<usize>,
    seqs: BTreeMap<u64, SeqAlloc>,
    /// per-block reference count; 0 == on the free list
    refcount: Vec<u32>,
    /// prefix-hash -> shareable prefix (first writer wins; purged
    /// eagerly when any member block's refcount hits zero)
    prefix_map: BTreeMap<u64, PrefixEntry>,
    /// reverse index: block -> registry keys naming it. A freed block
    /// id gets recycled with different contents, so every entry still
    /// pointing at it must die with it (the ABA hazard).
    block_keys: BTreeMap<usize, Vec<u64>>,
    /// counters for metrics
    pub alloc_failures: u64,
    pub peak_used: Blocks,
    /// cumulative blocks served by bumping a registry refcount
    /// instead of the free list
    pub shared_block_hits: u64,
    /// cumulative prompt tokens whose KV those shared blocks cover
    pub shared_token_hits: u64,
}

impl KvBlockManager {
    pub fn new(
        geometry: KvGeometry,
        total_blocks: Blocks,
    ) -> Result<Self, KvGeometryError> {
        geometry.validate()?;
        let total_blocks = total_blocks.get();
        Ok(KvBlockManager {
            geometry,
            total_blocks,
            free: (0..total_blocks).rev().collect(),
            seqs: BTreeMap::new(),
            refcount: vec![0; total_blocks],
            prefix_map: BTreeMap::new(),
            block_keys: BTreeMap::new(),
            alloc_failures: 0,
            peak_used: Blocks::ZERO,
            shared_block_hits: 0,
            shared_token_hits: 0,
        })
    }

    pub fn from_budget(
        geometry: KvGeometry,
        budget: Bytes,
    ) -> Result<Self, KvGeometryError> {
        Self::new(geometry, geometry.blocks_in(budget)?)
    }

    pub fn total_blocks(&self) -> Blocks {
        Blocks::new(self.total_blocks)
    }

    pub fn used_blocks(&self) -> Blocks {
        // free blocks only ever come out of the initial pool, so the
        // free list can never exceed the total; saturate anyway rather
        // than letting a future accounting bug wrap to usize::MAX
        Blocks::new(self.total_blocks.saturating_sub(self.free.len()))
    }

    pub fn free_blocks(&self) -> Blocks {
        Blocks::new(self.free.len())
    }

    pub fn n_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn has_seq(&self, id: u64) -> bool {
        self.seqs.contains_key(&id)
    }

    pub fn seq_tokens(&self, id: u64) -> Tokens {
        Tokens::new(self.seqs.get(&id).map(|s| s.tokens).unwrap_or(0))
    }

    /// Bytes of prompt KV served from shared blocks so far (what the
    /// engine's `kv_bytes_shared` counter is derived from).
    pub fn shared_bytes_total(&self) -> Bytes {
        Bytes::new(
            self.geometry.bytes_per_block().get()
                * self.shared_block_hits as usize,
        )
    }

    /// Blocks needed to hold `tokens` tokens (the tokens -> blocks
    /// conversion point for rule U1).
    pub fn blocks_for(&self, tokens: Tokens) -> Blocks {
        Blocks::new(tokens.get().div_ceil(self.geometry.block_tokens))
    }

    fn rc(&self, b: usize) -> u32 {
        self.refcount.get(b).copied().unwrap_or(0)
    }

    /// True when the sequence's allocation is exactly full — its next
    /// appended token will need a fresh block.
    pub fn at_block_boundary(&self, id: u64) -> bool {
        self.seqs.get(&id).is_some_and(|s| {
            s.tokens == s.blocks.len() * self.geometry.block_tokens
        })
    }

    /// Will this sequence's next `append_token` take a block from the
    /// free list? True at a block boundary (fresh block needed) or
    /// when its tail block is shared (the append must copy-on-write).
    /// The scheduler counts these into its admission growth reserve;
    /// without sharing every refcount is 1 and this degenerates to
    /// exactly [`KvBlockManager::at_block_boundary`].
    pub fn append_needs_block(&self, id: u64) -> bool {
        let Some(s) = self.seqs.get(&id) else {
            return false;
        };
        if s.tokens == s.blocks.len() * self.geometry.block_tokens {
            return true;
        }
        s.blocks.last().is_some_and(|&b| self.rc(b) > 1)
    }

    /// Can a new sequence of `tokens` tokens be admitted right now?
    /// Applies the same `max(1)` clamp as `allocate`: the old version
    /// answered "yes, 0 blocks" for a 0-token probe that `allocate`
    /// would then charge a whole block for, so a check-then-allocate
    /// caller could fail the allocation it was just promised.
    pub fn can_allocate(&self, tokens: Tokens) -> bool {
        self.blocks_for(tokens.max(Tokens::new(1)))
            <= Blocks::new(self.free.len())
    }

    /// Pop `need` blocks off the free list at refcount 1, or `None`
    /// (without touching anything) if the list is short.
    fn take_free(&mut self, need: usize) -> Option<Vec<usize>> {
        if need > self.free.len() {
            return None;
        }
        let at = self.free.len().saturating_sub(need);
        let blocks = self.free.split_off(at);
        for &b in &blocks {
            if let Some(slot) = self.refcount.get_mut(b) {
                *slot = 1;
            }
        }
        Some(blocks)
    }

    fn ref_block(&mut self, b: usize) {
        if let Some(slot) = self.refcount.get_mut(b) {
            *slot = slot.saturating_add(1);
        }
    }

    /// Drop one reference; a block whose refcount reaches zero goes
    /// back on the free list and every registry entry naming it dies
    /// with it. This is the sharing-safety property: eviction of one
    /// group member can never free a block another still references.
    fn unref_block(&mut self, b: usize) {
        let Some(slot) = self.refcount.get_mut(b) else {
            return;
        };
        *slot = slot.saturating_sub(1);
        if *slot == 0 {
            self.free.push(b);
            self.purge_block_keys(b);
        }
    }

    /// Remove every registry entry naming `b` (called exactly when
    /// its refcount hits zero), unlinking the entries from the other
    /// blocks' reverse-index rows as well.
    fn purge_block_keys(&mut self, b: usize) {
        let Some(keys) = self.block_keys.remove(&b) else {
            return;
        };
        for k in keys {
            let Some(entry) = self.prefix_map.remove(&k) else {
                continue;
            };
            for &ob in &entry.blocks {
                if ob == b {
                    continue;
                }
                let emptied = self
                    .block_keys
                    .get_mut(&ob)
                    .map(|ks| {
                        ks.retain(|&kk| kk != k);
                        ks.is_empty()
                    })
                    .unwrap_or(false);
                if emptied {
                    self.block_keys.remove(&ob);
                }
            }
        }
    }

    /// Register `tokens` -> `blocks` under its prefix hash. First
    /// writer wins: identical prompts re-register the same mapping;
    /// a colliding different prompt keeps the incumbent (lookups
    /// verify tokens, so a collision costs a miss, never corruption).
    fn register_prefix(&mut self, tokens: &[i32], blocks: &[usize]) {
        if tokens.is_empty() || blocks.is_empty() {
            return;
        }
        let key = prefix_hash(tokens);
        if self.prefix_map.contains_key(&key) {
            return;
        }
        self.prefix_map.insert(
            key,
            PrefixEntry {
                tokens: tokens.to_vec(),
                blocks: blocks.to_vec(),
            },
        );
        for &b in blocks {
            self.block_keys.entry(b).or_default().push(key);
        }
    }

    /// Register every shareable prefix of `prompt` under this
    /// sequence's block table: each full-block prefix, plus the whole
    /// prompt when it ends inside a partial block AND the allocation
    /// adds no tokens beyond the prompt. A partial tail block of an
    /// allocation that extends past the prompt will also hold
    /// non-prompt KV, so it must stay private.
    fn register_all(
        &mut self,
        tokens_total: usize,
        prompt: &[i32],
        blocks: &[usize],
    ) {
        let bt = self.geometry.block_tokens;
        let p = prompt.len().min(tokens_total);
        for k in 1..=p / bt {
            let (Some(pre), Some(bl)) =
                (prompt.get(..k * bt), blocks.get(..k))
            else {
                break;
            };
            self.register_prefix(pre, bl);
        }
        if tokens_total == p && p % bt != 0 {
            if let (Some(pre), Some(bl)) =
                (prompt.get(..p), blocks.get(..p.div_ceil(bt)))
            {
                self.register_prefix(pre, bl);
            }
        }
    }

    /// Longest registered prefix of `prompt` still resident: the
    /// whole prompt first (partial tail block included — only ever
    /// registered when the owning allocation ends exactly at the
    /// prompt, and only claimable under the same condition), then
    /// full-block prefixes, longest first. Returns the blocks to
    /// share and the token count they cover.
    fn find_prefix(
        &self,
        tokens_total: usize,
        prompt: &[i32],
    ) -> Option<(Vec<usize>, usize)> {
        let bt = self.geometry.block_tokens;
        let p = prompt.len().min(tokens_total);
        let try_len = |len: usize| -> Option<(Vec<usize>, usize)> {
            let pre = prompt.get(..len)?;
            let e = self.prefix_map.get(&prefix_hash(pre))?;
            if e.tokens != pre {
                return None; // hash collision: verified mismatch
            }
            if e.blocks.len() != len.div_ceil(bt) {
                return None; // defensive: malformed entry
            }
            Some((e.blocks.clone(), len))
        };
        if tokens_total == p && p % bt != 0 {
            if let Some(hit) = try_len(p) {
                return Some(hit);
            }
        }
        let mut k = p / bt;
        while k > 0 {
            if let Some(hit) = try_len(k * bt) {
                return Some(hit);
            }
            k -= 1;
        }
        None
    }

    /// Admission accounting for the sharing path, mirror of the
    /// unshared `(blocks_for(t), blocks_for(t+1))` pair: free-list
    /// blocks a fresh `allocate_shared` would take right now, and
    /// with one token of growth. The growth block is charged when the
    /// allocation ends exactly at a block boundary (same as the
    /// unshared math) OR when the registry covers the allocation's
    /// tail block — the first append then needs a copy-on-write
    /// block instead of appending in place.
    pub fn shared_admission_need(
        &self,
        tokens: Tokens,
        prompt: &[i32],
    ) -> (Blocks, Blocks) {
        let t = tokens.get().max(1);
        let total = self.blocks_for(Tokens::new(t)).get();
        let shared = self
            .find_prefix(t, prompt)
            .map(|(bl, _)| bl.len())
            .unwrap_or(0);
        let now = total.saturating_sub(shared);
        let grown = if t % self.geometry.block_tokens == 0
            || shared >= total
        {
            now.saturating_add(1)
        } else {
            now
        };
        (Blocks::new(now), Blocks::new(grown))
    }

    /// Admit a sequence with an initial `tokens` tokens (prompt).
    /// Returns false (and counts a failure) if blocks are unavailable.
    /// A 0-token allocate is clamped to 1 token *consistently*: the
    /// old code sized the blocks from `tokens.max(1)` but stored the
    /// raw 0, leaving a 1-block sequence whose `seq_tokens()` /
    /// `at_block_boundary()` disagreed with its allocation — it never
    /// looked block-boundary-full, so it evaded the scheduler's
    /// admission growth reserve.
    pub fn allocate(&mut self, id: u64, tokens: Tokens) -> bool {
        assert!(!self.seqs.contains_key(&id), "seq {id} already allocated");
        let tokens = tokens.get().max(1);
        let need = self.blocks_for(Tokens::new(tokens)).get();
        let Some(blocks) = self.take_free(need) else {
            self.alloc_failures = self.alloc_failures.saturating_add(1);
            return false;
        };
        self.seqs.insert(id, SeqAlloc { blocks, tokens });
        self.peak_used = self.peak_used.max(self.used_blocks());
        true
    }

    /// Admit a sequence, serving as much of its prompt prefix as
    /// possible from the shared-prefix registry: registered blocks
    /// get a refcount bump, only the tail comes off the free list,
    /// and this prompt's own shareable prefixes are registered for
    /// later arrivals (a GRPO group's first member registers, the
    /// other G-1 hit). Returns what the allocation was served from,
    /// or `None` (counting a failure) if the incremental blocks are
    /// unavailable. `allocate` remains the sharing-free path and
    /// never touches the registry.
    pub fn allocate_shared(
        &mut self,
        id: u64,
        tokens: Tokens,
        prompt: &[i32],
    ) -> Option<SharedGrant> {
        assert!(!self.seqs.contains_key(&id), "seq {id} already allocated");
        let tokens = tokens.get().max(1);
        let need_total = self.blocks_for(Tokens::new(tokens)).get();
        let (hit_blocks, hit_tokens) = self
            .find_prefix(tokens, prompt)
            .unwrap_or((Vec::new(), 0));
        let need_new = need_total.saturating_sub(hit_blocks.len());
        let Some(fresh) = self.take_free(need_new) else {
            self.alloc_failures = self.alloc_failures.saturating_add(1);
            return None;
        };
        for &b in &hit_blocks {
            self.ref_block(b);
        }
        self.shared_block_hits = self
            .shared_block_hits
            .saturating_add(hit_blocks.len() as u64);
        self.shared_token_hits =
            self.shared_token_hits.saturating_add(hit_tokens as u64);
        let grant = SharedGrant {
            shared_blocks: Blocks::new(hit_blocks.len()),
            new_blocks: Blocks::new(need_new),
            shared_tokens: Tokens::new(hit_tokens),
        };
        let mut blocks = hit_blocks;
        blocks.extend(fresh);
        self.register_all(tokens, prompt, &blocks);
        self.seqs.insert(id, SeqAlloc { blocks, tokens });
        self.peak_used = self.peak_used.max(self.used_blocks());
        Some(grant)
    }

    /// Extend a sequence by one token; may need a fresh block (at a
    /// block boundary) or a copy-on-write block (tail shared with
    /// other sequences — appending in place would corrupt their KV).
    /// Returns `Ok(false)` if the cache is out of blocks (preemption
    /// required), `Err` if the sequence is unknown (caller bug).
    pub fn append_token(&mut self, id: u64) -> Result<bool> {
        let block_tokens = self.geometry.block_tokens;
        let (at_boundary, tail) = {
            let Some(s) = self.seqs.get(&id) else {
                bail!("append_token on unknown seq {id}");
            };
            (
                s.tokens == s.blocks.len() * block_tokens,
                s.blocks.last().copied(),
            )
        };
        // the displaced COW block keeps its other references (its
        // refcount is > 1 here), so the unref below never frees it
        let cow = !at_boundary && tail.is_some_and(|b| self.rc(b) > 1);
        if at_boundary || cow {
            let fresh = match self.take_free(1).as_deref() {
                Some(&[b]) => b,
                _ => {
                    self.alloc_failures =
                        self.alloc_failures.saturating_add(1);
                    return Ok(false);
                }
            };
            let Some(s) = self.seqs.get_mut(&id) else {
                bail!("append_token on unknown seq {id}");
            };
            if at_boundary {
                s.blocks.push(fresh);
            } else if let Some(t) = s.blocks.last_mut() {
                *t = fresh;
            }
            if cow {
                if let Some(old) = tail {
                    self.unref_block(old);
                }
            }
        }
        let Some(s) = self.seqs.get_mut(&id) else {
            bail!("append_token on unknown seq {id}");
        };
        s.tokens = s.tokens.saturating_add(1);
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(true)
    }

    /// Release a sequence entirely (finished or preempted-with-
    /// recompute): every block drops one reference; only refcount
    /// zero returns a block to the free list.
    pub fn release(&mut self, id: u64) {
        if let Some(s) = self.seqs.remove(&id) {
            for b in s.blocks {
                self.unref_block(b);
            }
        }
    }

    /// Fraction of capacity in use.
    pub fn utilization(&self) -> f64 {
        self.used_blocks().get() as f64 / self.total_blocks.max(1) as f64
    }

    /// Invariant check (used by property tests): refcount
    /// conservation (per-block refcount == number of per-sequence
    /// references), no block both free and referenced, no leaks, and
    /// a registry that names only live blocks with a consistent
    /// reverse index.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut in_free = vec![false; self.total_blocks];
        for &b in &self.free {
            let Some(slot) = in_free.get_mut(b) else {
                return Err(format!("free block {b} out of range"));
            };
            if *slot {
                return Err(format!("block {b} double-listed in free"));
            }
            *slot = true;
            if self.rc(b) != 0 {
                return Err(format!(
                    "free block {b} has refcount {}",
                    self.rc(b)
                ));
            }
        }
        let mut refs = vec![0u32; self.total_blocks];
        for (id, s) in &self.seqs {
            // every live allocation accounts for at least one token —
            // a 0-token sequence would hold blocks its own accessors
            // (`seq_tokens`, `at_block_boundary`) don't account for
            if s.tokens == 0 {
                return Err(format!(
                    "seq {id}: 0 tokens recorded for {} allocated \
                     block(s)",
                    s.blocks.len()
                ));
            }
            let max_tokens = s.blocks.len() * self.geometry.block_tokens;
            if s.tokens > max_tokens {
                return Err(format!(
                    "seq {id}: {} tokens in {} blocks",
                    s.tokens,
                    s.blocks.len()
                ));
            }
            // blocks must be enough but not wasteful (<= 1 spare block)
            if s.tokens + self.geometry.block_tokens
                < s.blocks.len() * self.geometry.block_tokens
            {
                return Err(format!("seq {id}: over-allocated"));
            }
            // a sequence's own table never repeats a block (sharing
            // is only ever across sequences)
            let mut sorted = s.blocks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != s.blocks.len() {
                return Err(format!("seq {id}: duplicate block in table"));
            }
            for &b in &s.blocks {
                let Some(r) = refs.get_mut(b) else {
                    return Err(format!("seq block {b} out of range"));
                };
                *r += 1;
            }
        }
        // refcount conservation + free/referenced exclusivity + leaks
        for b in 0..self.total_blocks {
            let r = refs.get(b).copied().unwrap_or(0);
            let rc = self.rc(b);
            if r != rc {
                return Err(format!(
                    "block {b}: refcount {rc} but {r} sequence \
                     reference(s)"
                ));
            }
            let free = in_free.get(b).copied().unwrap_or(false);
            if free && r > 0 {
                return Err(format!("block {b} both free and referenced"));
            }
            if !free && r == 0 {
                return Err(format!(
                    "leaked block {b} (neither free nor referenced)"
                ));
            }
        }
        // registry hygiene: entries sized to their token prefix, only
        // live blocks, reverse index bijective
        for (key, e) in &self.prefix_map {
            if e.blocks.len()
                != e.tokens.len().div_ceil(self.geometry.block_tokens)
            {
                return Err(format!(
                    "prefix {key:#x}: {} block(s) for {} token(s)",
                    e.blocks.len(),
                    e.tokens.len()
                ));
            }
            for &b in &e.blocks {
                if self.rc(b) == 0 {
                    return Err(format!(
                        "prefix {key:#x} names dead block {b}"
                    ));
                }
                if !self
                    .block_keys
                    .get(&b)
                    .is_some_and(|ks| ks.contains(key))
                {
                    return Err(format!(
                        "prefix {key:#x} missing from block {b}'s \
                         reverse index"
                    ));
                }
            }
        }
        for (b, ks) in &self.block_keys {
            for k in ks {
                if !self
                    .prefix_map
                    .get(k)
                    .is_some_and(|e| e.blocks.contains(b))
                {
                    return Err(format!(
                        "block {b} reverse-indexes stale prefix {k:#x}"
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo(prec: KvPrecision) -> KvGeometry {
        KvGeometry {
            n_layers: 4,
            n_kv_heads: 2,
            d_head: 32,
            block_tokens: 16,
            precision: prec,
        }
    }

    fn mk(prec: KvPrecision, blocks: usize) -> KvBlockManager {
        KvBlockManager::new(geo(prec), Blocks::new(blocks)).unwrap()
    }

    #[test]
    fn bytes_accounting() {
        let g = geo(KvPrecision::Bf16);
        assert_eq!(g.bytes_per_token(), Bytes::new(2 * 4 * 2 * 32 * 2));
        let g8 = geo(KvPrecision::Fp8);
        assert_eq!(g8.bytes_per_token().get() * 2, g.bytes_per_token().get());
    }

    #[test]
    fn fp8_doubles_capacity() {
        let budget = Bytes::new(1 << 20);
        let bf = KvBlockManager::from_budget(geo(KvPrecision::Bf16), budget)
            .unwrap();
        let f8 = KvBlockManager::from_budget(geo(KvPrecision::Fp8), budget)
            .unwrap();
        assert_eq!(f8.total_blocks().get(), 2 * bf.total_blocks().get());
    }

    #[test]
    fn zero_sized_geometry_is_a_typed_error_not_a_panic() {
        // regression: blocks_in divided by bytes_per_block(), which a
        // zero-sized geometry turns into a divide-by-zero panic
        let cases = [
            (
                KvGeometry { n_layers: 0, ..geo(KvPrecision::Bf16) },
                KvGeometryError::ZeroLayers,
            ),
            (
                KvGeometry { n_kv_heads: 0, ..geo(KvPrecision::Bf16) },
                KvGeometryError::ZeroKvHeads,
            ),
            (
                KvGeometry { d_head: 0, ..geo(KvPrecision::Bf16) },
                KvGeometryError::ZeroHeadDim,
            ),
            (
                KvGeometry { block_tokens: 0, ..geo(KvPrecision::Bf16) },
                KvGeometryError::ZeroBlockTokens,
            ),
        ];
        for (bad, want) in cases {
            assert_eq!(bad.validate(), Err(want));
            assert_eq!(bad.blocks_in(Bytes::new(1 << 20)), Err(want));
            assert!(KvBlockManager::new(bad, Blocks::new(4)).is_err());
            assert!(
                KvBlockManager::from_budget(bad, Bytes::new(1 << 20))
                    .is_err()
            );
            assert!(!format!("{want}").is_empty(), "Display impl");
        }
        assert!(geo(KvPrecision::Bf16).validate().is_ok());
    }

    #[test]
    fn alloc_extend_release() {
        let mut m = mk(KvPrecision::Bf16, 8);
        assert!(m.allocate(1, Tokens::new(16))); // exactly 1 block
        assert_eq!(m.used_blocks(), Blocks::new(1));
        // 16 more tokens => one more block
        for _ in 0..16 {
            assert!(m.append_token(1).unwrap());
        }
        assert_eq!(m.used_blocks(), Blocks::new(2));
        m.check_invariants().unwrap();
        m.release(1);
        assert_eq!(m.used_blocks(), Blocks::ZERO);
        assert_eq!(m.peak_used, Blocks::new(2));
        m.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_counts_failures() {
        let mut m = mk(KvPrecision::Bf16, 2);
        assert!(m.allocate(1, Tokens::new(32))); // both blocks
        assert!(!m.allocate(2, Tokens::new(1)));
        assert_eq!(m.alloc_failures, 1);
        assert!(!m.append_token(1).unwrap());
        assert_eq!(m.alloc_failures, 2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn zero_token_allocate_is_clamped_consistently() {
        // regression: allocate(id, 0) used to size its blocks from
        // max(1) but record 0 tokens, so the sequence's accounting
        // disagreed with its allocation (and `at_block_boundary` could
        // never fire, dodging the scheduler's growth reserve)
        let mut m = mk(KvPrecision::Bf16, 4);
        assert!(m.allocate(1, Tokens::ZERO));
        assert_eq!(
            m.seq_tokens(1),
            Tokens::new(1),
            "clamped token count is stored"
        );
        assert_eq!(m.used_blocks(), Blocks::new(1));
        assert!(!m.at_block_boundary(1));
        m.check_invariants().unwrap();
        // growth proceeds from the clamped count: 15 more appends fill
        // the first block exactly, making the boundary visible
        for _ in 0..15 {
            assert!(m.append_token(1).unwrap());
        }
        assert_eq!(m.seq_tokens(1), Tokens::new(16));
        assert!(m.at_block_boundary(1), "boundary must be observable");
        assert_eq!(m.used_blocks(), Blocks::new(1));
        assert!(m.append_token(1).unwrap());
        assert_eq!(m.used_blocks(), Blocks::new(2));
        m.check_invariants().unwrap();
        m.release(1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn can_allocate_matches_allocate_on_zero_tokens() {
        // regression: can_allocate(0) answered "yes, 0 blocks needed"
        // while allocate(0) clamps to 1 token and takes a block — with
        // an empty free list the promise was a lie
        let mut m = mk(KvPrecision::Bf16, 1);
        assert!(m.can_allocate(Tokens::ZERO));
        assert!(m.allocate(1, Tokens::new(16))); // the only block
        assert!(
            !m.can_allocate(Tokens::ZERO),
            "a full cache must not promise a 0-token allocation"
        );
        assert!(!m.allocate(2, Tokens::ZERO));
        m.check_invariants().unwrap();
    }

    #[test]
    fn release_unknown_is_noop() {
        let mut m = mk(KvPrecision::Fp8, 4);
        m.release(99);
        m.check_invariants().unwrap();
    }

    #[test]
    fn shared_group_pays_one_prompt() {
        // a GRPO group: 8 members, one 32-token prompt (2 full blocks)
        let mut m = mk(KvPrecision::Bf16, 64);
        let prompt: Vec<i32> = (0..32).collect();
        let g0 = m
            .allocate_shared(0, Tokens::new(32), &prompt)
            .expect("first member allocates");
        assert_eq!(g0.shared_blocks, Blocks::ZERO, "nothing to hit yet");
        assert_eq!(g0.new_blocks, Blocks::new(2));
        for id in 1..8u64 {
            let g = m
                .allocate_shared(id, Tokens::new(32), &prompt)
                .expect("group member allocates");
            assert_eq!(g.shared_blocks, Blocks::new(2), "full prefix hit");
            assert_eq!(g.new_blocks, Blocks::ZERO);
            assert_eq!(g.shared_tokens, Tokens::new(32));
        }
        // 8 sequences, 2 unique blocks: 1/G of the prompt KV
        assert_eq!(m.used_blocks(), Blocks::new(2));
        assert_eq!(m.shared_block_hits, 14);
        m.check_invariants().unwrap();
        // releasing 7 members keeps the blocks alive for the last one
        for id in 0..7u64 {
            m.release(id);
            m.check_invariants().unwrap();
        }
        assert_eq!(m.used_blocks(), Blocks::new(2));
        assert!(m.has_seq(7));
        m.release(7);
        assert_eq!(m.used_blocks(), Blocks::ZERO);
        m.check_invariants().unwrap();
    }

    #[test]
    fn append_into_shared_tail_copies_on_write() {
        // 5-token prompt in 4-token blocks: 1 full block + a partial
        // tail, both shared (allocation ends exactly at the prompt)
        let g = KvGeometry { block_tokens: 4, ..geo(KvPrecision::Bf16) };
        let mut m = KvBlockManager::new(g, Blocks::new(16)).unwrap();
        let prompt = vec![7, 8, 9, 10, 11];
        assert!(m.allocate_shared(0, Tokens::new(5), &prompt).is_some());
        let g1 = m.allocate_shared(1, Tokens::new(5), &prompt).unwrap();
        assert_eq!(g1.shared_blocks, Blocks::new(2));
        assert_eq!(m.used_blocks(), Blocks::new(2));
        assert!(
            m.append_needs_block(0),
            "appending into the shared tail must look like growth"
        );
        // seq 0 appends: its tail is shared, so it must get a private
        // copy; seq 1's view is untouched
        assert!(m.append_token(0).unwrap());
        assert_eq!(m.used_blocks(), Blocks::new(3));
        assert_eq!(m.seq_tokens(0), Tokens::new(6));
        assert_eq!(m.seq_tokens(1), Tokens::new(5));
        m.check_invariants().unwrap();
        assert!(
            !m.append_needs_block(0),
            "the private tail has room for in-place appends"
        );
        // seq 1 appends next: rc of the old shared tail is now 1, so
        // it owns it and appends in place
        assert!(!m.append_needs_block(1));
        assert!(m.append_token(1).unwrap());
        assert_eq!(m.used_blocks(), Blocks::new(3));
        m.check_invariants().unwrap();
        // releasing seq 1 must not free seq 0's blocks
        m.release(1);
        assert!(m.has_seq(0));
        m.check_invariants().unwrap();
        m.release(0);
        assert_eq!(m.used_blocks(), Blocks::ZERO);
        m.check_invariants().unwrap();
    }

    #[test]
    fn partial_tail_shares_only_at_exact_prompt_length() {
        // an allocation extending past the prompt (recompute
        // readmission reserving prompt + preserved progress) may share
        // the FULL-block prefix but never the partial tail: the tail
        // will hold non-prompt KV
        let g = KvGeometry { block_tokens: 4, ..geo(KvPrecision::Bf16) };
        let mut m = KvBlockManager::new(g, Blocks::new(16)).unwrap();
        let prompt = vec![1, 2, 3, 4, 5, 6];
        assert!(m.allocate_shared(0, Tokens::new(6), &prompt).is_some());
        // 6 prompt tokens + 2 preserved: tail block is private
        let g1 = m.allocate_shared(1, Tokens::new(8), &prompt).unwrap();
        assert_eq!(g1.shared_blocks, Blocks::new(1), "full block only");
        assert_eq!(g1.shared_tokens, Tokens::new(4));
        assert_eq!(g1.new_blocks, Blocks::new(1));
        m.check_invariants().unwrap();
    }

    #[test]
    fn freed_blocks_purge_their_registry_entries() {
        // ABA safety: once the group drains, its blocks recycle — a
        // new allocation with the same prompt must MISS (the KV is
        // gone) instead of sharing stale block ids
        let mut m = mk(KvPrecision::Bf16, 8);
        let prompt: Vec<i32> = (0..16).collect();
        assert!(m.allocate_shared(0, Tokens::new(16), &prompt).is_some());
        m.release(0);
        m.check_invariants().unwrap();
        let g = m.allocate_shared(1, Tokens::new(16), &prompt).unwrap();
        assert_eq!(
            g.shared_blocks,
            Blocks::ZERO,
            "a drained prefix must not be served from recycled blocks"
        );
        m.check_invariants().unwrap();
    }

    #[test]
    fn shared_admission_need_matches_what_allocation_takes() {
        let g = KvGeometry { block_tokens: 4, ..geo(KvPrecision::Bf16) };
        let mut m = KvBlockManager::new(g, Blocks::new(16)).unwrap();
        let prompt = vec![3, 4, 5, 6, 7];
        let (now, grown) =
            m.shared_admission_need(Tokens::new(5), &prompt);
        assert_eq!((now, grown), (Blocks::new(2), Blocks::new(2)));
        let free0 = m.free_blocks();
        assert!(m.allocate_shared(0, Tokens::new(5), &prompt).is_some());
        assert_eq!(
            free0.get().saturating_sub(m.free_blocks().get()),
            now.get()
        );
        // second member: everything shared, growth = 1 COW block
        let (now, grown) =
            m.shared_admission_need(Tokens::new(5), &prompt);
        assert_eq!((now, grown), (Blocks::ZERO, Blocks::new(1)));
        let free0 = m.free_blocks();
        assert!(m.allocate_shared(1, Tokens::new(5), &prompt).is_some());
        assert_eq!(free0, m.free_blocks(), "fully shared: no new blocks");
        assert!(m.append_token(1).unwrap());
        assert_eq!(
            free0.get().saturating_sub(m.free_blocks().get()),
            1,
            "the first append consumed exactly the reserved COW block"
        );
        m.check_invariants().unwrap();
    }
}
