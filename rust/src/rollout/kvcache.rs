//! Paged KV-cache block manager (the vLLM-style allocator).
//!
//! This is the substrate behind the paper's §2.3 performance analysis:
//! KV-cache *capacity* bounds concurrency, and when the active set's
//! context grows past capacity the scheduler must preempt sequences
//! (recompute-style eviction), wasting work. FP8 KV storage halves
//! bytes/token, doubling capacity — the mechanism behind the 38% gain.
//!
//! Used by both the real HLO-backed engine (tiny models) and the H100
//! cost-model simulator (8B/30B descriptors), so preemption dynamics in
//! the perf figures come from a real allocator, not a formula.

use std::collections::BTreeMap;

use crate::util::error::{bail, Result};
use crate::util::units::{Blocks, Bytes, Tokens};

/// Bytes per KV element for each storage precision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvPrecision {
    Bf16,
    Fp8,
}

impl KvPrecision {
    pub fn bytes_per_elem(self) -> usize {
        match self {
            KvPrecision::Bf16 => 2,
            KvPrecision::Fp8 => 1,
        }
    }
}

/// Static geometry of the cache.
#[derive(Clone, Copy, Debug)]
pub struct KvGeometry {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    /// tokens per block (vLLM default 16)
    pub block_tokens: usize,
    pub precision: KvPrecision,
}

impl KvGeometry {
    /// Bytes of K+V for one token across all layers.
    pub fn bytes_per_token(&self) -> Bytes {
        Bytes::new(
            2 * self.n_layers
                * self.n_kv_heads
                * self.d_head
                * self.precision.bytes_per_elem(),
        )
    }

    pub fn bytes_per_block(&self) -> Bytes {
        Bytes::new(self.bytes_per_token().get() * self.block_tokens)
    }

    /// How many blocks fit in a byte budget (the bytes -> blocks
    /// conversion point for rule U1).
    pub fn blocks_in(&self, budget: Bytes) -> Blocks {
        Blocks::new(budget.get() / self.bytes_per_block().get())
    }
}

#[derive(Debug)]
struct SeqAlloc {
    blocks: Vec<usize>,
    tokens: usize,
}

/// Block allocator with per-sequence block tables.
pub struct KvBlockManager {
    pub geometry: KvGeometry,
    total_blocks: usize,
    free: Vec<usize>,
    seqs: BTreeMap<u64, SeqAlloc>,
    /// counters for metrics
    pub alloc_failures: u64,
    pub peak_used: Blocks,
}

impl KvBlockManager {
    pub fn new(geometry: KvGeometry, total_blocks: Blocks) -> Self {
        let total_blocks = total_blocks.get();
        KvBlockManager {
            geometry,
            total_blocks,
            free: (0..total_blocks).rev().collect(),
            seqs: BTreeMap::new(),
            alloc_failures: 0,
            peak_used: Blocks::ZERO,
        }
    }

    pub fn from_budget(geometry: KvGeometry, budget: Bytes) -> Self {
        Self::new(geometry, geometry.blocks_in(budget))
    }

    pub fn total_blocks(&self) -> Blocks {
        Blocks::new(self.total_blocks)
    }

    pub fn used_blocks(&self) -> Blocks {
        // free blocks only ever come out of the initial pool, so the
        // free list can never exceed the total; saturate anyway rather
        // than letting a future accounting bug wrap to usize::MAX
        Blocks::new(self.total_blocks.saturating_sub(self.free.len()))
    }

    pub fn free_blocks(&self) -> Blocks {
        Blocks::new(self.free.len())
    }

    pub fn n_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn has_seq(&self, id: u64) -> bool {
        self.seqs.contains_key(&id)
    }

    pub fn seq_tokens(&self, id: u64) -> Tokens {
        Tokens::new(self.seqs.get(&id).map(|s| s.tokens).unwrap_or(0))
    }

    /// Blocks needed to hold `tokens` tokens (the tokens -> blocks
    /// conversion point for rule U1).
    pub fn blocks_for(&self, tokens: Tokens) -> Blocks {
        Blocks::new(tokens.get().div_ceil(self.geometry.block_tokens))
    }

    /// True when the sequence's allocation is exactly full — its next
    /// appended token will need a fresh block. The scheduler counts
    /// these into its admission growth reserve.
    pub fn at_block_boundary(&self, id: u64) -> bool {
        self.seqs.get(&id).is_some_and(|s| {
            s.tokens == s.blocks.len() * self.geometry.block_tokens
        })
    }

    /// Can a new sequence of `tokens` tokens be admitted right now?
    pub fn can_allocate(&self, tokens: Tokens) -> bool {
        self.blocks_for(tokens) <= Blocks::new(self.free.len())
    }

    /// Admit a sequence with an initial `tokens` tokens (prompt).
    /// Returns false (and counts a failure) if blocks are unavailable.
    /// A 0-token allocate is clamped to 1 token *consistently*: the
    /// old code sized the blocks from `tokens.max(1)` but stored the
    /// raw 0, leaving a 1-block sequence whose `seq_tokens()` /
    /// `at_block_boundary()` disagreed with its allocation — it never
    /// looked block-boundary-full, so it evaded the scheduler's
    /// admission growth reserve.
    pub fn allocate(&mut self, id: u64, tokens: Tokens) -> bool {
        assert!(!self.seqs.contains_key(&id), "seq {id} already allocated");
        let tokens = tokens.get().max(1);
        let need = self.blocks_for(Tokens::new(tokens)).get();
        if need > self.free.len() {
            self.alloc_failures += 1;
            return false;
        }
        let blocks = self.free.split_off(self.free.len() - need);
        self.seqs.insert(id, SeqAlloc { blocks, tokens });
        self.peak_used = self.peak_used.max(self.used_blocks());
        true
    }

    /// Extend a sequence by one token; may need a fresh block.
    /// Returns `Ok(false)` if the cache is out of blocks (preemption
    /// required), `Err` if the sequence is unknown (caller bug).
    pub fn append_token(&mut self, id: u64) -> Result<bool> {
        let block_tokens = self.geometry.block_tokens;
        let Some(s) = self.seqs.get_mut(&id) else {
            bail!("append_token on unknown seq {id}");
        };
        // capacity exactly filled -> next token needs a fresh block
        if s.tokens == s.blocks.len() * block_tokens {
            let Some(b) = self.free.pop() else {
                self.alloc_failures += 1;
                return Ok(false);
            };
            s.blocks.push(b);
        }
        s.tokens = s.tokens.saturating_add(1);
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(true)
    }

    /// Release a sequence entirely (finished or preempted-with-recompute).
    pub fn release(&mut self, id: u64) {
        if let Some(s) = self.seqs.remove(&id) {
            self.free.extend(s.blocks);
        }
    }

    /// Fraction of capacity in use.
    pub fn utilization(&self) -> f64 {
        self.used_blocks().get() as f64 / self.total_blocks.max(1) as f64
    }

    /// Invariant check (used by property tests): no block is both free
    /// and allocated, and block counts add up.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.total_blocks];
        for &b in &self.free {
            let Some(slot) = seen.get_mut(b) else {
                return Err(format!("free block {b} out of range"));
            };
            if *slot {
                return Err(format!("block {b} double-listed in free"));
            }
            *slot = true;
        }
        for (id, s) in &self.seqs {
            // every live allocation accounts for at least one token —
            // a 0-token sequence would hold blocks its own accessors
            // (`seq_tokens`, `at_block_boundary`) don't account for
            if s.tokens == 0 {
                return Err(format!(
                    "seq {id}: 0 tokens recorded for {} allocated \
                     block(s)",
                    s.blocks.len()
                ));
            }
            let max_tokens = s.blocks.len() * self.geometry.block_tokens;
            if s.tokens > max_tokens {
                return Err(format!(
                    "seq {id}: {} tokens in {} blocks",
                    s.tokens,
                    s.blocks.len()
                ));
            }
            // blocks must be enough but not wasteful (<= 1 spare block)
            if s.tokens + self.geometry.block_tokens
                < s.blocks.len() * self.geometry.block_tokens
            {
                return Err(format!("seq {id}: over-allocated"));
            }
            for &b in &s.blocks {
                let Some(slot) = seen.get_mut(b) else {
                    return Err(format!("seq block {b} out of range"));
                };
                if *slot {
                    return Err(format!("block {b} allocated twice"));
                }
                *slot = true;
            }
        }
        if !seen.iter().all(|&x| x) {
            return Err("leaked blocks (neither free nor allocated)".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo(prec: KvPrecision) -> KvGeometry {
        KvGeometry {
            n_layers: 4,
            n_kv_heads: 2,
            d_head: 32,
            block_tokens: 16,
            precision: prec,
        }
    }

    #[test]
    fn bytes_accounting() {
        let g = geo(KvPrecision::Bf16);
        assert_eq!(g.bytes_per_token(), Bytes::new(2 * 4 * 2 * 32 * 2));
        let g8 = geo(KvPrecision::Fp8);
        assert_eq!(g8.bytes_per_token().get() * 2, g.bytes_per_token().get());
    }

    #[test]
    fn fp8_doubles_capacity() {
        let budget = Bytes::new(1 << 20);
        let bf = KvBlockManager::from_budget(geo(KvPrecision::Bf16), budget);
        let f8 = KvBlockManager::from_budget(geo(KvPrecision::Fp8), budget);
        assert_eq!(f8.total_blocks().get(), 2 * bf.total_blocks().get());
    }

    #[test]
    fn alloc_extend_release() {
        let mut m = KvBlockManager::new(geo(KvPrecision::Bf16), Blocks::new(8));
        assert!(m.allocate(1, Tokens::new(16))); // exactly 1 block
        assert_eq!(m.used_blocks(), Blocks::new(1));
        // 16 more tokens => one more block
        for _ in 0..16 {
            assert!(m.append_token(1).unwrap());
        }
        assert_eq!(m.used_blocks(), Blocks::new(2));
        m.check_invariants().unwrap();
        m.release(1);
        assert_eq!(m.used_blocks(), Blocks::ZERO);
        assert_eq!(m.peak_used, Blocks::new(2));
        m.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_counts_failures() {
        let mut m = KvBlockManager::new(geo(KvPrecision::Bf16), Blocks::new(2));
        assert!(m.allocate(1, Tokens::new(32))); // both blocks
        assert!(!m.allocate(2, Tokens::new(1)));
        assert_eq!(m.alloc_failures, 1);
        assert!(!m.append_token(1).unwrap());
        assert_eq!(m.alloc_failures, 2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn zero_token_allocate_is_clamped_consistently() {
        // regression: allocate(id, 0) used to size its blocks from
        // max(1) but record 0 tokens, so the sequence's accounting
        // disagreed with its allocation (and `at_block_boundary` could
        // never fire, dodging the scheduler's growth reserve)
        let mut m =
            KvBlockManager::new(geo(KvPrecision::Bf16), Blocks::new(4));
        assert!(m.allocate(1, Tokens::ZERO));
        assert_eq!(
            m.seq_tokens(1),
            Tokens::new(1),
            "clamped token count is stored"
        );
        assert_eq!(m.used_blocks(), Blocks::new(1));
        assert!(!m.at_block_boundary(1));
        m.check_invariants().unwrap();
        // growth proceeds from the clamped count: 15 more appends fill
        // the first block exactly, making the boundary visible
        for _ in 0..15 {
            assert!(m.append_token(1).unwrap());
        }
        assert_eq!(m.seq_tokens(1), Tokens::new(16));
        assert!(m.at_block_boundary(1), "boundary must be observable");
        assert_eq!(m.used_blocks(), Blocks::new(1));
        assert!(m.append_token(1).unwrap());
        assert_eq!(m.used_blocks(), Blocks::new(2));
        m.check_invariants().unwrap();
        m.release(1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn release_unknown_is_noop() {
        let mut m = KvBlockManager::new(geo(KvPrecision::Fp8), Blocks::new(4));
        m.release(99);
        m.check_invariants().unwrap();
    }
}
