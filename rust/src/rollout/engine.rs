//! The generation engine: continuous batching over the decode
//! entrypoint (RefBackend or PJRT — see runtime/backend.rs), with the
//! paged-KV scheduler, per-slot sampling and rollout-policy logprob
//! capture.
//!
//! Slot model: the decode artifact has a fixed batch of `B` slots. Each
//! slot hosts one running sequence at its own position. New sequences are
//! admitted into free slots and *prefilled through the decode path*
//! (prompt tokens teacher-forced one per step — chunked-prefill style),
//! so prefill and decode mix in the same batch exactly like a
//! continuous-batching server. A whole-batch fast path uses the prefill
//! artifact when the engine starts empty (the common RL-rollout shape).
//!
//! Streaming entry points: [`HloEngine::enqueue`] queues a request
//! without running, [`HloEngine::step`] runs ONE admission + decode
//! round (admitting queued work into free slots mid-decode), and
//! [`HloEngine::cancel`] aborts a queued/running request. `generate`
//! is now just enqueue-all + step-to-drain, so the batch and streaming
//! paths share one scheduler loop — and the chunked-prefill/wave
//! bit-exactness means outputs do not depend on WHEN a request was
//! admitted, only on (engine seed, request id, prompt, weight epoch).
//!
//! Every completed request is tagged with the engine's *weight epoch*
//! (bumped by each successful `install_weights` / `install_kv_scales`),
//! so the trainer can verify which behavior policy a completion's
//! logprobs were measured under (the pool's epoch fence guarantees no
//! completion spans an install).
//!
//! Weights are persistent device buffers; the per-step KV state rides
//! through each execution. The engine's weights are the *quantized* ones
//! installed by the weight-sync pipeline (sync/), so sampled-token
//! logprobs measured here are exactly pi_fp8 from paper eq. (2).

use std::sync::Arc;

use crate::fp8::ScaleSet;
use crate::runtime::{DeviceBuffer, Executable, HostArray, Runtime};
use crate::util::error::{bail, Context, Result};
use crate::util::rng::Pcg64;
use crate::util::units::{Blocks, Bytes, ScaleEpoch, Tokens};

use super::kvcache::{KvBlockManager, KvGeometry, KvPrecision};
use super::request::{Completion, FinishReason, Request};
use super::sampler;
use super::scheduler::Scheduler;

/// Engine configuration: which artifact variant backs generation and how
/// much KV memory the scheduler may use.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub arch: String,      // "dense" | "moe"
    pub variant: String,   // rollout variant name (bf16, fp8lin, ...)
    /// KV storage precision (affects capacity accounting; numerics are
    /// baked into the artifact variant)
    pub kv_precision: KvPrecision,
    /// KV byte budget for the block manager; None = exactly the dense
    /// cache the artifact carries (no artificial pressure)
    pub kv_budget_bytes: Option<Bytes>,
    pub block_tokens: usize,
    pub seed: u64,
    /// Shared-prefix KV reuse (DESIGN.md §10): admission shares prompt
    /// blocks copy-on-write, and the engine skips prefill work for a
    /// newly admitted request whose prompt prefix is already resident
    /// in a device KV row. A pure memory/FLOPs optimization — outputs
    /// are bit-identical with it on or off.
    pub prefix_sharing: bool,
}

impl EngineConfig {
    pub fn new(arch: &str, variant: &str) -> Self {
        let kv_precision = if variant.contains("kvfp8")
            || variant.contains("fullfp8")
        {
            KvPrecision::Fp8
        } else {
            KvPrecision::Bf16
        };
        EngineConfig {
            arch: arch.to_string(),
            variant: variant.to_string(),
            kv_precision,
            kv_budget_bytes: None,
            block_tokens: 16,
            seed: 1234,
            prefix_sharing: false,
        }
    }
}

/// What a device KV row currently holds: the token prefix whose KV is
/// resident there, and the weight epoch it was computed under (KV from
/// an older epoch is NOT reusable — a weight/scale install changes the
/// cache numerics, so aliasing stale rows would break bit-identity
/// with a from-scratch prefill).
struct RowPrefix {
    tokens: Vec<i32>,
    epoch: u64,
}

struct Slot {
    req: Request,
    /// tokens written to the KV cache so far (== current position)
    pos: usize,
    /// next prompt token to feed (prefill-through-decode cursor)
    cursor: usize,
    /// token to feed this step (last sampled, once prompt is exhausted)
    next_feed: i32,
    generated: Vec<i32>,
    /// behavior-policy logprobs (truncated+renormalized — pi_fp8)
    logprobs: Vec<f32>,
    /// full-vocab temperature-1 logprobs (trainer convention)
    logprobs_full: Vec<f32>,
    /// the request's PRIVATE sampling stream, derived purely from
    /// (engine seed, request id): samples do not depend on batch
    /// composition, replica assignment, or recompute preemption — the
    /// invariant the engine pool's bit-identical merge rests on
    rng: Pcg64,
}

/// Aggregate counters the experiments read.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub decode_steps: u64,
    pub prefill_waves: u64,
    /// tokens DELIVERED in completions (recompute-preemption discards
    /// are subtracted back out — see `tokens_discarded`)
    pub tokens_generated: u64,
    /// sampled tokens later thrown away by recompute preemption or an
    /// aborted `generate` — they are re-generated after readmission,
    /// so counting them as generated would inflate throughput
    pub tokens_discarded: u64,
    pub preemptions: u64,
    /// host<->device bytes the engine moved across the runtime
    /// boundary (uploads + logit downloads); with device-resident KV
    /// threading this is O(B·V) per decode step, independent of the
    /// cache size
    pub host_bytes_moved: u64,
    /// host<->device bytes moved during the most recent decode step
    /// of the current `generate` call (0 until its first decode step)
    pub host_bytes_last_step: u64,
    /// prompt tokens whose prefill compute was skipped by aliasing an
    /// already-resident shared-prefix KV row (prefix sharing only)
    pub prefill_tokens_saved: u64,
    /// prompt-KV bytes served by sharing already-resident blocks
    /// instead of storing a private copy (block-manager accounting)
    pub kv_bytes_shared: u64,
}

impl EngineStats {
    /// Merge another engine's counters into this one (the pool's
    /// aggregate view across replicas).
    pub fn merge(&mut self, o: &EngineStats) {
        self.decode_steps += o.decode_steps;
        self.prefill_waves += o.prefill_waves;
        self.tokens_generated += o.tokens_generated;
        self.tokens_discarded += o.tokens_discarded;
        self.preemptions += o.preemptions;
        self.host_bytes_moved += o.host_bytes_moved;
        self.host_bytes_last_step += o.host_bytes_last_step;
        self.prefill_tokens_saved += o.prefill_tokens_saved;
        self.kv_bytes_shared += o.kv_bytes_shared;
    }

    /// Move `n` sampled-but-undelivered tokens from `tokens_generated`
    /// to `tokens_discarded` (recompute preemption, an aborted
    /// `generate`, or a pool-level all-or-nothing failure dropping this
    /// replica's delivered completions).
    pub(crate) fn discard_tokens(&mut self, n: u64) {
        self.tokens_generated = self.tokens_generated.saturating_sub(n);
        self.tokens_discarded += n;
    }
}

/// Upload `a` into an existing device buffer when the backend supports
/// in-place writes, else replace it with a fresh upload; counts the
/// host->device traffic either way.
fn upload_into(
    rt: &Runtime,
    stats: &mut EngineStats,
    buf: &mut DeviceBuffer,
    a: &HostArray,
) -> Result<()> {
    stats.host_bytes_moved += a.nbytes() as u64;
    if !buf.write_from_host(a)? {
        *buf = rt.to_device(a)?;
    }
    Ok(())
}

/// Download a device buffer, counting the device->host traffic.
fn download(
    stats: &mut EngineStats,
    b: &DeviceBuffer,
) -> Result<HostArray> {
    let a = b.to_host()?;
    stats.host_bytes_moved += a.nbytes() as u64;
    Ok(a)
}

/// Apply `(src, dst, len)` element-range copies to a device buffer:
/// device-side when the backend supports it (RefBackend — zero host
/// traffic), else via a download / copy / re-upload round trip (the
/// traffic is counted like any other host crossing).
fn copy_ranges_in(
    rt: &Runtime,
    stats: &mut EngineStats,
    buf: &mut DeviceBuffer,
    ranges: &[(usize, usize, usize)],
) -> Result<()> {
    if buf.copy_within_ranges(ranges)? {
        return Ok(());
    }
    let mut a = download(stats, buf)?;
    {
        let data = a.as_f32_mut()?;
        for &(src, dst, len) in ranges {
            let (Some(src_end), Some(dst_end)) =
                (src.checked_add(len), dst.checked_add(len))
            else {
                bail!("kv row copy: range overflow");
            };
            if src_end > data.len() || dst_end > data.len() {
                bail!(
                    "kv row copy: range out of bounds ({src}+{len} / \
                     {dst}+{len} of {})",
                    data.len()
                );
            }
            data.copy_within(src..src_end, dst);
        }
    }
    upload_into(rt, stats, buf, &a)
}

pub struct HloEngine {
    rt: Arc<Runtime>,
    cfg: EngineConfig,
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    param_bufs: Vec<DeviceBuffer>,
    /// dense KV cache state threaded through decode calls — DEVICE
    /// resident: the full cache never crosses the host boundary on the
    /// hot path (the RefBackend mutates it in place; PJRT degrades to
    /// the run+re-upload fallback)
    kc: DeviceBuffer,
    vc: DeviceBuffer,
    /// pre-sized reusable per-step input buffers (tokens, positions,
    /// k/v scales) — recycled via `write_from_host` where supported
    tok_buf: DeviceBuffer,
    pos_buf: DeviceBuffer,
    ks_buf: DeviceBuffer,
    vs_buf: DeviceBuffer,
    /// epoch-stamped K/V dequant scales (rule Q2: installed only via
    /// `install_kv_scales`, read back through the `ScaleSet` handle)
    scales: ScaleSet,
    /// true when the scales changed since ks_buf/vs_buf were staged
    scales_dirty: bool,
    slots: Vec<Option<Slot>>,
    /// per device-KV-row: which token prefix is resident there (and
    /// under which weight epoch) — the lookup table behind the
    /// shared-prefix prefill-skip path (`find_resident_prefix_row`)
    row_prefix: Vec<Option<RowPrefix>>,
    /// `sched.kv.shared_block_hits` high-water mark already folded
    /// into `stats.kv_bytes_shared`
    kv_shared_blocks_seen: u64,
    sched: Scheduler,
    preempt_counts: std::collections::BTreeMap<u64, u32>,
    /// bumped by every successful weight / KV-scale install; stamps
    /// every completion (see the module docs)
    weight_epoch: u64,
    pub stats: EngineStats,
    // geometry
    b: usize,
    max_seq: usize,
    prompt_len: usize,
    vocab: usize,
}

impl HloEngine {
    pub fn new(rt: Arc<Runtime>, cfg: EngineConfig) -> Result<HloEngine> {
        let m = rt.manifest.model(&cfg.arch)?.clone();
        let c = rt.manifest.constants.clone();
        let prefill =
            rt.load(&format!("{}_prefill_{}", cfg.arch, cfg.variant))?;
        let decode =
            rt.load(&format!("{}_decode_{}", cfg.arch, cfg.variant))?;
        let b = c.b_rollout;
        let max_seq = m.cfg("max_seq");
        let geo = KvGeometry {
            n_layers: m.cfg("n_layers"),
            n_kv_heads: m.cfg("n_kv_heads"),
            d_head: m.cfg("d_head"),
            block_tokens: cfg.block_tokens,
            precision: cfg.kv_precision,
        };
        let kv = match cfg.kv_budget_bytes {
            Some(budget) => KvBlockManager::from_budget(geo, budget)?,
            None => {
                // capacity == the dense cache the artifact carries
                KvBlockManager::new(
                    geo,
                    Blocks::new(b * max_seq / cfg.block_tokens),
                )?
            }
        };
        let mut sched = Scheduler::new(kv, b);
        sched.set_prefix_sharing(cfg.prefix_sharing);
        let kv_shape = vec![
            geo.n_layers,
            b,
            geo.n_kv_heads,
            max_seq,
            geo.d_head,
        ];
        let n: usize = kv_shape.iter().product();
        let kc = rt
            .to_device(&HostArray::f32(kv_shape.clone(), vec![0.0; n]))?;
        let vc = rt.to_device(&HostArray::f32(kv_shape, vec![0.0; n]))?;
        let tok_buf =
            rt.to_device(&HostArray::i32(vec![b, 1], vec![0; b]))?;
        let pos_buf =
            rt.to_device(&HostArray::i32(vec![b, 1], vec![0; b]))?;
        let ks_buf = rt.to_device(&HostArray::scalar_f32(1.0))?;
        let vs_buf = rt.to_device(&HostArray::scalar_f32(1.0))?;
        // initial weights: the aot dump; weight-sync replaces them
        let init = rt.manifest.load_initial_params(&cfg.arch)?;
        let params: Vec<HostArray> = init
            .into_iter()
            .zip(&m.params)
            .map(|(v, p)| HostArray::f32(p.shape.clone(), v))
            .collect();
        let param_bufs = rt.to_device_all(&params)?;
        Ok(HloEngine {
            rt,
            cfg,
            prefill,
            decode,
            param_bufs,
            kc,
            vc,
            tok_buf,
            pos_buf,
            ks_buf,
            vs_buf,
            scales: ScaleSet::identity(),
            scales_dirty: false,
            slots: (0..b).map(|_| None).collect(),
            row_prefix: (0..b).map(|_| None).collect(),
            kv_shared_blocks_seen: 0,
            sched,
            preempt_counts: std::collections::BTreeMap::new(),
            weight_epoch: 0,
            stats: EngineStats::default(),
            b,
            max_seq,
            prompt_len: c.prompt_len,
            vocab: m.cfg("vocab"),
        })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Install freshly synchronized weights (called by the weight-sync
    /// pipeline at every RL step — paper Fig 1 "weight synchronization
    /// phase"). The persistent device buffers are reused in place when
    /// the backend supports it: the upload is O(params) per sync either
    /// way, but no new device allocations are made.
    pub fn install_weights(&mut self, params: &[HostArray]) -> Result<()> {
        if self.param_bufs.len() != params.len() {
            for a in params {
                self.stats.host_bytes_moved += a.nbytes() as u64;
            }
            self.param_bufs = self.rt.to_device_all(params)?;
            self.weight_epoch += 1;
            self.scales = self
                .scales
                .restamped(ScaleEpoch::new(self.weight_epoch));
            return Ok(());
        }
        for (buf, a) in self.param_bufs.iter_mut().zip(params) {
            upload_into(&self.rt, &mut self.stats, buf, a)?;
        }
        // bumped only on SUCCESS: a failed install leaves the epoch
        // behind, which the pool's submit-time epoch check turns into a
        // loud per-request failure instead of silently mis-tagging
        self.weight_epoch += 1;
        // the scales themselves did not change; carrying them across
        // the weight bump is deliberate (recalibration is out of band),
        // so restamp the handle at the new epoch
        self.scales = self
            .scales
            .restamped(ScaleEpoch::new(self.weight_epoch));
        Ok(())
    }

    /// Install recalibrated QKV scales (paper §2.3.1). The device
    /// copies are refreshed lazily on the next prefill/decode. Bumps
    /// the weight epoch: the behavior policy's numerics changed.
    pub fn install_kv_scales(&mut self, kscale: f32, vscale: f32) {
        self.weight_epoch += 1;
        self.scales =
            ScaleSet::new(kscale, vscale, ScaleEpoch::new(self.weight_epoch));
        self.scales_dirty = true;
    }

    /// The current weight epoch (see the module docs): number of
    /// successful weight / KV-scale installs so far. Every completion
    /// is stamped with the epoch it was generated under.
    pub fn weight_epoch(&self) -> u64 {
        self.weight_epoch
    }

    /// Re-stage the k/v scale device buffers if the scales changed.
    /// The freshness check in [`ScaleSet::read`] asserts (debug) that
    /// the handle was stamped at the current weight epoch.
    fn refresh_scales(&mut self) -> Result<()> {
        if !self.scales_dirty {
            return Ok(());
        }
        let (k, v) = self.scales.read(ScaleEpoch::new(self.weight_epoch));
        upload_into(
            &self.rt,
            &mut self.stats,
            &mut self.ks_buf,
            &HostArray::scalar_f32(k),
        )?;
        upload_into(
            &self.rt,
            &mut self.stats,
            &mut self.vs_buf,
            &HostArray::scalar_f32(v),
        )?;
        self.scales_dirty = false;
        Ok(())
    }

    pub fn kv_scales(&self) -> (f32, f32) {
        self.scales.read(ScaleEpoch::new(self.weight_epoch))
    }

    /// The engine's current epoch-stamped scale handle. A caller that
    /// holds on to it across an install and reads it again trips the
    /// staleness assert — see `tests/fp8_roundtrip.rs`.
    pub fn scale_set(&self) -> ScaleSet {
        self.scales
    }

    /// Generate completions for a batch of requests (runs to drain).
    /// On error every submitted request — running or still queued — is
    /// dropped, so the next `generate` starts from a clean scheduler
    /// (a failed call must not leak ghost requests into later calls).
    pub fn generate(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<Vec<Completion>> {
        self.stats.host_bytes_last_step = 0; // per-call semantics
        let mut done: Vec<Completion> = Vec::new();
        match self.generate_inner(requests, &mut done) {
            Ok(()) => Ok(done),
            Err(e) => {
                // completions finished before the failure are dropped
                // with it — their tokens were never delivered either
                for c in &done {
                    self.stats.discard_tokens(c.tokens.len() as u64);
                }
                self.abort_in_flight();
                Err(e)
            }
        }
    }

    /// Drop all queued and running work, counting sampled-but-
    /// undelivered tokens as discarded. Callers of [`HloEngine::step`]
    /// MUST invoke this after a step error (exactly what `generate`'s
    /// internal error path does) so the next round starts from a clean
    /// scheduler.
    pub fn abort_in_flight(&mut self) {
        for s in self.slots.iter_mut() {
            if let Some(slot) = s.take() {
                self.stats
                    .discard_tokens(slot.generated.len() as u64);
            }
        }
        self.sched.drain();
        self.preempt_counts.clear();
    }

    /// Queue one request without running anything — the streaming
    /// admission entry point ([`step`](HloEngine::step) picks it up
    /// between decode rounds, mid-flight work and all). Rejects at the
    /// door both malformed prompts and prompts that could never be
    /// admitted even with the whole KV cache free, so a queued request
    /// is guaranteed to eventually reach a slot.
    pub fn enqueue(&mut self, req: Request) -> Result<()> {
        if req.prompt.is_empty() || req.prompt.len() > self.prompt_len {
            bail!(
                "prompt length {} outside 1..={}",
                req.prompt.len(),
                self.prompt_len
            );
        }
        let need =
            self.sched.kv.blocks_for(Tokens::new(req.prompt.len() + 1));
        if need > self.sched.kv.total_blocks() {
            bail!(
                "request {} can never be admitted — its {}-token prompt \
                 (+1 growth reserve) needs {} KV blocks but the cache \
                 has only {} blocks total",
                req.id,
                req.prompt.len(),
                need,
                self.sched.kv.total_blocks()
            );
        }
        self.sched.submit(req);
        Ok(())
    }

    /// True when the engine owes no completions (nothing queued or
    /// running). The streaming worker blocks for new work when idle.
    pub fn is_idle(&self) -> bool {
        self.sched.is_idle()
    }

    /// Every request id still queued or running (what a streaming
    /// caller must fail/settle when a step errors out).
    pub fn outstanding_ids(&self) -> Vec<u64> {
        self.sched.outstanding_ids()
    }

    /// Abort one queued or running request (the streaming cancel
    /// path): its sampled-but-undelivered tokens count as discarded.
    /// Returns `false` when the engine no longer knows the id (it
    /// already completed).
    pub fn cancel(&mut self, id: u64) -> bool {
        for s in self.slots.iter_mut() {
            if s.as_ref().map(|x| x.req.id) == Some(id) {
                if let Some(x) = s.take() {
                    self.stats
                        .discard_tokens(x.generated.len() as u64);
                }
            }
        }
        self.preempt_counts.remove(&id);
        self.sched.cancel(id)
    }

    /// One scheduling round: admit queued work (a batched prefill wave
    /// when the engine is empty, mid-decode slot injection otherwise)
    /// and advance every running sequence one token. A no-op when
    /// idle; finished requests are appended to `done` in completion
    /// order (NOT id-sorted — streaming callers ship them as they
    /// come). On `Err` the caller must call
    /// [`abort_in_flight`](HloEngine::abort_in_flight).
    pub fn step(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        if self.sched.is_idle() {
            return Ok(());
        }
        if self.slots.iter().all(|s| s.is_none()) {
            // nothing running => every KV block is free, so this can
            // only admit nothing if the head-of-line request can never
            // fit — which `enqueue` rejects up front. Defensive bail so
            // a violated invariant can't spin the caller forever.
            let admitted = self.prefill_wave(done)?;
            if admitted == 0 && !self.sched.is_idle() {
                let Some(head) = self.sched.head_of_line() else {
                    bail!("stalled scheduler with an empty queue");
                };
                bail!(
                    "engine stalled: request {} can never be admitted — \
                     its {}-token prompt (+1 growth reserve) needs {} KV \
                     blocks but the cache has only {} blocks total",
                    head.id,
                    head.prompt.len(),
                    self.sched.kv.blocks_for(Tokens::new(
                        head.prompt.len() + 1
                    )),
                    self.sched.kv.total_blocks()
                );
            }
            return Ok(());
        }
        // occupied slots == running sequences, so admission can rely on
        // the block-boundary growth reserve and decode always has work
        self.admit_into_slots()?;
        self.decode_step(done)
    }

    fn generate_inner(
        &mut self,
        requests: Vec<Request>,
        done: &mut Vec<Completion>,
    ) -> Result<()> {
        for r in requests {
            self.enqueue(r)?;
        }
        let mut guard = 0usize;
        while !self.sched.is_idle() {
            self.step(done)?;
            guard += 1;
            if guard > 200_000 {
                bail!("engine livelock: {} running", self.sched.n_running());
            }
        }
        // stable output order by request id
        done.sort_by_key(|c| c.id);
        Ok(())
    }

    /// The request's private sampling stream (see `Slot::rng`). Re-
    /// derived from scratch on recompute readmission, so a preempted
    /// request regenerates exactly the tokens it lost.
    fn slot_rng(&self, req_id: u64) -> Pcg64 {
        Pcg64::new(sampler::request_seed(self.cfg.seed, req_id))
    }

    /// Fold newly shared block-manager hits into `kv_bytes_shared`
    /// (called after every admission round; a no-op with sharing off).
    fn note_shared_blocks(&mut self) {
        let hits = self.sched.kv.shared_block_hits;
        let delta = hits.saturating_sub(self.kv_shared_blocks_seen);
        self.kv_shared_blocks_seen = hits;
        let per_block =
            self.sched.kv.geometry.bytes_per_block().get() as u64;
        self.stats.kv_bytes_shared = self
            .stats
            .kv_bytes_shared
            .saturating_add(delta.saturating_mul(per_block));
    }

    /// A device KV row whose resident prefix covers this prompt's
    /// first `plen-1` tokens under the CURRENT weight epoch. Those are
    /// exactly the positions a full chunked prefill would write before
    /// the request samples its first token, so aliasing the row lets
    /// admission fast-forward past the whole teacher-forced replay.
    fn find_resident_prefix_row(&self, prompt: &[i32]) -> Option<usize> {
        let need = prompt.len().checked_sub(1)?;
        if need == 0 {
            return None; // nothing to skip for a 1-token prompt
        }
        self.row_prefix.iter().position(|rp| {
            rp.as_ref().is_some_and(|rp| {
                rp.epoch == self.weight_epoch
                    && rp.tokens.len() >= need
                    && rp.tokens.get(..need) == prompt.get(..need)
            })
        })
    }

    /// Copy device KV row `src` onto row `dst` in both caches. The
    /// dense layout is [n_layers, b, n_kv_heads, max_seq, d_head], so
    /// each layer contributes one contiguous per-row chunk. Copying
    /// the FULL row is safe: positions at or beyond the shared prefix
    /// hold junk that the causal mask keeps unattended until decode
    /// overwrites them — the same argument the prefill wave's pad
    /// positions rely on.
    fn copy_kv_row(&mut self, src: usize, dst: usize) -> Result<()> {
        let geo = &self.sched.kv.geometry;
        let chunk = geo.n_kv_heads * self.max_seq * geo.d_head;
        let ranges: Vec<(usize, usize, usize)> = (0..geo.n_layers)
            .map(|l| {
                ((l * self.b + src) * chunk, (l * self.b + dst) * chunk, chunk)
            })
            .collect();
        copy_ranges_in(&self.rt, &mut self.stats, &mut self.kc, &ranges)?;
        copy_ranges_in(&self.rt, &mut self.stats, &mut self.vc, &ranges)
    }

    /// Admit waiting requests into free slots. With prefix sharing on,
    /// a request whose prompt prefix is already resident in a device
    /// KV row skips the teacher-forced prompt replay: the row is
    /// aliased (copied device-side) and the slot starts at the last
    /// prompt token. Bit-exact vs the replay path: KV content per
    /// position is a pure function of (token prefix, weights, scales),
    /// prompt replay never consumes sampler RNG, and the first sampled
    /// token comes from the same position either way.
    fn admit_into_slots(&mut self) -> Result<()> {
        let admitted = self.sched.admit();
        self.note_shared_blocks();
        for req in admitted {
            let rng = self.slot_rng(req.id);
            let plen = req.prompt.len();
            let Some(i) = self.slots.iter().position(|s| s.is_none())
            else {
                bail!("scheduler admitted beyond slot capacity");
            };
            let mut start = 0usize;
            if self.cfg.prefix_sharing && plen >= 2 {
                if let Some(src) =
                    self.find_resident_prefix_row(&req.prompt)
                {
                    if src != i {
                        self.copy_kv_row(src, i)?;
                    }
                    start = plen - 1;
                    self.stats.prefill_tokens_saved += start as u64;
                }
            }
            let feed = *req
                .prompt
                .get(start)
                .context("admitted request has an empty prompt")?;
            if let Some(rp) = self.row_prefix.get_mut(i) {
                *rp = Some(RowPrefix {
                    tokens: req
                        .prompt
                        .get(..start)
                        .unwrap_or(&[])
                        .to_vec(),
                    epoch: self.weight_epoch,
                });
            }
            let Some(slot) = self.slots.get_mut(i) else {
                bail!("slot index out of range");
            };
            *slot = Some(Slot {
                next_feed: feed,
                cursor: start + 1,
                pos: start,
                generated: Vec::new(),
                logprobs: Vec::new(),
                logprobs_full: Vec::new(),
                rng,
                req,
            });
        }
        Ok(())
    }

    /// Whole-batch prefill fast path (engine must be empty). Returns
    /// how many requests were admitted into the wave.
    fn prefill_wave(
        &mut self,
        done: &mut Vec<Completion>,
    ) -> Result<usize> {
        let admitted = self.sched.admit();
        self.note_shared_blocks();
        if admitted.is_empty() {
            return Ok(0);
        }
        self.stats.prefill_waves += 1;
        let mut tokens = vec![0i32; self.b * self.prompt_len];
        for (row, req) in
            tokens.chunks_mut(self.prompt_len).zip(admitted.iter())
        {
            let last = *req
                .prompt
                .last()
                .context("admitted request has an empty prompt")?;
            // pad by repeating the last prompt token (never attended)
            let fill =
                req.prompt.iter().chain(std::iter::repeat(&last));
            for (dst, &t) in row.iter_mut().zip(fill) {
                *dst = t;
            }
        }
        self.refresh_scales()?;
        let tok = HostArray::i32(vec![self.b, self.prompt_len], tokens);
        self.stats.host_bytes_moved += tok.nbytes() as u64;
        let tok_buf = self.rt.to_device(&tok)?;
        let mut out = {
            let mut all: Vec<&DeviceBuffer> =
                self.param_bufs.iter().collect();
            all.push(&tok_buf);
            all.push(&self.ks_buf);
            all.push(&self.vs_buf);
            self.prefill.run_to_device(&all)?
        };
        if out.len() != 3 {
            bail!("prefill returned {} outputs, want 3", out.len());
        }
        // the caches stay device-resident; only the logits come back
        let mut it = out.into_iter();
        let logits_buf =
            it.next().context("prefill: missing logits output")?;
        let kc = it.next().context("prefill: missing k-cache")?;
        let vc = it.next().context("prefill: missing v-cache")?;
        let logits = download(&mut self.stats, &logits_buf)?;
        self.kc = kc;
        self.vc = vc;
        // the wave replaced both cache buffers wholesale: whatever the
        // old rows held is gone, so the resident-prefix registry starts
        // over from this wave's rows
        for rp in self.row_prefix.iter_mut() {
            *rp = None;
        }
        // install slots; prompt tokens 0..plen-1 are already in cache;
        // the scheduler allocated plen tokens. sample the first response
        // token from logits[:, plen-1].
        let lg = logits.as_f32()?;
        let n_admitted = admitted.len();
        for (i, req) in admitted.into_iter().enumerate() {
            let plen = req.prompt.len();
            // row i now holds this prompt's full KV (positions
            // 0..plen-1), usable as a shared-prefix source until the
            // row is clobbered or the weight epoch moves
            if let Some(rp) = self.row_prefix.get_mut(i) {
                *rp = Some(RowPrefix {
                    tokens: req.prompt.clone(),
                    epoch: self.weight_epoch,
                });
            }
            let base = (i * self.prompt_len + plen - 1) * self.vocab;
            let row = lg
                .get(base..base + self.vocab)
                .context("prefill logits row out of range")?;
            let mut rng = self.slot_rng(req.id);
            let s = sampler::sample(row, &req.params, &mut rng)?;
            let mut slot = Slot {
                next_feed: s.token,
                cursor: plen, // prompt fully consumed
                pos: plen,
                generated: vec![s.token],
                logprobs: vec![s.logprob],
                logprobs_full: vec![s.logprob_full],
                rng,
                req,
            };
            // prefill wrote positions 0..plen-1; positions beyond plen-1
            // hold pad junk that is never attended (causal mask) and is
            // overwritten as decoding proceeds.
            self.stats.tokens_generated += 1;
            if self.maybe_finish(&mut slot, s.token, done) {
                continue;
            }
            // the prefill artifact put sequence i's KV in cache row i,
            // so the slot index MUST be i
            let dst = self
                .slots
                .get_mut(i)
                .context("prefill wave exceeds slot capacity")?;
            debug_assert!(dst.is_none());
            *dst = Some(slot);
        }
        Ok(n_admitted)
    }

    /// One decode step over all active slots. The KV cache stays
    /// device-resident end to end: the only host traffic is the (B,1)
    /// token/position uploads and the (B,V) logits download.
    fn decode_step(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        if self.slots.iter().all(|s| s.is_none()) {
            return Ok(());
        }
        self.stats.decode_steps += 1;
        let bytes0 = self.stats.host_bytes_moved;
        // the decode artifact executes ALL b rows every step: an empty
        // slot feeds token 0 at position 0, clobbering position 0 of
        // its row — so whatever prefix was resident there is invalid
        // the moment this step runs. (A row freed THIS step stays
        // aliasable until the next decode, and `step` admits before
        // decoding, so a group member can still alias a just-freed
        // sibling row.)
        for (rp, s) in self.row_prefix.iter_mut().zip(self.slots.iter())
        {
            if s.is_none() {
                *rp = None;
            }
        }
        let mut tokens = vec![0i32; self.b];
        let mut pos = vec![0i32; self.b];
        // sequences consuming a token BEYOND their preallocated prompt
        // this step (those need a KV-block extension)
        let mut grow_ids: Vec<u64> = Vec::new();
        for ((s, tok), p) in self
            .slots
            .iter()
            .zip(tokens.iter_mut())
            .zip(pos.iter_mut())
        {
            if let Some(s) = s {
                *tok = s.next_feed;
                *p = s.pos as i32;
                if s.pos >= s.req.prompt.len() {
                    grow_ids.push(s.req.id);
                }
            }
        }
        self.refresh_scales()?;
        upload_into(
            &self.rt,
            &mut self.stats,
            &mut self.tok_buf,
            &HostArray::i32(vec![self.b, 1], tokens),
        )?;
        upload_into(
            &self.rt,
            &mut self.stats,
            &mut self.pos_buf,
            &HostArray::i32(vec![self.b, 1], pos),
        )?;
        let mut out = {
            let mut all: Vec<&DeviceBuffer> =
                self.param_bufs.iter().collect();
            all.push(&self.kc);
            all.push(&self.vc);
            all.push(&self.tok_buf);
            all.push(&self.pos_buf);
            all.push(&self.ks_buf);
            all.push(&self.vs_buf);
            self.decode.run_to_device(&all)?
        };
        if out.len() != 3 {
            bail!("decode returned {} outputs, want 3", out.len());
        }
        let mut it = out.into_iter();
        let logits_buf =
            it.next().context("decode: missing logits output")?;
        let kc = it.next().context("decode: missing k-cache")?;
        let vc = it.next().context("decode: missing v-cache")?;
        let logits_arr = download(&mut self.stats, &logits_buf)?;
        self.kc = kc;
        self.vc = vc;
        let logits = logits_arr.as_f32()?;
        self.stats.host_bytes_last_step =
            self.stats.host_bytes_moved - bytes0;

        // grow bookkeeping + preemption
        let report = self.sched.extend_all(&grow_ids)?;
        self.stats.preemptions += report.preempted.len() as u64;
        for victim in &report.preempted {
            *self.preempt_counts.entry(*victim).or_insert(0) += 1;
            for s in self.slots.iter_mut() {
                if s.as_ref().map(|x| x.req.id) == Some(*victim) {
                    if let Some(x) = s.take() {
                        // recompute-preemption discards these tokens;
                        // they re-run after readmission, so counting
                        // them as generated would double-count
                        self.stats
                            .discard_tokens(x.generated.len() as u64);
                    }
                }
            }
        }
        // A sequence that self-preempts with nothing else running had
        // the WHOLE cache to itself and still ran out of blocks.
        // Recompute can only succeed if resampling terminates earlier
        // (EOS), so allow a couple of retries, then fail fast instead
        // of thrashing until the 200k-iteration guard.
        if self.sched.n_running() == 0 {
            if let Some(&victim) = report.preempted.last() {
                let tries =
                    self.preempt_counts.get(&victim).copied().unwrap_or(0);
                if tries >= 3 {
                    bail!(
                        "engine livelock: request {victim} self-preempted \
                         {tries} times with the whole KV cache ({} blocks) \
                         to itself — its prompt+generation footprint can \
                         never fit",
                        self.sched.kv.total_blocks()
                    );
                }
            }
        }

        // per-slot: advance cursor/sample
        for i in 0..self.b {
            let Some(slot) =
                self.slots.get_mut(i).and_then(|s| s.as_mut())
            else {
                continue;
            };
            slot.pos += 1;
            // this step wrote the slot's fed token's KV at pos-1:
            // extend the row's resident-prefix record over any prompt
            // tokens now in cache (generated tokens are per-sequence,
            // never shareable, so the record stops at the prompt)
            let resident = slot.pos.min(slot.req.prompt.len());
            if let Some(rp_slot) = self.row_prefix.get_mut(i) {
                match rp_slot {
                    Some(rp) if rp.epoch == self.weight_epoch => {
                        while rp.tokens.len() < resident {
                            match slot.req.prompt.get(rp.tokens.len()) {
                                Some(&t) => rp.tokens.push(t),
                                None => break,
                            }
                        }
                    }
                    // a stale-epoch or invalidated record stays dead:
                    // the row's early positions may hold KV computed
                    // under older weights, so it must never be offered
                    // as a share source again until re-seeded by a
                    // wave or a fresh admission
                    Some(_) => *rp_slot = None,
                    None => {}
                }
            }
            if let Some(&t) = slot.req.prompt.get(slot.cursor) {
                // still prefilling: feed next prompt token, ignore
                // logits
                slot.next_feed = t;
                slot.cursor += 1;
                continue;
            }
            let row = logits
                .get(i * self.vocab..(i + 1) * self.vocab)
                .context("decode logits row out of range")?;
            let s =
                sampler::sample(row, &slot.req.params, &mut slot.rng)?;
            slot.generated.push(s.token);
            slot.logprobs.push(s.logprob);
            slot.logprobs_full.push(s.logprob_full);
            slot.next_feed = s.token;
            self.stats.tokens_generated += 1;
            // take only AFTER the sample succeeded: an error path must
            // leave the slot in place for abort_in_flight's accounting
            let Some(mut taken) =
                self.slots.get_mut(i).and_then(|s| s.take())
            else {
                continue;
            };
            if !self.maybe_finish(&mut taken, s.token, done) {
                if let Some(dst) = self.slots.get_mut(i) {
                    *dst = Some(taken);
                }
            }
        }
        Ok(())
    }

    /// Check termination; if finished, release and record the completion.
    fn maybe_finish(
        &mut self,
        slot: &mut Slot,
        last_tok: i32,
        done: &mut Vec<Completion>,
    ) -> bool {
        let finish = if last_tok == slot.req.params.eos {
            Some(FinishReason::Eos)
        } else if slot.generated.len() >= slot.req.params.max_new_tokens {
            Some(FinishReason::MaxTokens)
        } else if slot.pos >= self.max_seq {
            Some(FinishReason::CacheLimit)
        } else {
            None
        };
        if let Some(reason) = finish {
            // the completion path must finish each sequence EXACTLY
            // once — a rejected finish here means the slot and the
            // scheduler disagree about who owns the id
            let finished = self.sched.finish(slot.req.id);
            assert!(finished, "request {} finished twice", slot.req.id);
            done.push(Completion {
                id: slot.req.id,
                prompt: slot.req.prompt.clone(),
                tokens: slot.generated.clone(),
                logprobs: slot.logprobs.clone(),
                logprobs_full: slot.logprobs_full.clone(),
                finish: reason,
                preemptions: self
                    .preempt_counts
                    .remove(&slot.req.id)
                    .unwrap_or(0),
                epoch: self.weight_epoch,
            });
            return true;
        }
        false
    }
}
