//! The generation engine: continuous batching over the decode
//! entrypoint (RefBackend or PJRT — see runtime/backend.rs), with the
//! paged-KV scheduler, per-slot sampling and rollout-policy logprob
//! capture.
//!
//! Slot model: the decode artifact has a fixed batch of `B` slots. Each
//! slot hosts one running sequence at its own position. New sequences are
//! admitted into free slots and *prefilled through the decode path*
//! (prompt tokens teacher-forced one per step — chunked-prefill style),
//! so prefill and decode mix in the same batch exactly like a
//! continuous-batching server. A whole-batch fast path uses the prefill
//! artifact when the engine starts empty (the common RL-rollout shape).
//!
//! Weights are persistent device buffers; the per-step KV state rides
//! through each execution. The engine's weights are the *quantized* ones
//! installed by the weight-sync pipeline (sync/), so sampled-token
//! logprobs measured here are exactly pi_fp8 from paper eq. (2).

use std::sync::Arc;

use crate::runtime::{DeviceBuffer, Executable, HostArray, Runtime};
use crate::util::error::{bail, Result};
use crate::util::rng::Pcg64;

use super::kvcache::{KvBlockManager, KvGeometry, KvPrecision};
use super::request::{Completion, FinishReason, Request};
use super::sampler;
use super::scheduler::Scheduler;

/// Engine configuration: which artifact variant backs generation and how
/// much KV memory the scheduler may use.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub arch: String,      // "dense" | "moe"
    pub variant: String,   // rollout variant name (bf16, fp8lin, ...)
    /// KV storage precision (affects capacity accounting; numerics are
    /// baked into the artifact variant)
    pub kv_precision: KvPrecision,
    /// KV byte budget for the block manager; None = exactly the dense
    /// cache the artifact carries (no artificial pressure)
    pub kv_budget_bytes: Option<usize>,
    pub block_tokens: usize,
    pub seed: u64,
}

impl EngineConfig {
    pub fn new(arch: &str, variant: &str) -> Self {
        let kv_precision = if variant.contains("kvfp8")
            || variant.contains("fullfp8")
        {
            KvPrecision::Fp8
        } else {
            KvPrecision::Bf16
        };
        EngineConfig {
            arch: arch.to_string(),
            variant: variant.to_string(),
            kv_precision,
            kv_budget_bytes: None,
            block_tokens: 16,
            seed: 1234,
        }
    }
}

struct Slot {
    req: Request,
    /// tokens written to the KV cache so far (== current position)
    pos: usize,
    /// next prompt token to feed (prefill-through-decode cursor)
    cursor: usize,
    /// token to feed this step (last sampled, once prompt is exhausted)
    next_feed: i32,
    generated: Vec<i32>,
    logprobs: Vec<f32>,
}

/// Aggregate counters the experiments read.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub decode_steps: u64,
    pub prefill_waves: u64,
    pub tokens_generated: u64,
    pub preemptions: u64,
}

pub struct HloEngine {
    rt: Arc<Runtime>,
    cfg: EngineConfig,
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    param_bufs: Vec<DeviceBuffer>,
    /// dense KV cache state threaded through decode calls
    kc: HostArray,
    vc: HostArray,
    kscale: f32,
    vscale: f32,
    slots: Vec<Option<Slot>>,
    sched: Scheduler,
    rng: Pcg64,
    preempt_counts: std::collections::BTreeMap<u64, u32>,
    pub stats: EngineStats,
    // geometry
    b: usize,
    max_seq: usize,
    prompt_len: usize,
    vocab: usize,
}

impl HloEngine {
    pub fn new(rt: Arc<Runtime>, cfg: EngineConfig) -> Result<HloEngine> {
        let m = rt.manifest.model(&cfg.arch)?.clone();
        let c = rt.manifest.constants.clone();
        let prefill =
            rt.load(&format!("{}_prefill_{}", cfg.arch, cfg.variant))?;
        let decode =
            rt.load(&format!("{}_decode_{}", cfg.arch, cfg.variant))?;
        let b = c.b_rollout;
        let max_seq = m.cfg("max_seq");
        let geo = KvGeometry {
            n_layers: m.cfg("n_layers"),
            n_kv_heads: m.cfg("n_kv_heads"),
            d_head: m.cfg("d_head"),
            block_tokens: cfg.block_tokens,
            precision: cfg.kv_precision,
        };
        let kv = match cfg.kv_budget_bytes {
            Some(budget) => KvBlockManager::from_budget(geo, budget),
            None => {
                // capacity == the dense cache the artifact carries
                KvBlockManager::new(
                    geo,
                    b * max_seq / cfg.block_tokens,
                )
            }
        };
        let sched = Scheduler::new(kv, b);
        let kv_shape = vec![
            geo.n_layers,
            b,
            geo.n_kv_heads,
            max_seq,
            geo.d_head,
        ];
        let n: usize = kv_shape.iter().product();
        let kc = HostArray::f32(kv_shape.clone(), vec![0.0; n]);
        let vc = HostArray::f32(kv_shape, vec![0.0; n]);
        // initial weights: the aot dump; weight-sync replaces them
        let init = rt.manifest.load_initial_params(&cfg.arch)?;
        let params: Vec<HostArray> = init
            .into_iter()
            .zip(&m.params)
            .map(|(v, p)| HostArray::f32(p.shape.clone(), v))
            .collect();
        let param_bufs = rt.to_device_all(&params)?;
        let seed = cfg.seed;
        Ok(HloEngine {
            rt,
            cfg,
            prefill,
            decode,
            param_bufs,
            kc,
            vc,
            kscale: 1.0,
            vscale: 1.0,
            slots: (0..b).map(|_| None).collect(),
            sched,
            rng: Pcg64::new(seed),
            preempt_counts: std::collections::BTreeMap::new(),
            stats: EngineStats::default(),
            b,
            max_seq,
            prompt_len: c.prompt_len,
            vocab: m.cfg("vocab"),
        })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Install freshly synchronized weights (called by sync::Pipeline at
    /// every RL step — paper Fig 1 "weight synchronization phase").
    pub fn install_weights(&mut self, params: &[HostArray]) -> Result<()> {
        self.param_bufs = self.rt.to_device_all(params)?;
        Ok(())
    }

    /// Install recalibrated QKV scales (paper §2.3.1).
    pub fn install_kv_scales(&mut self, kscale: f32, vscale: f32) {
        self.kscale = kscale;
        self.vscale = vscale;
    }

    pub fn kv_scales(&self) -> (f32, f32) {
        (self.kscale, self.vscale)
    }

    /// Generate completions for a batch of requests (runs to drain).
    pub fn generate(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<Vec<Completion>> {
        for r in &requests {
            if r.prompt.is_empty() || r.prompt.len() > self.prompt_len {
                bail!(
                    "prompt length {} outside 1..={}",
                    r.prompt.len(),
                    self.prompt_len
                );
            }
            self.sched.submit(r.clone());
        }
        let mut done: Vec<Completion> = Vec::new();
        // fast path: empty engine + batch start => batched prefill wave
        if self.slots.iter().all(|s| s.is_none()) {
            self.prefill_wave(&mut done)?;
        }
        let mut guard = 0usize;
        while !self.sched.is_idle() {
            self.admit_into_slots();
            if self.sched.n_running() == 0 {
                // Nothing is running and admission produced nothing, so
                // no KV block can ever be freed: the head-of-line
                // request can never fit. Fail fast with a diagnostic
                // instead of spinning 200k no-op iterations.
                let head = self
                    .sched
                    .head_of_line()
                    .expect("stalled scheduler with an empty queue");
                bail!(
                    "engine stalled: request {} can never be admitted — \
                     its {}-token prompt (+1 growth reserve) needs {} KV \
                     blocks but the cache has only {} blocks total",
                    head.id,
                    head.prompt.len(),
                    self.sched.kv.blocks_for(head.prompt.len() + 1),
                    self.sched.kv.total_blocks()
                );
            }
            self.decode_step(&mut done)?;
            guard += 1;
            if guard > 200_000 {
                bail!("engine livelock: {} running", self.sched.n_running());
            }
        }
        // stable output order by request id
        done.sort_by_key(|c| c.id);
        Ok(done)
    }

    /// Admit waiting requests into free slots.
    fn admit_into_slots(&mut self) {
        let admitted = self.sched.admit();
        for req in admitted {
            let slot_idx = self
                .slots
                .iter()
                .position(|s| s.is_none())
                .expect("scheduler admitted beyond slot capacity");
            let first = req.prompt[0];
            self.slots[slot_idx] = Some(Slot {
                next_feed: first,
                cursor: 1,
                pos: 0,
                generated: Vec::new(),
                logprobs: Vec::new(),
                req,
            });
        }
    }

    /// Whole-batch prefill fast path (engine must be empty).
    fn prefill_wave(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        let admitted = self.sched.admit();
        if admitted.is_empty() {
            return Ok(());
        }
        self.stats.prefill_waves += 1;
        let mut tokens = vec![0i32; self.b * self.prompt_len];
        for (i, req) in admitted.iter().enumerate() {
            for (j, &t) in req.prompt.iter().enumerate() {
                tokens[i * self.prompt_len + j] = t;
            }
            // pad by repeating the last prompt token (never attended)
            for j in req.prompt.len()..self.prompt_len {
                tokens[i * self.prompt_len + j] =
                    *req.prompt.last().unwrap();
            }
        }
        let mut inputs: Vec<HostArray> = Vec::new();
        let tok =
            HostArray::i32(vec![self.b, self.prompt_len], tokens);
        let ks = HostArray::scalar_f32(self.kscale);
        let vs = HostArray::scalar_f32(self.vscale);
        inputs.push(tok);
        inputs.push(ks);
        inputs.push(vs);
        let in_bufs = self.rt.to_device_all(&inputs)?;
        let mut all: Vec<&DeviceBuffer> =
            self.param_bufs.iter().collect();
        all.extend(in_bufs.iter());
        let out = self.prefill.run_buffers(&all)?;
        let (logits, kc, vc) = (&out[0], out[1].clone(), out[2].clone());
        self.kc = kc;
        self.vc = vc;
        // install slots; prompt tokens 0..plen-1 are already in cache;
        // the scheduler allocated plen tokens. sample the first response
        // token from logits[:, plen-1].
        let lg = logits.as_f32()?;
        for (i, req) in admitted.into_iter().enumerate() {
            let plen = req.prompt.len();
            let row = &lg[(i * self.prompt_len + plen - 1) * self.vocab
                ..(i * self.prompt_len + plen - 1) * self.vocab
                    + self.vocab];
            let (tok, lp) = sampler::sample(row, &req.params, &mut self.rng);
            let mut slot = Slot {
                next_feed: tok,
                cursor: plen, // prompt fully consumed
                pos: plen,
                generated: vec![tok],
                logprobs: vec![lp],
                req,
            };
            // prefill wrote positions 0..plen-1; positions beyond plen-1
            // hold pad junk that is never attended (causal mask) and is
            // overwritten as decoding proceeds.
            self.stats.tokens_generated += 1;
            if self.maybe_finish(&mut slot, tok, done) {
                continue;
            }
            // the prefill artifact put sequence i's KV in cache row i,
            // so the slot index MUST be i
            debug_assert!(self.slots[i].is_none());
            self.slots[i] = Some(slot);
        }
        Ok(())
    }

    /// One decode step over all active slots.
    fn decode_step(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        if self.slots.iter().all(|s| s.is_none()) {
            return Ok(());
        }
        self.stats.decode_steps += 1;
        let mut tokens = vec![0i32; self.b];
        let mut pos = vec![0i32; self.b];
        // sequences consuming a token BEYOND their preallocated prompt
        // this step (those need a KV-block extension)
        let mut grow_ids: Vec<u64> = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                tokens[i] = s.next_feed;
                pos[i] = s.pos as i32;
                if s.pos >= s.req.prompt.len() {
                    grow_ids.push(s.req.id);
                }
            }
        }
        let inputs = [
            self.kc.clone(),
            self.vc.clone(),
            HostArray::i32(vec![self.b, 1], tokens),
            HostArray::i32(vec![self.b, 1], pos),
            HostArray::scalar_f32(self.kscale),
            HostArray::scalar_f32(self.vscale),
        ];
        let in_bufs = self.rt.to_device_all(&inputs)?;
        let mut all: Vec<&DeviceBuffer> =
            self.param_bufs.iter().collect();
        all.extend(in_bufs.iter());
        let out = self.decode.run_buffers(&all)?;
        let logits = out[0].as_f32()?.to_vec();
        self.kc = out[1].clone();
        self.vc = out[2].clone();

        // grow bookkeeping + preemption
        let report = self.sched.extend_all(&grow_ids);
        self.stats.preemptions += report.preempted.len() as u64;
        for victim in &report.preempted {
            *self.preempt_counts.entry(*victim).or_insert(0) += 1;
            for s in self.slots.iter_mut() {
                if s.as_ref().map(|x| x.req.id) == Some(*victim) {
                    *s = None;
                }
            }
        }
        // A sequence that self-preempts with nothing else running had
        // the WHOLE cache to itself and still ran out of blocks.
        // Recompute can only succeed if resampling terminates earlier
        // (EOS), so allow a couple of retries, then fail fast instead
        // of thrashing until the 200k-iteration guard.
        if self.sched.n_running() == 0 {
            if let Some(&victim) = report.preempted.last() {
                let tries =
                    self.preempt_counts.get(&victim).copied().unwrap_or(0);
                if tries >= 3 {
                    bail!(
                        "engine livelock: request {victim} self-preempted \
                         {tries} times with the whole KV cache ({} blocks) \
                         to itself — its prompt+generation footprint can \
                         never fit",
                        self.sched.kv.total_blocks()
                    );
                }
            }
        }

        // per-slot: advance cursor/sample
        for i in 0..self.b {
            let Some(slot) = self.slots[i].as_mut() else { continue };
            slot.pos += 1;
            if slot.cursor < slot.req.prompt.len() {
                // still prefilling: feed next prompt token, ignore logits
                slot.next_feed = slot.req.prompt[slot.cursor];
                slot.cursor += 1;
                continue;
            }
            let row = &logits[i * self.vocab..(i + 1) * self.vocab];
            let (tok, lp) =
                sampler::sample(row, &slot.req.params, &mut self.rng);
            slot.generated.push(tok);
            slot.logprobs.push(lp);
            slot.next_feed = tok;
            self.stats.tokens_generated += 1;
            let mut taken = self.slots[i].take().unwrap();
            if !self.maybe_finish(&mut taken, tok, done) {
                self.slots[i] = Some(taken);
            }
        }
        Ok(())
    }

    /// Check termination; if finished, release and record the completion.
    fn maybe_finish(
        &mut self,
        slot: &mut Slot,
        last_tok: i32,
        done: &mut Vec<Completion>,
    ) -> bool {
        let finish = if last_tok == slot.req.params.eos {
            Some(FinishReason::Eos)
        } else if slot.generated.len() >= slot.req.params.max_new_tokens {
            Some(FinishReason::MaxTokens)
        } else if slot.pos >= self.max_seq {
            Some(FinishReason::CacheLimit)
        } else {
            None
        };
        if let Some(reason) = finish {
            self.sched.finish(slot.req.id);
            done.push(Completion {
                id: slot.req.id,
                prompt: slot.req.prompt.clone(),
                tokens: slot.generated.clone(),
                logprobs: slot.logprobs.clone(),
                finish: reason,
                preemptions: self
                    .preempt_counts
                    .remove(&slot.req.id)
                    .unwrap_or(0),
            });
            return true;
        }
        false
    }
}
