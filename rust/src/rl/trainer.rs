//! The trainer: owns master weights + Adam state and drives the AOT
//! train-step artifact (DAPO loss + token-level TIS + Adam fused in HLO).
//!
//! The artifact computes everything differentiable; this wrapper owns
//! state threading, hyperparameters, and metric extraction (including
//! the paper's mismatch-KL and the Fig-11 gradient tile-exceedance
//! profile).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::runtime::{HostArray, Runtime};
use crate::util::error::{bail, Context, Result};

use super::dapo::{TrainBatch, EPOCH_PAD};

#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub arch: String,
    /// train variant: bf16 | fp8hybrid | fp8e4m3 | fp8hybrid_ue8m0
    pub variant: String,
    pub lr: f32,
    /// TIS clip threshold C (paper uses 2.0); <= 0 disables TIS
    pub tis_c: f32,
    /// entropy bonus coefficient (stabilizes tiny-scale DAPO)
    pub ent_coef: f32,
    /// use Masked IS instead of Truncated IS (paper §2.1.3 "TIS/MIS")
    pub mis: bool,
}

impl TrainerConfig {
    pub fn new(arch: &str, variant: &str) -> TrainerConfig {
        TrainerConfig {
            arch: arch.to_string(),
            variant: variant.to_string(),
            lr: 3e-4,
            tis_c: 2.0,
            ent_coef: 0.02,
            mis: false,
        }
    }
}

/// Metrics from one train step (names from the manifest).
#[derive(Clone, Debug, Default)]
pub struct TrainMetrics {
    pub values: BTreeMap<String, f32>,
}

impl TrainMetrics {
    pub fn get(&self, name: &str) -> f32 {
        *self.values.get(name).unwrap_or(&f32::NAN)
    }
}

pub struct Trainer {
    rt: Arc<Runtime>,
    pub cfg: TrainerConfig,
    /// flat master weights (param_spec order)
    params: Vec<HostArray>,
    m_state: Vec<HostArray>,
    v_state: Vec<HostArray>,
    step: f32,
    n_params: usize,
    b: usize,
    t: usize,
}

impl Trainer {
    pub fn new(rt: Arc<Runtime>, cfg: TrainerConfig) -> Result<Trainer> {
        let spec = rt.manifest.model(&cfg.arch)?.clone();
        let c = rt.manifest.constants.clone();
        let init = rt.manifest.load_initial_params(&cfg.arch)?;
        let params: Vec<HostArray> = init
            .into_iter()
            .zip(&spec.params)
            .map(|(v, p)| HostArray::f32(p.shape.clone(), v))
            .collect();
        let zeros: Vec<HostArray> = spec
            .params
            .iter()
            .map(|p| {
                HostArray::f32(
                    p.shape.clone(),
                    vec![0.0; p.shape.iter().product()],
                )
            })
            .collect();
        Ok(Trainer {
            rt,
            n_params: params.len(),
            params,
            m_state: zeros.clone(),
            v_state: zeros,
            step: 0.0,
            b: c.b_train,
            t: c.t_train,
            cfg,
        })
    }

    pub fn params(&self) -> &[HostArray] {
        &self.params
    }

    pub fn step_count(&self) -> f32 {
        self.step
    }

    /// Run one DAPO update on an assembled batch.
    pub fn train_step(&mut self, batch: &TrainBatch) -> Result<TrainMetrics> {
        if batch.b != self.b || batch.t != self.t {
            bail!(
                "batch ({}, {}) does not match artifact ({}, {})",
                batch.b,
                batch.t,
                self.b,
                self.t
            );
        }
        if batch.epochs.len() != batch.b {
            bail!(
                "batch carries {} behavior-epoch tags for {} rows — \
                 the TIS/MIS denominators would not be attributable \
                 to their sampling epochs",
                batch.epochs.len(),
                batch.b
            );
        }
        let exe = self.rt.load(&format!(
            "{}_train_{}",
            self.cfg.arch, self.cfg.variant
        ))?;
        let mut inputs: Vec<HostArray> = Vec::with_capacity(
            3 * self.n_params + 6,
        );
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.m_state.iter().cloned());
        inputs.extend(self.v_state.iter().cloned());
        inputs.push(HostArray::f32(vec![1, 1], vec![self.step]));
        inputs.push(HostArray::i32(
            vec![self.b, self.t],
            batch.tokens.clone(),
        ));
        inputs.push(HostArray::f32(
            vec![self.b, self.t - 1],
            batch.mask.clone(),
        ));
        inputs.push(HostArray::f32(
            vec![self.b, self.t - 1],
            batch.advantages.clone(),
        ));
        inputs.push(HostArray::f32(
            vec![self.b, self.t - 1],
            batch.rollout_logp.clone(),
        ));
        inputs.push(HostArray::f32(
            vec![1, 4],
            vec![
                self.cfg.lr,
                self.cfg.tis_c,
                self.cfg.ent_coef,
                if self.cfg.mis { 1.0 } else { 0.0 },
            ],
        ));
        let out = exe.run(&inputs)?;
        let n = self.n_params;
        if out.len() != 3 * n + 2 {
            bail!("train artifact returned {} outputs", out.len());
        }
        let mut it = out.into_iter();
        self.params = it.by_ref().take(n).collect();
        self.m_state = it.by_ref().take(n).collect();
        self.v_state = it.by_ref().take(n).collect();
        let step_arr =
            it.next().context("train artifact: missing step")?;
        self.step = *step_arr
            .as_f32()?
            .first()
            .context("train artifact: empty step output")?;
        let metrics_arr =
            it.next().context("train artifact: missing metrics")?;
        let metric_vals = metrics_arr.as_f32()?;
        let names = &self.rt.manifest.constants.metric_names;
        let mut metrics = TrainMetrics::default();
        for (name, &v) in names.iter().zip(metric_vals.iter()) {
            metrics.values.insert(name.clone(), v);
        }
        // behavior-epoch provenance: which weight epochs this batch's
        // rollout_logp (the TIS/MIS denominators) were measured under.
        // Under cross-step pipelining these run behind the trainer's
        // epoch by the bounded staleness; reporting min/max keeps the
        // per-epoch correctness auditable from the metrics alone.
        let (mut emin, mut emax) = (u64::MAX, 0u64);
        for &e in &batch.epochs {
            if e != EPOCH_PAD {
                emin = emin.min(e);
                emax = emax.max(e);
            }
        }
        if emin <= emax {
            metrics
                .values
                .insert("behavior_epoch_min".into(), emin as f32);
            metrics
                .values
                .insert("behavior_epoch_max".into(), emax as f32);
        }
        Ok(metrics)
    }

    /// Mismatch KL / TIS diagnostics without updating weights: runs the
    /// logprobs artifact to evaluate the current policy on given rows.
    pub fn eval_logprobs(
        &self,
        tokens: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self
            .rt
            .load(&format!("{}_logprobs_bf16", self.cfg.arch))?;
        let mut inputs: Vec<HostArray> = self.params.to_vec();
        inputs.push(HostArray::i32(
            vec![self.b, self.t],
            tokens.to_vec(),
        ));
        let out = exe.run(&inputs)?;
        let mut it = out.into_iter();
        let lp = it
            .next()
            .context("logprobs artifact: missing logprobs")?
            .as_f32()?
            .to_vec();
        let ent = it
            .next()
            .context("logprobs artifact: missing entropy")?
            .as_f32()?
            .to_vec();
        Ok((lp, ent))
    }
}
