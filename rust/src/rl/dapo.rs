//! DAPO batch assembly: group-relative advantages + token alignment.
//!
//! The group-relative advantage (GRPO/DAPO family) normalizes each
//! response's reward within its prompt group: A_i = (r_i - mean) / (std
//! + eps); the same advantage is broadcast to every response token.
//! Dynamic-sampling (DAPO's "keep groups with signal") drops groups
//! whose rewards are all identical (no gradient).
//!
//! `TrainBatch::assemble` also aligns rollout logprobs to the trainer's
//! (B, T-1) next-token grid: position t carries the logprob/advantage of
//! token t+1, masked to response tokens only.

use crate::rollout::Completion;

use super::task::{Problem, Task, TOK_PAD};

/// One (prompt, response) row with its reward and group id.
#[derive(Clone, Debug)]
pub struct Sample {
    pub problem: Problem,
    pub completion: Completion,
    pub reward: f32,
    pub group: usize,
}

/// Epoch tag carried by padding rows of [`TrainBatch::epochs`] (no
/// completion backs them; they are fully masked).
pub const EPOCH_PAD: u64 = u64::MAX;

/// Assembled tensors for one train-step artifact call.
#[derive(Clone, Debug)]
pub struct TrainBatch {
    pub b: usize,
    pub t: usize,
    pub tokens: Vec<i32>,       // (B, T)
    pub mask: Vec<f32>,         // (B, T-1)
    pub advantages: Vec<f32>,   // (B, T-1)
    pub rollout_logp: Vec<f32>, // (B, T-1)
    /// per-row behavior-policy weight epoch (`Completion::epoch`) —
    /// the epoch each row's `rollout_logp` (the TIS/MIS denominator)
    /// was measured under. Under cross-step pipelining rows may be
    /// tagged behind the trainer's current epoch; the RL loop bounds
    /// that staleness, and the trainer reports the batch's
    /// min/max so it stays auditable. Padding rows carry
    /// [`EPOCH_PAD`].
    pub epochs: Vec<u64>, // (B,)
    pub mean_reward: f32,
    pub mean_response_len: f32,
    /// groups dropped by dynamic sampling (zero variance)
    pub dropped_groups: usize,
}

pub fn score(task_samples: &mut [Sample]) {
    for s in task_samples.iter_mut() {
        s.reward = Task::reward(&s.problem, &s.completion.tokens);
    }
}

/// Running reward moments for one prompt group.
#[derive(Clone, Copy, Default)]
struct GroupStat {
    sum: f64,
    sq: f64,
    count: usize,
}

/// Group-relative advantages. Returns per-sample advantage.
pub fn group_advantages(samples: &[Sample], eps: f32) -> Vec<f32> {
    let n_groups = samples
        .iter()
        .map(|s| s.group)
        .max()
        .map(|g| g + 1)
        .unwrap_or(0);
    let mut stats = vec![GroupStat::default(); n_groups];
    for s in samples {
        if let Some(g) = stats.get_mut(s.group) {
            g.sum += s.reward as f64;
            g.sq += (s.reward as f64) * (s.reward as f64);
            g.count += 1;
        }
    }
    samples
        .iter()
        .map(|s| {
            let Some(g) = stats.get(s.group) else {
                return 0.0;
            };
            let n = g.count.max(1) as f64;
            let mean = g.sum / n;
            let var = (g.sq / n - mean * mean).max(0.0);
            ((s.reward as f64 - mean) / (var.sqrt() + eps as f64)) as f32
        })
        .collect()
}

impl TrainBatch {
    /// Build the padded (B, T) batch. Rows beyond `samples.len()` are
    /// fully masked padding.
    pub fn assemble(
        samples: &[Sample],
        b: usize,
        t: usize,
        adv_eps: f32,
        drop_zero_variance_groups: bool,
    ) -> TrainBatch {
        assert!(
            t >= 2,
            "t_train must be at least 2, got {t}: the (B, T-1) \
             next-token buffers would underflow"
        );
        let advs = group_advantages(samples, adv_eps);
        // dynamic sampling: identify zero-signal groups
        let n_groups = samples
            .iter()
            .map(|s| s.group)
            .max()
            .map(|g| g + 1)
            .unwrap_or(0);
        let mut group_has_signal = vec![false; n_groups];
        if drop_zero_variance_groups {
            let mut bounds =
                vec![(f32::INFINITY, f32::NEG_INFINITY); n_groups];
            for s in samples {
                if let Some(bd) = bounds.get_mut(s.group) {
                    bd.0 = bd.0.min(s.reward);
                    bd.1 = bd.1.max(s.reward);
                }
            }
            for (has, (lo, hi)) in
                group_has_signal.iter_mut().zip(bounds)
            {
                *has = hi - lo > 1e-6;
            }
        } else {
            group_has_signal.iter_mut().for_each(|x| *x = true);
        }
        let dropped_groups =
            group_has_signal.iter().filter(|&&x| !x).count();

        let mut tokens = vec![TOK_PAD; b * t];
        let mut mask = vec![0.0f32; b * (t - 1)];
        let mut advantages = vec![0.0f32; b * (t - 1)];
        let mut rollout_logp = vec![0.0f32; b * (t - 1)];
        let mut epochs = vec![EPOCH_PAD; b];
        let mut total_reward = 0.0f32;
        let mut total_len = 0usize;

        let rows = tokens
            .chunks_mut(t)
            .zip(epochs.iter_mut())
            .zip(mask.chunks_mut(t - 1).zip(
                advantages
                    .chunks_mut(t - 1)
                    .zip(rollout_logp.chunks_mut(t - 1)),
            ));
        for (
            (s, &adv),
            ((row_tok, epoch), (row_mask, (row_adv, row_lp))),
        ) in samples.iter().zip(&advs).zip(rows)
        {
            let plen = s.problem.prompt.len();
            *epoch = s.completion.epoch;
            let resp = &s.completion.tokens;
            total_reward += s.reward;
            total_len += resp.len();
            // row = prompt ++ response, truncated to t
            for (dst, &tok) in row_tok
                .iter_mut()
                .zip(s.problem.prompt.iter().chain(resp.iter()))
            {
                *dst = tok;
            }
            // NOTE: zero-variance ("dropped") groups keep their mask —
            // their advantage is exactly 0 so they contribute no
            // gradient, but the mismatch-KL / entropy / TIS metrics must
            // still see their tokens (the paper logs mismatch KL over
            // the whole rollout batch). `dropped_groups` reports the
            // dynamic-sampling statistic.
            // mask/adv/logp at position j predict token j+1: response
            // token r_k sits at absolute index plen + k, so its
            // prediction slot is plen + k - 1 — undefined for the very
            // first token of an EMPTY prompt (nothing precedes it to
            // predict from), so that token is skipped
            let start = plen.saturating_sub(1);
            let skip_k = usize::from(plen == 0);
            let slots = row_mask.iter_mut().skip(start).zip(
                row_adv
                    .iter_mut()
                    .skip(start)
                    .zip(row_lp.iter_mut().skip(start)),
            );
            let lps =
                s.completion.logprobs.iter().take(resp.len());
            for ((m, (a, l)), &lp) in slots.zip(lps.skip(skip_k)) {
                *m = 1.0;
                *a = adv;
                *l = lp;
            }
        }
        // metrics average over the rows actually assembled: when a step
        // produces more samples than b_train, the overflow rows carry
        // no tokens/rewards into this batch and must not dilute (or
        // skew) the recorded reward
        let used = samples.len().min(b).max(1);
        TrainBatch {
            b,
            t,
            tokens,
            mask,
            advantages,
            rollout_logp,
            epochs,
            mean_reward: total_reward / used as f32,
            mean_response_len: total_len as f32 / used as f32,
            dropped_groups,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rollout::request::FinishReason;
    use crate::rl::task::{make_problem, Problem, TOK_EOS};

    fn sample(group: usize, reward: f32, resp: Vec<i32>) -> Sample {
        let problem = make_problem(2, 3);
        let lp = vec![-0.5; resp.len()];
        Sample {
            problem: problem.clone(),
            completion: Completion {
                id: 0,
                prompt: problem.prompt.clone(),
                tokens: resp,
                logprobs: lp.clone(),
                logprobs_full: lp,
                finish: FinishReason::Eos,
                preemptions: 0,
                epoch: 0,
            },
            reward,
            group,
        }
    }

    #[test]
    fn group_advantage_zero_mean() {
        let samples = vec![
            sample(0, 1.0, vec![5, TOK_EOS]),
            sample(0, 0.0, vec![9, TOK_EOS]),
            sample(1, 0.5, vec![5, TOK_EOS]),
            sample(1, 0.5, vec![5, TOK_EOS]),
        ];
        let advs = group_advantages(&samples, 1e-4);
        assert!((advs[0] + advs[1]).abs() < 1e-5); // zero-mean per group
        assert!(advs[0] > 0.0 && advs[1] < 0.0);
        assert_eq!(advs[2], 0.0); // no variance => zero advantage
    }

    #[test]
    fn batch_alignment() {
        let s = sample(0, 1.0, vec![5, TOK_EOS]);
        let plen = s.problem.prompt.len(); // BOS 2 + 3 = -> 5 tokens
        let batch = TrainBatch::assemble(
            &[
                s,
                sample(0, 0.0, vec![9, TOK_EOS]),
            ],
            4,
            16,
            1e-4,
            false,
        );
        // token row: prompt then response
        assert_eq!(batch.tokens[plen], 5);
        assert_eq!(batch.tokens[plen + 1], TOK_EOS);
        // mask slots: plen-1 (predicting '5') and plen (predicting EOS)
        assert_eq!(batch.mask[plen - 1], 1.0);
        assert_eq!(batch.mask[plen], 1.0);
        assert_eq!(batch.mask[plen + 1], 0.0);
        // prompt positions unmasked
        assert_eq!(batch.mask[0], 0.0);
        // rollout logprobs land on the same slots
        assert_eq!(batch.rollout_logp[plen - 1], -0.5);
        // padding rows fully masked
        for j in 0..15 {
            assert_eq!(batch.mask[2 * 15 + j], 0.0);
        }
    }

    #[test]
    fn overflow_metrics_average_filled_rows_only() {
        // regression: 3 samples into b=2 used to divide the 2 assembled
        // rows' totals by 3, under-reporting reward and length
        let samples = vec![
            sample(0, 1.0, vec![5, 5, TOK_EOS]),
            sample(0, 1.0, vec![5, 5, TOK_EOS]),
            sample(1, 0.0, vec![9, TOK_EOS]),
        ];
        let batch = TrainBatch::assemble(&samples, 2, 16, 1e-4, false);
        assert_eq!(batch.mean_reward, 1.0);
        assert_eq!(batch.mean_response_len, 3.0);
    }

    #[test]
    #[should_panic(expected = "t_train must be at least 2")]
    fn degenerate_t_panics_with_diagnostic() {
        let samples = vec![sample(0, 1.0, vec![5, TOK_EOS])];
        let _ = TrainBatch::assemble(&samples, 2, 1, 1e-4, false);
    }

    #[test]
    fn empty_prompt_does_not_underflow() {
        // regression: `plen + k - 1` underflowed usize (debug panic)
        // for the FIRST response token of an empty prompt (plen == 0,
        // k == 0). That token has no prediction slot — position j
        // predicts token j+1, and nothing precedes it — so it is
        // skipped; the SECOND response token lands at slot 0.
        let problem = Problem {
            a: 0,
            b: 0,
            prompt: Vec::new(),
            answer: vec![5, TOK_EOS],
        };
        let resp = vec![5i32, TOK_EOS];
        let s = Sample {
            problem,
            completion: Completion {
                id: 0,
                prompt: Vec::new(),
                tokens: resp.clone(),
                logprobs: vec![-0.25; resp.len()],
                logprobs_full: vec![-0.25; resp.len()],
                finish: FinishReason::Eos,
                preemptions: 0,
                epoch: 0,
            },
            reward: 1.0,
            group: 0,
        };
        let batch = TrainBatch::assemble(&[s], 2, 16, 1e-4, false);
        // the row is response-only
        assert_eq!(batch.tokens[0], 5);
        assert_eq!(batch.tokens[1], TOK_EOS);
        // slot 0 predicts token index 1 (EOS) and carries ITS logprob;
        // the skipped first token contributed no slot anywhere
        assert_eq!(batch.mask[0], 1.0);
        assert_eq!(batch.rollout_logp[0], -0.25);
        assert_eq!(
            batch.mask.iter().filter(|&&m| m == 1.0).count(),
            1,
            "exactly one predictable response token"
        );
    }

    #[test]
    fn per_row_epochs_thread_through_assembly() {
        // the cross-step pipelining bookkeeping: each row's behavior
        // epoch tag (and its rollout_logp denominators) come from that
        // row's OWN completion, padding rows are EPOCH_PAD
        let mut s0 = sample(0, 1.0, vec![5, TOK_EOS]);
        s0.completion.epoch = 3;
        let mut s1 = sample(0, 0.0, vec![9, TOK_EOS]);
        s1.completion.epoch = 4;
        let plen = s0.problem.prompt.len();
        let batch = TrainBatch::assemble(&[s0, s1], 4, 16, 1e-4, false);
        assert_eq!(batch.epochs[0], 3);
        assert_eq!(batch.epochs[1], 4);
        assert_eq!(batch.epochs[2], EPOCH_PAD);
        assert_eq!(batch.epochs[3], EPOCH_PAD);
        // row 0's denominator slots hold row 0's behavior logprobs
        assert_eq!(batch.rollout_logp[plen - 1], -0.5);
    }

    #[test]
    fn dynamic_sampling_reports_flat_groups() {
        let samples = vec![
            sample(0, 0.5, vec![5, TOK_EOS]),
            sample(0, 0.5, vec![5, TOK_EOS]),
            sample(1, 1.0, vec![5, TOK_EOS]),
            sample(1, 0.0, vec![9, TOK_EOS]),
        ];
        let batch = TrainBatch::assemble(&samples, 4, 16, 1e-4, true);
        assert_eq!(batch.dropped_groups, 1);
        let plen = samples[0].problem.prompt.len();
        // flat group keeps its mask (KL metrics) but has zero advantage
        assert_eq!(batch.mask[plen - 1], 1.0);
        assert_eq!(batch.advantages[plen - 1], 0.0);
        // group with signal has nonzero advantage
        assert!(batch.advantages[2 * 15 + plen - 1] > 0.0);
    }
}
