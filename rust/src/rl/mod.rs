//! RL algorithm layer: the DAPO batch machinery, token-level TIS/MIS
//! mismatch correction (computed inside the train-step artifact), the
//! synthetic arithmetic task, and the trainer driving the AOT train step.
pub mod dapo;
pub mod task;
pub mod trainer;

pub use dapo::{group_advantages, Sample, TrainBatch};
pub use task::{Problem, Task, TaskConfig};
pub use trainer::{Trainer, TrainerConfig, TrainMetrics};
