//! The synthetic reasoning task: multi-digit addition with exact-match
//! reward — the AIME24/DAPO-math stand-in (DESIGN.md §1).
//!
//! Vocabulary (32 tokens, matching the model config):
//!   0..9   digits
//!   10     '+'
//!   11     '='
//!   12     BOS
//!   13     EOS
//!   14     PAD
//!   15..31 unused
//!
//! Prompt:  BOS d1.. '+' d2.. '='     (numbers little-ended per digit)
//! Target:  digits of the sum, then EOS.
//!
//! Reward = 0.5 * (correct digit prefix fraction) + 0.5 * exact match —
//! dense enough for a tiny policy to climb, sparse enough that accuracy
//! curves look like the paper's (slow rise, plateaus).

use crate::util::rng::Pcg64;

pub const TOK_PLUS: i32 = 10;
pub const TOK_EQ: i32 = 11;
pub const TOK_BOS: i32 = 12;
pub const TOK_EOS: i32 = 13;
pub const TOK_PAD: i32 = 14;

#[derive(Clone, Debug)]
pub struct TaskConfig {
    /// max digits per operand
    pub max_digits: u32,
    /// optional cap on a+b (curriculum: Some(9) keeps answers one digit)
    pub max_sum: Option<u64>,
    /// held-out validation problems
    pub n_validation: usize,
    pub seed: u64,
}

impl Default for TaskConfig {
    fn default() -> Self {
        TaskConfig {
            max_digits: 2,
            max_sum: None,
            n_validation: 64,
            seed: 7,
        }
    }
}

/// One problem instance.
#[derive(Clone, Debug, PartialEq)]
pub struct Problem {
    pub a: u64,
    pub b: u64,
    pub prompt: Vec<i32>,
    /// expected answer tokens INCLUDING the trailing EOS
    pub answer: Vec<i32>,
}

fn digits(mut n: u64) -> Vec<i32> {
    if n == 0 {
        return vec![0];
    }
    let mut out = Vec::new();
    while n > 0 {
        out.push((n % 10) as i32);
        n /= 10;
    }
    out.reverse();
    out
}

pub fn make_problem(a: u64, b: u64) -> Problem {
    let mut prompt = vec![TOK_BOS];
    prompt.extend(digits(a));
    prompt.push(TOK_PLUS);
    prompt.extend(digits(b));
    prompt.push(TOK_EQ);
    let mut answer = digits(a + b);
    answer.push(TOK_EOS);
    Problem {
        a,
        b,
        prompt,
        answer,
    }
}

/// The task: samples training problems, holds a fixed validation set.
pub struct Task {
    pub cfg: TaskConfig,
    rng: Pcg64,
    validation: Vec<Problem>,
}

impl Task {
    pub fn new(cfg: TaskConfig) -> Task {
        let mut rng = Pcg64::new(cfg.seed);
        let mut seen = std::collections::BTreeSet::new();
        let mut validation = Vec::new();
        // prefer distinct problems; if the problem space is smaller than
        // n_validation (e.g. one-digit sums: 55 pairs), allow repeats
        let mut attempts = 0usize;
        while validation.len() < cfg.n_validation {
            let (a, b) = Self::draw(&cfg, &mut rng);
            attempts += 1;
            if seen.insert((a, b)) || attempts > 20 * cfg.n_validation {
                validation.push(make_problem(a, b));
            }
        }
        Task {
            cfg,
            rng,
            validation,
        }
    }

    fn draw(cfg: &TaskConfig, rng: &mut Pcg64) -> (u64, u64) {
        let hi = 10u64.pow(cfg.max_digits) - 1;
        loop {
            let a = rng.below(hi + 1);
            let b = rng.below(hi + 1);
            if cfg.max_sum.map(|m| a + b <= m).unwrap_or(true) {
                return (a, b);
            }
        }
    }

    /// Sample a fresh training problem (may overlap validation — the
    /// space is tiny, like re-sampling the same math contest topics).
    pub fn sample(&mut self) -> Problem {
        let (a, b) = Self::draw(&self.cfg, &mut self.rng);
        make_problem(a, b)
    }

    pub fn validation(&self) -> &[Problem] {
        &self.validation
    }

    /// Reward for a generated response (tokens up to and incl. EOS).
    pub fn reward(problem: &Problem, response: &[i32]) -> f32 {
        let exact = response == problem.answer.as_slice();
        // digit-prefix credit (ignores trailing EOS slot)
        let cut = problem.answer.len().saturating_sub(1);
        let want = problem.answer.get(..cut).unwrap_or(&[]);
        let mut correct = 0usize;
        for (i, &w) in want.iter().enumerate() {
            if response.get(i) == Some(&w) {
                correct += 1;
            } else {
                break;
            }
        }
        let frac = correct as f32 / want.len().max(1) as f32;
        0.5 * frac + if exact { 0.5 } else { 0.0 }
    }

    /// Exact-match check (the validation-accuracy metric).
    pub fn is_correct(problem: &Problem, response: &[i32]) -> bool {
        response == problem.answer.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_encoding() {
        let p = make_problem(27, 19);
        assert_eq!(
            p.prompt,
            vec![TOK_BOS, 2, 7, TOK_PLUS, 1, 9, TOK_EQ]
        );
        assert_eq!(p.answer, vec![4, 6, TOK_EOS]);
    }

    #[test]
    fn zero_operands() {
        let p = make_problem(0, 0);
        assert_eq!(p.prompt, vec![TOK_BOS, 0, TOK_PLUS, 0, TOK_EQ]);
        assert_eq!(p.answer, vec![0, TOK_EOS]);
    }

    #[test]
    fn rewards() {
        let p = make_problem(27, 19); // 46
        assert_eq!(Task::reward(&p, &[4, 6, TOK_EOS]), 1.0);
        assert_eq!(Task::reward(&p, &[4, 5, TOK_EOS]), 0.25); // prefix 1/2
        assert_eq!(Task::reward(&p, &[9, 9, TOK_EOS]), 0.0);
        // right digits but no EOS -> not exact
        let r = Task::reward(&p, &[4, 6, 1]);
        assert_eq!(r, 0.5);
        assert!(Task::is_correct(&p, &[4, 6, TOK_EOS]));
        assert!(!Task::is_correct(&p, &[4, 6]));
    }

    #[test]
    fn validation_is_deterministic() {
        let t1 = Task::new(TaskConfig::default());
        let t2 = Task::new(TaskConfig::default());
        assert_eq!(t1.validation()[0], t2.validation()[0]);
        assert_eq!(t1.validation().len(), 64);
    }

    #[test]
    fn prompt_lengths_bounded() {
        let mut t = Task::new(TaskConfig {
            max_digits: 2,
            ..Default::default()
        });
        for _ in 0..200 {
            let p = t.sample();
            assert!(p.prompt.len() <= 1 + 2 + 1 + 2 + 1);
            assert!(p.answer.len() <= 4);
        }
    }
}
