//! Backend-agnostic runtime: manifest-driven loading and execution of
//! entrypoints through the pluggable [`Backend`] trait.
//!
//! The default backend is the hermetic [`RefBackend`]; the original XLA
//! PJRT path lives behind the `pjrt` cargo feature (runtime/pjrt.rs)
//! and becomes the default when that feature is enabled. Entrypoints
//! are compiled once and cached; inputs can be passed either as host
//! arrays (validated against the manifest signature) or as persistent
//! device buffers — the engine keeps model weights resident and only
//! streams per-step state.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::util::error::{bail, Context, Result};

use super::backend::{Backend, DeviceBuffer, ExecutableImpl};
use super::host::HostArray;
use super::manifest::{EntrySpec, Manifest};
use super::refbackend::RefBackend;

/// A compiled entrypoint bound to its manifest signature.
pub struct Executable {
    pub spec: EntrySpec,
    imp: Box<dyn ExecutableImpl>,
}

impl Executable {
    /// Execute with host arrays (validates shapes/dtypes first).
    pub fn run(&self, inputs: &[HostArray]) -> Result<Vec<HostArray>> {
        self.check_inputs(inputs)?;
        self.imp.run(inputs)
    }

    /// Execute with pre-staged device buffers (the hot path: weights
    /// stay resident, only per-step state is uploaded by the caller).
    pub fn run_buffers(
        &self,
        inputs: &[&DeviceBuffer],
    ) -> Result<Vec<HostArray>> {
        self.imp.run_buffers(inputs)
    }

    /// Execute with device buffers in AND out (the decode hot path):
    /// outputs stay backend-resident, so state threaded between calls
    /// — the KV cache above all — never crosses the host boundary.
    pub fn run_to_device(
        &self,
        inputs: &[&DeviceBuffer],
    ) -> Result<Vec<DeviceBuffer>> {
        self.imp.run_to_device(inputs)
    }

    fn check_inputs(&self, inputs: &[HostArray]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (a, sig)) in
            inputs.iter().zip(&self.spec.inputs).enumerate()
        {
            if !a.matches(sig) {
                bail!(
                    "{}: input {i} shape/dtype mismatch: got {:?} {:?}, \
                     want {:?} {:?}",
                    self.spec.name,
                    a.shape(),
                    a.dtype(),
                    sig.shape,
                    sig.dtype
                );
            }
        }
        Ok(())
    }
}

/// The runtime: one backend + a compile cache over entrypoints.
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Load the manifest from `artifacts_dir` and attach the default
    /// backend. When no manifest exists on disk, fall back to the
    /// built-in synthetic manifest so the stack stays runnable without
    /// `make artifacts` (the hermetic mode `cargo test` exercises).
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir = artifacts_dir.into();
        let rt = Runtime::new_quiet(dir.clone())?;
        // warn off the loaded manifest itself, so the warning can
        // never desync from the fallback criterion new_quiet applies
        if rt.manifest.is_synthetic() {
            crate::log_warn!(
                "no manifest under {dir:?} — falling back to the \
                 SYNTHETIC hermetic manifest (toy model, seeded \
                 weights); run `make artifacts` for the real AOT \
                 artifacts"
            );
        }
        Ok(rt)
    }

    /// [`Runtime::new`] without the missing-manifest warning — the
    /// engine pool's per-replica factories use this so N replicas do
    /// not log N copies of the synthetic-fallback notice. The fallback
    /// criterion lives only here.
    pub fn new_quiet(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir = artifacts_dir.into();
        let manifest = if dir.join("manifest.json").exists() {
            Manifest::load(&dir)?
        } else {
            Manifest::synthetic()
        };
        Ok(Runtime::with_backend(manifest, default_backend()?))
    }

    /// Fully hermetic runtime: synthetic manifest + RefBackend,
    /// regardless of features or on-disk artifacts.
    pub fn hermetic() -> Runtime {
        Runtime::with_backend(
            Manifest::synthetic(),
            Box::new(RefBackend::new()),
        )
    }

    /// Attach an explicit backend to a manifest. Infallible: the
    /// runtime holds no resources beyond what the caller hands it.
    pub fn with_backend(
        manifest: Manifest,
        backend: Box<dyn Backend>,
    ) -> Runtime {
        Runtime {
            manifest,
            backend,
            cache: Mutex::new(HashMap::new()),
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Load + compile an entrypoint (cached).
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self
            .cache
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(name)
        {
            return Ok(e.clone());
        }
        let spec = self.manifest.entry(name)?.clone();
        let imp = self
            .backend
            .compile(&self.manifest, &spec)
            .with_context(|| format!("compiling {name}"))?;
        let exec = Arc::new(Executable { spec, imp });
        self.cache
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Upload a host array to a persistent device buffer.
    pub fn to_device(&self, a: &HostArray) -> Result<DeviceBuffer> {
        self.backend.to_device(a)
    }

    /// Upload many host arrays.
    pub fn to_device_all(
        &self,
        arrays: &[HostArray],
    ) -> Result<Vec<DeviceBuffer>> {
        arrays.iter().map(|a| self.to_device(a)).collect()
    }
}

#[cfg(not(feature = "pjrt"))]
fn default_backend() -> Result<Box<dyn Backend>> {
    Ok(Box::new(RefBackend::new()))
}

#[cfg(feature = "pjrt")]
fn default_backend() -> Result<Box<dyn Backend>> {
    Ok(Box::new(super::pjrt::PjrtBackend::new()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hermetic_runtime_loads_and_validates() {
        let rt = Runtime::hermetic();
        assert_eq!(rt.backend_name(), "ref");
        let exe = rt.load("dense_calibrate").unwrap();
        // wrong arity is rejected before execution
        assert!(exe.run(&[]).is_err());
        // unknown entrypoints are rejected
        assert!(rt.load("dense_decode_nonsense").is_err());
    }

    #[test]
    fn compile_cache_is_shared() {
        let rt = Runtime::hermetic();
        let a = rt.load("dense_prefill_bf16").unwrap();
        let b = rt.load("dense_prefill_bf16").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
