//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the rollout/training hot paths. Adapted from /opt/xla-example/load_hlo.
//!
//! Key mechanics:
//! * HLO **text** interchange (old xla_extension rejects jax>=0.5 protos).
//! * Outputs arrive as ONE tuple PjRtBuffer per execution; we fetch it to
//!   a literal and decompose. Inputs can be passed either as host arrays
//!   (uploaded per call) or as persistent device buffers — the engine
//!   keeps model weights resident and only streams per-step state.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::host::HostArray;
use super::manifest::{EntrySpec, Manifest};

/// A device-resident input buffer with its backing host literal pinned.
pub struct DeviceBuffer {
    pub buf: xla::PjRtBuffer,
    _keepalive: xla::Literal,
}

/// A compiled entrypoint.
pub struct Executable {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host arrays (uploads inputs, downloads outputs).
    pub fn run(&self, inputs: &[HostArray]) -> Result<Vec<HostArray>> {
        self.check_inputs(inputs)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let out = self.exe.execute::<xla::Literal>(&lits)?;
        Self::collect(out)
    }

    /// Execute with pre-staged device buffers (the hot path: weights stay
    /// resident, only per-step state is uploaded by the caller).
    pub fn run_buffers(
        &self,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<HostArray>> {
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        Self::collect(out)
    }

    fn collect(
        out: Vec<Vec<xla::PjRtBuffer>>,
    ) -> Result<Vec<HostArray>> {
        let buf = &out[0][0];
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts
            .iter()
            .map(HostArray::from_literal)
            .collect::<Result<Vec<_>>>()
    }

    fn check_inputs(&self, inputs: &[HostArray]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (a, sig)) in
            inputs.iter().zip(&self.spec.inputs).enumerate()
        {
            if !a.matches(sig) {
                bail!(
                    "{}: input {i} shape/dtype mismatch: got {:?} {:?}, \
                     want {:?} {:?}",
                    self.spec.name,
                    a.shape(),
                    a.dtype(),
                    sig.shape,
                    sig.dtype
                );
            }
        }
        Ok(())
    }
}

/// The PJRT runtime: one CPU client + a compile cache over entrypoints.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir = artifacts_dir.into();
        let manifest = Manifest::load(&dir)?;
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "pjrt client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Load + compile an entrypoint (cached).
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.entry(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        log::info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let exec = Arc::new(Executable { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Upload a host array to a persistent device buffer.
    ///
    /// TFRT-CPU's `BufferFromHostLiteral` copies asynchronously and the
    /// xla crate exposes no ready-future, so the source literal MUST
    /// outlive the transfer — `DeviceBuffer` pins it for the buffer's
    /// whole lifetime (dropping it early is a use-after-free that shows
    /// up as nondeterministic `shape_util.cc` fatal checks).
    pub fn to_device(&self, a: &HostArray) -> Result<DeviceBuffer> {
        let lit = a.to_literal()?;
        let buf = self.client.buffer_from_host_literal(None, &lit)?;
        Ok(DeviceBuffer {
            buf,
            _keepalive: lit,
        })
    }

    /// Upload many host arrays.
    pub fn to_device_all(
        &self,
        arrays: &[HostArray],
    ) -> Result<Vec<DeviceBuffer>> {
        arrays.iter().map(|a| self.to_device(a)).collect()
    }
}
