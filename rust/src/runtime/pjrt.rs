//! PJRT backend: load AOT HLO-text artifacts, compile once, execute
//! from the rollout/training hot paths. Adapted from
//! /opt/xla-example/load_hlo.
//!
//! Behind the `pjrt` cargo feature: it wraps the external `xla` crate,
//! which needs the XLA C++ extension library — neither exists in the
//! hermetic environment, so building with `--features pjrt` requires
//! vendoring the crate (see DESIGN.md "Backends"). The default build
//! never compiles this module; `cargo check --features pjrt` is the CI
//! canary that keeps the code from rotting silently when an XLA
//! toolchain IS present.
//!
//! Key mechanics:
//! * HLO **text** interchange (old xla_extension rejects jax>=0.5
//!   protos).
//! * Outputs arrive as ONE tuple PjRtBuffer per execution; we fetch it
//!   to a literal and decompose.
//! * TFRT-CPU's `BufferFromHostLiteral` copies asynchronously and the
//!   xla crate exposes no ready-future, so the source literal MUST
//!   outlive the transfer — `PjrtDeviceBuffer` pins it for the buffer's
//!   whole lifetime (dropping it early is a use-after-free that shows
//!   up as nondeterministic `shape_util.cc` fatal checks).

use std::time::Instant;

use crate::util::error::{bail, Context, Result};

use super::backend::{
    Backend, DeviceBuffer, DeviceBufferImpl, ExecutableImpl,
};
use super::host::HostArray;
use super::manifest::{EntrySpec, Manifest};

/// A device-resident input buffer with its backing literal pinned.
pub struct PjrtDeviceBuffer {
    buf: xla::PjRtBuffer,
    _keepalive: xla::Literal,
}

impl DeviceBufferImpl for PjrtDeviceBuffer {
    fn to_host(&self) -> Result<HostArray> {
        let lit = self.buf.to_literal_sync()?;
        from_literal(&lit)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

pub struct PjrtExecutable {
    spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

impl ExecutableImpl for PjrtExecutable {
    fn run(&self, inputs: &[HostArray]) -> Result<Vec<HostArray>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(to_literal)
            .collect::<Result<Vec<_>>>()?;
        let out = self.exe.execute::<xla::Literal>(&lits)?;
        collect(out)
    }

    fn run_buffers(
        &self,
        inputs: &[&DeviceBuffer],
    ) -> Result<Vec<HostArray>> {
        let mut bufs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(inputs.len());
        for b in inputs {
            let Some(p) =
                b.imp().as_any().downcast_ref::<PjrtDeviceBuffer>()
            else {
                // a host-staged buffer (e.g. from the default
                // `run_to_device` fallback threading state between
                // calls): fetch everything and take the host path.
                // This round-trips the native inputs (params) too — a
                // native run_to_device holding a client handle to
                // re-stage foreign buffers is the planned fix (see
                // DESIGN.md "Device-resident KV threading").
                let hosts: Result<Vec<HostArray>> =
                    inputs.iter().map(|b| b.to_host()).collect();
                return self.run(&hosts?);
            };
            bufs.push(&p.buf);
        }
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(&bufs)?;
        collect(out)
    }
}

fn collect(out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<HostArray>> {
    let buf = out
        .first()
        .and_then(|r| r.first())
        .context("pjrt execute returned no output")?;
    let lit = buf.to_literal_sync()?;
    let parts = lit.to_tuple()?;
    parts.iter().map(from_literal).collect::<Result<Vec<_>>>()
}

/// Convert a host array to an xla literal (with shape).
fn to_literal(a: &HostArray) -> Result<xla::Literal> {
    let dims: Vec<i64> = a.shape().iter().map(|&d| d as i64).collect();
    let lit = match a {
        HostArray::F32(_, d) => xla::Literal::vec1(d),
        HostArray::I32(_, d) => xla::Literal::vec1(d),
    };
    Ok(lit.reshape(&dims)?)
}

/// Convert an xla literal back to a host array.
fn from_literal(lit: &xla::Literal) -> Result<HostArray> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> =
        shape.dims().iter().map(|&d| d as usize).collect();
    match shape.primitive_type() {
        xla::PrimitiveType::F32 => {
            Ok(HostArray::F32(dims, lit.to_vec::<f32>()?))
        }
        xla::PrimitiveType::S32 => {
            Ok(HostArray::I32(dims, lit.to_vec::<i32>()?))
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}

/// The PJRT backend: one CPU client shared by all executables.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::log_info!(
            "pjrt client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(PjrtBackend { client })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(
        &self,
        manifest: &Manifest,
        spec: &EntrySpec,
    ) -> Result<Box<dyn ExecutableImpl>> {
        let path = manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        crate::log_info!(
            "compiled {} in {:.2}s",
            spec.name,
            t0.elapsed().as_secs_f64()
        );
        Ok(Box::new(PjrtExecutable {
            spec: spec.clone(),
            exe,
        }))
    }

    fn to_device(&self, a: &HostArray) -> Result<DeviceBuffer> {
        let lit = to_literal(a)?;
        let buf = self.client.buffer_from_host_literal(None, &lit)?;
        Ok(DeviceBuffer::new(Box::new(PjrtDeviceBuffer {
            buf,
            _keepalive: lit,
        })))
    }
}
