//! RefBackend: the deterministic, hermetic reference executor.
//!
//! Serves every manifest entrypoint kind (`prefill` / `decode` /
//! `train` / `logprobs` / `calibrate`) with a tiny recurrent language
//! model computed from the *real* manifest parameters, so the full RL
//! loop (engine -> weight sync -> trainer) runs end to end in
//! `cargo test` with zero native or crates.io dependencies.
//!
//! The reference model, per batch row (d = d_model, V = vocab):
//!
//! ```text
//! c_t      = 0.7 * c_{t-1} + embed[tok_t]        state, R^d
//! h_t      = tanh(8 * c_t @ layer0.q_proj)       features, R^d
//! logits_t = h_t @ lm_head                       R^V
//! ```
//!
//! Precision semantics follow the variant name, mirroring the real
//! artifacts' recipes:
//!
//! * rollout paths round logits through bf16 (tensor-core stand-in), so
//!   even the `bf16` rollout diverges slightly from the trainer's f32
//!   logprobs path — the paper's kernel-level train/inference mismatch;
//! * `fp8lin` / `fullfp8` fake-quantize the features through E4M3 with a
//!   per-row amax scale (UE8M0 scales for `*_ue8m0` variants) — this is
//!   what makes pi_fp8 visibly diverge (paper eq. 2);
//! * `kvfp8` / `fullfp8` store the KV state E4M3-quantized under the
//!   live k/v scales, so scale calibration quality is observable.
//!
//! The KV state is genuinely threaded through the cache tensors: the
//! recurrence reads position p-1 back from the (possibly quantized)
//! cache, so chunked prefill through the decode path reproduces the
//! batched prefill wave bit-exactly — the invariant the engine's two
//! prefill paths rely on. The train path carries real Adam moments and
//! a real policy-gradient update; backprop runs through the lm_head
//! only (features are treated as constants), which is deliberate: it is
//! enough for learning to be observable in tests while keeping the
//! executor small. See DESIGN.md "RefBackend numerics" for the full
//! contract and divergence from PJRT.

use std::cell::{Ref, RefCell};
use std::rc::Rc;

use crate::fp8::{ScaleFormat, E4M3};
use crate::util::error::{bail, Context, Result};

use super::backend::{
    Backend, DeviceBuffer, DeviceBufferImpl, ExecutableImpl,
};
use super::host::HostArray;
use super::manifest::{Constants, EntrySpec, Manifest, ModelSpec};

/// State-recurrence decay.
const ALPHA: f32 = 0.7;
/// Feature pre-activation gain (keeps logits in a workable range).
const BETA: f32 = 8.0;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const GRAD_CLIP: f32 = 1.0;

pub struct RefBackend;

impl RefBackend {
    pub fn new() -> RefBackend {
        RefBackend
    }
}

impl Default for RefBackend {
    fn default() -> Self {
        RefBackend::new()
    }
}

/// "Device" memory for the reference backend is host memory behind a
/// shared cell: `run_to_device` mutates threaded state (the KV cache)
/// in place and hands back aliases, so the decode hot loop moves zero
/// cache bytes per step.
struct RefBuffer(Rc<RefCell<HostArray>>);

impl RefBuffer {
    fn alias(&self) -> DeviceBuffer {
        DeviceBuffer::new(Box::new(RefBuffer(self.0.clone())))
    }
}

/// Wrap a freshly computed host array as a ref-backend device buffer.
fn ref_device(a: HostArray) -> DeviceBuffer {
    DeviceBuffer::new(Box::new(RefBuffer(Rc::new(RefCell::new(a)))))
}

impl DeviceBufferImpl for RefBuffer {
    fn to_host(&self) -> Result<HostArray> {
        Ok(self.0.borrow().clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn write_from_host(&self, a: &HostArray) -> Result<bool> {
        let mut dst = self.0.borrow_mut();
        if dst.shape() != a.shape() || dst.dtype() != a.dtype() {
            return Ok(false); // caller uploads a fresh buffer
        }
        match (&mut *dst, a) {
            (HostArray::F32(_, d), HostArray::F32(_, s)) => {
                d.copy_from_slice(s)
            }
            (HostArray::I32(_, d), HostArray::I32(_, s)) => {
                d.copy_from_slice(s)
            }
            _ => return Ok(false),
        }
        Ok(true)
    }

    fn copy_within_ranges(
        &self,
        ranges: &[(usize, usize, usize)],
    ) -> Result<bool> {
        let mut a = self.0.borrow_mut();
        // only the f32 KV tensors need device-side row aliasing
        let HostArray::F32(_, data) = &mut *a else {
            return Ok(false);
        };
        for &(src, dst, len) in ranges {
            let (Some(src_end), Some(dst_end)) =
                (src.checked_add(len), dst.checked_add(len))
            else {
                bail!("copy_within_ranges: range overflow");
            };
            if src_end > data.len() || dst_end > data.len() {
                bail!(
                    "copy_within_ranges: range out of bounds \
                     ({src}+{len} / {dst}+{len} of {})",
                    data.len()
                );
            }
            data.copy_within(src..src_end, dst);
        }
        Ok(true)
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn compile(
        &self,
        manifest: &Manifest,
        spec: &EntrySpec,
    ) -> Result<Box<dyn ExecutableImpl>> {
        let model = manifest.model(&spec.arch)?.clone();
        let geo = Geometry::from_model(&model)?;
        Ok(Box::new(RefExecutable {
            spec: spec.clone(),
            model,
            geo,
            constants: manifest.constants.clone(),
        }))
    }

    fn to_device(&self, a: &HostArray) -> Result<DeviceBuffer> {
        Ok(ref_device(a.clone()))
    }
}

#[derive(Clone, Copy)]
struct Geometry {
    d: usize,
    vocab: usize,
    n_layers: usize,
    n_kv_heads: usize,
    d_head: usize,
    max_seq: usize,
}

impl Geometry {
    fn from_model(m: &ModelSpec) -> Result<Geometry> {
        let g = Geometry {
            d: m.cfg("d_model"),
            vocab: m.cfg("vocab"),
            n_layers: m.cfg("n_layers"),
            n_kv_heads: m.cfg("n_kv_heads"),
            d_head: m.cfg("d_head"),
            max_seq: m.cfg("max_seq"),
        };
        // the state vector is striped across the per-position cache
        // slots, so the cache must be at least d_model wide per token
        let slots = g.n_layers * g.n_kv_heads * g.d_head;
        if g.d > slots {
            bail!(
                "refbackend: d_model {} exceeds per-token KV capacity {}",
                g.d,
                slots
            );
        }
        Ok(g)
    }

    /// Flat index of state component `j` at (row `b`, position `pos`)
    /// inside a (L, B, H, S, Dh) cache tensor.
    fn cache_index(
        &self,
        b_rollout: usize,
        b: usize,
        pos: usize,
        j: usize,
    ) -> usize {
        let per_layer = self.n_kv_heads * self.d_head;
        let l = j / per_layer;
        let r = j % per_layer;
        let h = r / self.d_head;
        let dd = r % self.d_head;
        (((l * b_rollout + b) * self.n_kv_heads + h) * self.max_seq + pos)
            * self.d_head
            + dd
    }

    fn cache_len(&self, b_rollout: usize) -> usize {
        self.n_layers
            * b_rollout
            * self.n_kv_heads
            * self.max_seq
            * self.d_head
    }

    fn kv_shape(&self, b_rollout: usize) -> Vec<usize> {
        vec![
            self.n_layers,
            b_rollout,
            self.n_kv_heads,
            self.max_seq,
            self.d_head,
        ]
    }
}

#[derive(Clone, Copy)]
struct VariantFlags {
    fp8_linear: bool,
    fp8_kv: bool,
    scale_fmt: ScaleFormat,
}

fn variant_flags(variant: &str) -> VariantFlags {
    VariantFlags {
        fp8_linear: variant.contains("fp8lin")
            || variant.contains("fullfp8"),
        fp8_kv: variant.contains("kvfp8") || variant.contains("fullfp8"),
        scale_fmt: if variant.contains("ue8m0") {
            ScaleFormat::Ue8m0
        } else {
            ScaleFormat::Fp32
        },
    }
}

/// Truncate to bf16 precision (tensor-core rounding stand-in).
fn bf16(x: f32) -> f32 {
    f32::from_bits(x.to_bits() & 0xFFFF_0000)
}

/// E4M3 fake-quant of one value under an explicit scale.
fn qdq_kv(x: f32, scale: f32) -> f32 {
    if scale <= 0.0 || !scale.is_finite() {
        return 0.0;
    }
    E4M3.qdq(x / scale) * scale
}

/// Per-row E4M3 activation fake-quant with an amax-derived scale.
fn qdq_row_e4m3(h: &mut [f32], scale_fmt: ScaleFormat) {
    let amax = h.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    if amax <= 0.0 || !amax.is_finite() {
        return;
    }
    let s = scale_fmt.apply(amax / E4M3.max);
    for x in h.iter_mut() {
        *x = E4M3.qdq(*x / s) * s;
    }
}

/// Borrowed view of the reference model's live parameters.
struct RefModel<'a> {
    geo: Geometry,
    embed: &'a [f32],
    wq: Option<&'a [f32]>,
    wq_cols: usize,
    lm_head: &'a [f32],
}

/// The host array bound to the named parameter of `spec`.
fn lookup<'p>(
    spec: &ModelSpec,
    params: &[&'p HostArray],
    name: &str,
) -> Result<&'p HostArray> {
    let i = spec
        .params
        .iter()
        .position(|p| p.name == name)
        .with_context(|| {
            format!("model {} has no param {name}", spec.arch)
        })?;
    params.get(i).copied().with_context(|| {
        format!("model {}: parameter list shorter than spec", spec.arch)
    })
}

impl<'a> RefModel<'a> {
    fn new(
        spec: &ModelSpec,
        geo: Geometry,
        params: &[&'a HostArray],
    ) -> Result<RefModel<'a>> {
        let embed = lookup(spec, params, "embed")?.as_f32()?;
        let lm_head = lookup(spec, params, "lm_head")?.as_f32()?;
        let (wq, wq_cols) = match spec
            .params
            .iter()
            .enumerate()
            .find(|(_, p)| p.name == "layer0.q_proj")
        {
            Some((i, p)) => match params.get(i) {
                Some(a) => (
                    Some(a.as_f32()?),
                    p.shape.get(1).copied().unwrap_or(0),
                ),
                None => (None, 0),
            },
            None => (None, 0),
        };
        Ok(RefModel {
            geo,
            embed,
            wq,
            wq_cols,
            lm_head,
        })
    }

    /// c' = ALPHA * prev + embed[tok]
    fn state_update(&self, prev: &[f32], tok: i32) -> Vec<f32> {
        let d = self.geo.d;
        let t = (tok.max(0) as usize) % self.geo.vocab.max(1);
        let row = if d > 0 {
            self.embed.chunks_exact(d).nth(t).unwrap_or(&[])
        } else {
            &[]
        };
        let mut out = vec![0.0f32; d];
        for ((o, &p), &r) in out.iter_mut().zip(prev).zip(row) {
            *o = ALPHA * p + r;
        }
        out
    }

    /// h = tanh(BETA * c @ layer0.q_proj) (identity mix if absent).
    fn features(&self, c: &[f32]) -> Vec<f32> {
        let d = self.geo.d;
        let mut h = vec![0.0f32; d];
        let cols = self.wq_cols.min(d);
        match self.wq {
            Some(w) if cols > 0 => {
                // k-outer accumulation, same per-output add order as
                // the j-outer form: acc[j] += c[k] * w[k, j]
                let mut acc = vec![0.0f32; cols];
                for (&ck, wrow) in
                    c.iter().zip(w.chunks_exact(self.wq_cols))
                {
                    for (a, &wkj) in acc.iter_mut().zip(wrow) {
                        *a += ck * wkj;
                    }
                }
                for (out, &a) in h.iter_mut().zip(&acc) {
                    *out = (BETA * a).tanh();
                }
                // identity tail beyond the projection's columns
                for (out, &cj) in h.iter_mut().zip(c).skip(cols) {
                    *out = (BETA * cj).tanh();
                }
            }
            _ => {
                for (out, &cj) in h.iter_mut().zip(c) {
                    *out = (BETA * cj).tanh();
                }
            }
        }
        h
    }

    /// logits = h @ lm_head
    fn logits(&self, h: &[f32]) -> Vec<f32> {
        let v = self.geo.vocab;
        let mut out = vec![0.0f32; v];
        if v == 0 {
            return out;
        }
        for (&hk, row) in h.iter().zip(self.lm_head.chunks_exact(v)) {
            // lint: allow(D2): exact-zero sparsity skip, not a tolerance
            if hk == 0.0 {
                continue;
            }
            for (o, r) in out.iter_mut().zip(row) {
                *o += hk * r;
            }
        }
        out
    }
}

/// Borrow the leading `n` host inputs as the flat parameter list.
fn borrow_params(inputs: &[HostArray], n: usize) -> Vec<&HostArray> {
    inputs.iter().take(n).collect()
}

/// First element of a scalar f32 input.
fn scalar(a: &HostArray, what: &str) -> Result<f32> {
    a.as_f32()?
        .first()
        .copied()
        .with_context(|| format!("empty {what} scalar"))
}

/// Read the state stored at `pos` back out of the caches (mean of the
/// K and V copies — both carry the state, each under its own scale).
fn read_state(
    geo: Geometry,
    kc: &[f32],
    vc: &[f32],
    b_rollout: usize,
    b: usize,
    pos: usize,
) -> Vec<f32> {
    (0..geo.d)
        .map(|j| {
            let i = geo.cache_index(b_rollout, b, pos, j);
            0.5 * (kc.get(i).copied().unwrap_or(0.0)
                + vc.get(i).copied().unwrap_or(0.0))
        })
        .collect()
}

/// Store the state at `pos` (quantized when the variant demands it) and
/// return exactly what a subsequent read would see — prefill threads
/// this so the wave and chunked paths agree bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn store_state(
    geo: Geometry,
    kc: &mut [f32],
    vc: &mut [f32],
    b_rollout: usize,
    b: usize,
    pos: usize,
    c: &[f32],
    fp8_kv: bool,
    ks: f32,
    vs: f32,
) -> Vec<f32> {
    let mut seen = vec![0.0f32; geo.d];
    for ((j, &cj), s) in c.iter().enumerate().zip(seen.iter_mut()) {
        let i = geo.cache_index(b_rollout, b, pos, j);
        let (k, v) = if fp8_kv {
            (qdq_kv(cj, ks), qdq_kv(cj, vs))
        } else {
            (cj, cj)
        };
        if let Some(slot) = kc.get_mut(i) {
            *slot = k;
        }
        if let Some(slot) = vc.get_mut(i) {
            *slot = v;
        }
        *s = 0.5 * (k + v);
    }
    seen
}

pub struct RefExecutable {
    spec: EntrySpec,
    model: ModelSpec,
    geo: Geometry,
    constants: Constants,
}

impl ExecutableImpl for RefExecutable {
    fn run(&self, inputs: &[HostArray]) -> Result<Vec<HostArray>> {
        match self.spec.kind.as_str() {
            "prefill" => self.run_prefill(inputs),
            "decode" => self.run_decode(inputs),
            "train" => self.run_train(inputs),
            "logprobs" => self.run_logprobs(inputs),
            "calibrate" => self.run_calibrate(inputs),
            other => {
                bail!("refbackend: unsupported entrypoint kind {other:?}")
            }
        }
    }

    fn run_to_device(
        &self,
        inputs: &[&DeviceBuffer],
    ) -> Result<Vec<DeviceBuffer>> {
        let refs: Option<Vec<&RefBuffer>> = inputs
            .iter()
            .map(|b| b.imp().as_any().downcast_ref::<RefBuffer>())
            .collect();
        if let Some(bufs) = refs {
            match self.spec.kind.as_str() {
                "decode" => return self.run_decode_device(&bufs),
                "prefill" => return self.run_prefill_device(&bufs),
                _ => {}
            }
        }
        // cold kinds / foreign buffers: host round-trip, re-wrapped so
        // later device-path calls can still consume the outputs
        Ok(self
            .run_buffers(inputs)?
            .into_iter()
            .map(ref_device)
            .collect())
    }
}

impl RefExecutable {
    fn check_arity(&self, got: usize, want: usize) -> Result<()> {
        if got != want {
            bail!("{}: expected {want} inputs, got {got}", self.spec.name);
        }
        Ok(())
    }

    /// Prefill compute shared by the host and device entrypoints:
    /// returns (logits, kc, vc) as freshly allocated flat vecs.
    fn prefill_core(
        &self,
        model: &RefModel,
        tokens: &[i32],
        ks: f32,
        vs: f32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let flags = variant_flags(&self.spec.variant);
        let geo = self.geo;
        let (b_roll, plen) =
            (self.constants.b_rollout, self.constants.prompt_len);
        let v = geo.vocab;
        let mut kc = vec![0.0f32; geo.cache_len(b_roll)];
        let mut vc = vec![0.0f32; geo.cache_len(b_roll)];
        let mut logits = vec![0.0f32; b_roll * plen * v];
        if plen == 0 || v == 0 {
            return (logits, kc, vc);
        }
        for (b, (trow, lrow_b)) in tokens
            .chunks_exact(plen)
            .zip(logits.chunks_exact_mut(plen * v))
            .take(b_roll)
            .enumerate()
        {
            let mut state = vec![0.0f32; geo.d];
            for (p, (&tok, lrow)) in trow
                .iter()
                .zip(lrow_b.chunks_exact_mut(v))
                .enumerate()
            {
                let c = model.state_update(&state, tok);
                let mut h = model.features(&c);
                if flags.fp8_linear {
                    qdq_row_e4m3(&mut h, flags.scale_fmt);
                }
                let row = model.logits(&h);
                for (dst, x) in lrow.iter_mut().zip(&row) {
                    *dst = bf16(*x);
                }
                state = store_state(
                    geo,
                    &mut kc,
                    &mut vc,
                    b_roll,
                    b,
                    p,
                    &c,
                    flags.fp8_kv,
                    ks,
                    vs,
                );
            }
        }
        (logits, kc, vc)
    }

    fn run_prefill(
        &self,
        inputs: &[HostArray],
    ) -> Result<Vec<HostArray>> {
        let n = self.model.params.len();
        self.check_arity(inputs.len(), n + 3)?;
        let model =
            RefModel::new(&self.model, self.geo, &borrow_params(inputs, n))?;
        let (_, rest) = inputs.split_at(n);
        let [tokens_a, ks_a, vs_a] = rest else {
            bail!("{}: input unpacking failed", self.spec.name);
        };
        let tokens = tokens_a.as_i32()?;
        let ks = scalar(ks_a, "kscale")?;
        let vs = scalar(vs_a, "vscale")?;
        let (logits, kc, vc) = self.prefill_core(&model, tokens, ks, vs);
        let geo = self.geo;
        let (b_roll, plen) =
            (self.constants.b_rollout, self.constants.prompt_len);
        Ok(vec![
            HostArray::f32(vec![b_roll, plen, geo.vocab], logits),
            HostArray::f32(geo.kv_shape(b_roll), kc),
            HostArray::f32(geo.kv_shape(b_roll), vc),
        ])
    }

    /// Native device-resident prefill: parameters are read in place
    /// (no per-call clone) and the fresh KV caches come back as
    /// backend-owned buffers the decode path consumes directly.
    fn run_prefill_device(
        &self,
        bufs: &[&RefBuffer],
    ) -> Result<Vec<DeviceBuffer>> {
        let n = self.model.params.len();
        self.check_arity(bufs.len(), n + 3)?;
        let (logits, kc, vc) = {
            let guards: Vec<Ref<HostArray>> =
                bufs.iter().map(|b| b.0.borrow()).collect();
            let refs: Vec<&HostArray> =
                guards.iter().map(|g| &**g).collect();
            let (ps, rest) = refs.split_at(n);
            let [tokens_a, ks_a, vs_a] = rest else {
                bail!("{}: input unpacking failed", self.spec.name);
            };
            let model = RefModel::new(&self.model, self.geo, ps)?;
            let tokens = tokens_a.as_i32()?;
            let ks = scalar(ks_a, "kscale")?;
            let vs = scalar(vs_a, "vscale")?;
            self.prefill_core(&model, tokens, ks, vs)
        };
        let geo = self.geo;
        let (b_roll, plen) =
            (self.constants.b_rollout, self.constants.prompt_len);
        Ok(vec![
            ref_device(HostArray::f32(
                vec![b_roll, plen, geo.vocab],
                logits,
            )),
            ref_device(HostArray::f32(geo.kv_shape(b_roll), kc)),
            ref_device(HostArray::f32(geo.kv_shape(b_roll), vc)),
        ])
    }

    /// Decode compute shared by the host and device entrypoints; the
    /// caches are updated IN PLACE, logits are returned fresh.
    #[allow(clippy::too_many_arguments)]
    fn decode_core(
        &self,
        model: &RefModel,
        kc: &mut [f32],
        vc: &mut [f32],
        tokens: &[i32],
        pos: &[i32],
        ks: f32,
        vs: f32,
    ) -> Result<Vec<f32>> {
        let flags = variant_flags(&self.spec.variant);
        let geo = self.geo;
        let b_roll = self.constants.b_rollout;
        if kc.len() != geo.cache_len(b_roll) || vc.len() != kc.len() {
            bail!(
                "{}: cache length {} != expected {}",
                self.spec.name,
                kc.len(),
                geo.cache_len(b_roll)
            );
        }
        let v = geo.vocab;
        let mut logits = vec![0.0f32; b_roll * v];
        if v == 0 {
            return Ok(logits);
        }
        for (b, ((&tok, &pv), lrow)) in tokens
            .iter()
            .zip(pos)
            .zip(logits.chunks_exact_mut(v))
            .take(b_roll)
            .enumerate()
        {
            let p = pv.max(0) as usize;
            if p >= geo.max_seq {
                bail!(
                    "{}: decode position {p} out of range (max_seq {})",
                    self.spec.name,
                    geo.max_seq
                );
            }
            let prev = if p == 0 {
                vec![0.0f32; geo.d]
            } else {
                read_state(geo, kc, vc, b_roll, b, p - 1)
            };
            let c = model.state_update(&prev, tok);
            let mut h = model.features(&c);
            if flags.fp8_linear {
                qdq_row_e4m3(&mut h, flags.scale_fmt);
            }
            let row = model.logits(&h);
            for (dst, x) in lrow.iter_mut().zip(&row) {
                *dst = bf16(*x);
            }
            store_state(
                geo,
                kc,
                vc,
                b_roll,
                b,
                p,
                &c,
                flags.fp8_kv,
                ks,
                vs,
            );
        }
        Ok(logits)
    }

    fn run_decode(
        &self,
        inputs: &[HostArray],
    ) -> Result<Vec<HostArray>> {
        let n = self.model.params.len();
        self.check_arity(inputs.len(), n + 6)?;
        let model =
            RefModel::new(&self.model, self.geo, &borrow_params(inputs, n))?;
        let (_, rest) = inputs.split_at(n);
        let [kc_a, vc_a, tokens_a, pos_a, ks_a, vs_a] = rest else {
            bail!("{}: input unpacking failed", self.spec.name);
        };
        let mut kc = kc_a.as_f32()?.to_vec();
        let mut vc = vc_a.as_f32()?.to_vec();
        let tokens = tokens_a.as_i32()?;
        let pos = pos_a.as_i32()?;
        let ks = scalar(ks_a, "kscale")?;
        let vs = scalar(vs_a, "vscale")?;
        let logits = self
            .decode_core(&model, &mut kc, &mut vc, tokens, pos, ks, vs)?;
        let geo = self.geo;
        let b_roll = self.constants.b_rollout;
        Ok(vec![
            HostArray::f32(vec![b_roll, geo.vocab], logits),
            HostArray::f32(geo.kv_shape(b_roll), kc),
            HostArray::f32(geo.kv_shape(b_roll), vc),
        ])
    }

    /// Native device-resident decode — the engine hot path. The KV
    /// caches are mutated IN PLACE inside their backend cells and
    /// returned as aliases: zero cache bytes move per step; only the
    /// (B, V) logits ever cross back to the host.
    fn run_decode_device(
        &self,
        bufs: &[&RefBuffer],
    ) -> Result<Vec<DeviceBuffer>> {
        let n = self.model.params.len();
        self.check_arity(bufs.len(), n + 6)?;
        let (pbufs, rest) = bufs.split_at(n);
        let [kcb, vcb, tokb, posb, ksb, vsb] = rest else {
            bail!("{}: input unpacking failed", self.spec.name);
        };
        let logits = {
            let guards: Vec<Ref<HostArray>> =
                pbufs.iter().map(|b| b.0.borrow()).collect();
            let refs: Vec<&HostArray> =
                guards.iter().map(|g| &**g).collect();
            let model = RefModel::new(&self.model, self.geo, &refs)?;
            let mut kcg = kcb.0.borrow_mut();
            let mut vcg = vcb.0.borrow_mut();
            let tokg = tokb.0.borrow();
            let posg = posb.0.borrow();
            let ksg = ksb.0.borrow();
            let vsg = vsb.0.borrow();
            let ks = scalar(&ksg, "kscale")?;
            let vs = scalar(&vsg, "vscale")?;
            self.decode_core(
                &model,
                kcg.as_f32_mut()?,
                vcg.as_f32_mut()?,
                tokg.as_i32()?,
                posg.as_i32()?,
                ks,
                vs,
            )?
        };
        let b_roll = self.constants.b_rollout;
        Ok(vec![
            ref_device(HostArray::f32(
                vec![b_roll, self.geo.vocab],
                logits,
            )),
            kcb.alias(),
            vcb.alias(),
        ])
    }

    /// Teacher-forced forward on the trainer's f32 path. Returns, per
    /// row and position t in 0..T-1: features h_t, softmax probs,
    /// next-token logprob and entropy.
    fn train_forward(
        &self,
        model: &RefModel,
        tokens: &[i32],
    ) -> TrainForward {
        let geo = self.geo;
        let (bt, tt) = (self.constants.b_train, self.constants.t_train);
        let (d, v) = (geo.d, geo.vocab);
        let steps = tt.saturating_sub(1);
        let mut fwd = TrainForward {
            feats: vec![0.0f32; bt * steps * d],
            probs: vec![0.0f32; bt * steps * v],
            lp: vec![0.0f32; bt * steps],
            ent: vec![0.0f32; bt * steps],
            nexts: vec![0usize; bt * steps],
        };
        if steps == 0 || d == 0 || v == 0 {
            return fwd;
        }
        for ((((trow, frow_b), prow_b), lrow_b), (erow_b, nrow_b)) in
            tokens
                .chunks_exact(tt)
                .zip(fwd.feats.chunks_exact_mut(steps * d))
                .zip(fwd.probs.chunks_exact_mut(steps * v))
                .zip(fwd.lp.chunks_exact_mut(steps))
                .zip(
                    fwd.ent
                        .chunks_exact_mut(steps)
                        .zip(fwd.nexts.chunks_exact_mut(steps)),
                )
                .take(bt)
        {
            let mut state = vec![0.0f32; d];
            for (((((&tok, &tok_next), fslot), pslot), lslot), (eslot, nslot)) in
                trow.iter()
                    .zip(trow.iter().skip(1))
                    .zip(frow_b.chunks_exact_mut(d))
                    .zip(prow_b.chunks_exact_mut(v))
                    .zip(lrow_b.iter_mut())
                    .zip(erow_b.iter_mut().zip(nrow_b.iter_mut()))
            {
                let c = model.state_update(&state, tok);
                let h = model.features(&c);
                let row = model.logits(&h);
                let mx =
                    row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                let z: f64 =
                    row.iter().map(|&x| ((x - mx) as f64).exp()).sum();
                let logz = mx as f64 + z.ln();
                let nxt = (tok_next.max(0) as usize) % v;
                *nslot = nxt;
                *lslot = (row.get(nxt).copied().unwrap_or(0.0) as f64
                    - logz) as f32;
                let mut e = 0.0f64;
                for (ps, &x) in pslot.iter_mut().zip(&row) {
                    let p = ((x as f64) - logz).exp();
                    *ps = p as f32;
                    e -= p * ((x as f64) - logz);
                }
                *eslot = e as f32;
                fslot.copy_from_slice(&h);
                state = c;
            }
        }
        fwd
    }

    fn run_train(&self, inputs: &[HostArray]) -> Result<Vec<HostArray>> {
        let n = self.model.params.len();
        self.check_arity(inputs.len(), 3 * n + 6)?;
        let (params, rest) = inputs.split_at(n);
        let (m_in, rest) = rest.split_at(n);
        let (v_in, rest) = rest.split_at(n);
        let [step_a, tokens_a, mask_a, adv_a, rlogp_a, hp_a] = rest
        else {
            bail!("{}: train input unpacking failed", self.spec.name);
        };
        let step = scalar(step_a, "step")?;
        let tokens = tokens_a.as_i32()?;
        let mask = mask_a.as_f32()?;
        let adv = adv_a.as_f32()?;
        let rlogp = rlogp_a.as_f32()?;
        let hp = hp_a.as_f32()?;
        let &[lr, tis_c, ent_coef, mis, ..] = hp else {
            bail!("{}: hyperparameter vector too short", self.spec.name);
        };

        let model =
            RefModel::new(&self.model, self.geo, &borrow_params(params, n))?;
        let fwd = self.train_forward(&model, tokens);
        let (bt, tt) = (self.constants.b_train, self.constants.t_train);
        let (d, v) = (self.geo.d, self.geo.vocab);
        let steps = tt.saturating_sub(1);

        // ---- loss + mismatch diagnostics (pi_old == pi_theta: one
        // update per batch, so ratio == 1 and the DAPO clip is inactive;
        // the gradient of ratio*adv w.r.t. lp is exactly adv) ----
        let denom: f32 =
            mask.iter().sum::<f32>().max(1.0);
        let mut obj = 0.0f64;
        let mut sum_ent = 0.0f64;
        let mut k1 = 0.0f64;
        let mut k3 = 0.0f64;
        let mut tis_sum = 0.0f64;
        let mut raw_sum = 0.0f64;
        let mut tis_w = vec![0.0f32; bt * steps];
        for ((((w_slot, &mk), (&lpi, &rl)), &ad), &en) in tis_w
            .iter_mut()
            .zip(mask)
            .zip(fwd.lp.iter().zip(rlogp))
            .zip(adv)
            .zip(&fwd.ent)
        {
            let dlog = (lpi - rl) as f64;
            let raw = dlog.exp();
            let w = if tis_c > 0.0 {
                if mis > 0.0 {
                    let lo = 1.0 / (tis_c as f64).max(1e-6);
                    if raw <= tis_c as f64 && raw >= lo {
                        1.0
                    } else {
                        0.0
                    }
                } else {
                    raw.min(tis_c as f64)
                }
            } else {
                1.0
            };
            *w_slot = w as f32;
            // lint: allow(D2): mask entries are exactly 0.0 or 1.0
            if mk == 0.0 {
                continue;
            }
            let mkd = mk as f64;
            obj += ad as f64 * w * mkd;
            sum_ent += en as f64 * mkd;
            k1 -= dlog * mkd;
            k3 += ((raw - 1.0) - dlog) * mkd;
            tis_sum += w * mkd;
            raw_sum += raw * mkd;
        }
        let mean_ent = sum_ent / denom as f64;
        let loss =
            -(obj / denom as f64) - ent_coef as f64 * mean_ent;

        // ---- policy gradient through the lm_head only ----
        let mut g_lm = vec![0.0f32; d * v];
        if d > 0 && v > 0 {
            let mut dl = vec![0.0f32; v];
            for ((((&mk, &ad), &w), hrow), (prow, &nxt)) in mask
                .iter()
                .zip(adv)
                .zip(&tis_w)
                .zip(fwd.feats.chunks_exact(d))
                .zip(fwd.probs.chunks_exact(v).zip(&fwd.nexts))
            {
                // lint: allow(D2): mask entries are exactly 0.0 or 1.0
                if mk == 0.0 {
                    continue;
                }
                let coef = -(ad * w) / denom;
                for (j, (dst, &pj)) in
                    dl.iter_mut().zip(prow).enumerate()
                {
                    let onehot = if j == nxt { 1.0 } else { 0.0 };
                    *dst = coef * (onehot - pj);
                }
                // k-outer accumulation: each g_lm element still sees at
                // most one add per masked step, in step order, so the
                // float sums stay bit-identical to the index form.
                for (&hk, grow) in
                    hrow.iter().zip(g_lm.chunks_exact_mut(v))
                {
                    for (g, &dlj) in grow.iter_mut().zip(&dl) {
                        // lint: allow(D2): exact-zero gradient skip
                        if dlj == 0.0 {
                            continue;
                        }
                        *g += hk * dlj;
                    }
                }
            }
        }
        let gnorm =
            g_lm.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>();
        let gnorm = gnorm.sqrt() as f32;
        let clip = (GRAD_CLIP / (gnorm + 1e-12)).min(1.0);

        // ---- global-step Adam over ALL parameters (zero grads decay
        // the moments; only lm_head receives signal) ----
        let t_new = step + 1.0;
        let bc1 = 1.0 - ADAM_B1.powf(t_new);
        let bc2 = 1.0 - ADAM_B2.powf(t_new);
        let zeros: Vec<f32> = Vec::new();
        let mut new_p = Vec::with_capacity(n);
        let mut new_m = Vec::with_capacity(n);
        let mut new_v = Vec::with_capacity(n);
        for ((pspec, pa), (ma, va)) in self
            .model
            .params
            .iter()
            .zip(params)
            .zip(m_in.iter().zip(v_in))
        {
            let p = pa.as_f32()?;
            let m0 = ma.as_f32()?;
            let v0 = va.as_f32()?;
            let grad: &[f32] = if pspec.name == "lm_head" {
                &g_lm
            } else {
                &zeros
            };
            let len = p.len();
            let mut pn = Vec::with_capacity(len);
            let mut mn = Vec::with_capacity(len);
            let mut vn = Vec::with_capacity(len);
            for ((&pj, (&m0j, &v0j)), g) in p
                .iter()
                .zip(m0.iter().zip(v0))
                .zip(grad.iter().copied().chain(std::iter::repeat(0.0)))
            {
                let g = g * clip;
                let m1 = ADAM_B1 * m0j + (1.0 - ADAM_B1) * g;
                let v1 = ADAM_B2 * v0j + (1.0 - ADAM_B2) * g * g;
                let upd =
                    lr * (m1 / bc1) / ((v1 / bc2).sqrt() + ADAM_EPS);
                pn.push(pj - upd);
                mn.push(m1);
                vn.push(v1);
            }
            let shape = pspec.shape.clone();
            new_p.push(HostArray::f32(shape.clone(), pn));
            new_m.push(HostArray::f32(shape.clone(), mn));
            new_v.push(HostArray::f32(shape, vn));
        }

        // ---- metrics in manifest order ----
        let denom64 = denom as f64;
        let value = |name: &str| -> f32 {
            match name {
                "loss" => loss as f32,
                "entropy" => mean_ent as f32,
                "kl_k1" => (k1 / denom64) as f32,
                "kl_k3" => (k3 / denom64) as f32,
                "tis_mean" => (tis_sum / denom64) as f32,
                "ratio_raw_mean" => (raw_sum / denom64) as f32,
                "grad_norm" => gnorm,
                "lr" => lr,
                // tile-exceedance profiling is a PJRT-only metric
                _ => 0.0,
            }
        };
        let names = &self.constants.metric_names;
        let metrics: Vec<f32> =
            names.iter().map(|nm| value(nm.as_str())).collect();

        let mut out = new_p;
        out.extend(new_m);
        out.extend(new_v);
        out.push(HostArray::f32(vec![1, 1], vec![t_new]));
        out.push(HostArray::f32(vec![1, names.len()], metrics));
        Ok(out)
    }

    fn run_logprobs(
        &self,
        inputs: &[HostArray],
    ) -> Result<Vec<HostArray>> {
        let n = self.model.params.len();
        self.check_arity(inputs.len(), n + 1)?;
        let model =
            RefModel::new(&self.model, self.geo, &borrow_params(inputs, n))?;
        let (_, rest) = inputs.split_at(n);
        let [tokens_a] = rest else {
            bail!("{}: logprobs input unpacking failed", self.spec.name);
        };
        let tokens = tokens_a.as_i32()?;
        let fwd = self.train_forward(&model, tokens);
        let (bt, tt) = (self.constants.b_train, self.constants.t_train);
        let steps = tt.saturating_sub(1);
        Ok(vec![
            HostArray::f32(vec![bt, steps], fwd.lp),
            HostArray::f32(vec![bt, steps], fwd.ent),
        ])
    }

    /// K/V amax scan over the given rows — the reference twin of the
    /// calibrate artifact. K tracks even state components, V odd ones,
    /// so the two scales are genuinely data-dependent but close.
    fn run_calibrate(
        &self,
        inputs: &[HostArray],
    ) -> Result<Vec<HostArray>> {
        let n = self.model.params.len();
        self.check_arity(inputs.len(), n + 1)?;
        let model =
            RefModel::new(&self.model, self.geo, &borrow_params(inputs, n))?;
        let (_, rest) = inputs.split_at(n);
        let [tokens_a] = rest else {
            bail!("{}: calibrate input unpacking failed", self.spec.name);
        };
        let tokens = tokens_a.as_i32()?;
        let (bt, tt) = (self.constants.b_train, self.constants.t_train);
        let mut amax_even = 0.0f32;
        let mut amax_odd = 0.0f32;
        if tt > 0 {
            for trow in tokens.chunks_exact(tt).take(bt) {
                let mut state = vec![0.0f32; self.geo.d];
                for &tok in trow {
                    state = model.state_update(&state, tok);
                    for (j, &x) in state.iter().enumerate() {
                        if j % 2 == 0 {
                            amax_even = amax_even.max(x.abs());
                        } else {
                            amax_odd = amax_odd.max(x.abs());
                        }
                    }
                }
            }
        }
        let kscale = amax_even.max(1e-6) / E4M3.max;
        let vscale = amax_odd.max(1e-6) / E4M3.max;
        Ok(vec![
            HostArray::f32(vec![1, 1], vec![kscale]),
            HostArray::f32(vec![1, 1], vec![vscale]),
        ])
    }
}

struct TrainForward {
    feats: Vec<f32>,
    probs: Vec<f32>,
    lp: Vec<f32>,
    ent: Vec<f32>,
    /// Per-step next-token index (already reduced mod vocab), so the
    /// gradient pass never re-derives it from the token stream.
    nexts: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn exec(name: &str) -> RefExecutable {
        let m = Manifest::synthetic();
        let spec = m.entry(name).unwrap().clone();
        let model = m.model(&spec.arch).unwrap().clone();
        let geo = Geometry::from_model(&model).unwrap();
        RefExecutable {
            spec,
            model,
            geo,
            constants: m.constants.clone(),
        }
    }

    fn params(m: &Manifest, arch: &str) -> Vec<HostArray> {
        let spec = m.model(arch).unwrap();
        m.load_initial_params(arch)
            .unwrap()
            .into_iter()
            .zip(&spec.params)
            .map(|(v, p)| HostArray::f32(p.shape.clone(), v))
            .collect()
    }

    #[test]
    fn prefill_decode_state_threading_agrees() {
        // feeding the same tokens through prefill vs one-at-a-time
        // decode must land on identical logits at every position
        let m = Manifest::synthetic();
        let ps = params(&m, "dense");
        let c = m.constants.clone();
        let pre = exec("dense_prefill_bf16");
        let dec = exec("dense_decode_bf16");
        let geo = pre.geo;

        let toks: Vec<i32> = (0..c.prompt_len as i32).collect();
        let mut tokens = vec![0i32; c.b_rollout * c.prompt_len];
        tokens[..c.prompt_len].copy_from_slice(&toks);
        let mut inputs = ps.clone();
        inputs.push(HostArray::i32(
            vec![c.b_rollout, c.prompt_len],
            tokens,
        ));
        inputs.push(HostArray::scalar_f32(1.0));
        inputs.push(HostArray::scalar_f32(1.0));
        let wave = pre.run(&inputs).unwrap();
        let wave_logits = wave[0].as_f32().unwrap().to_vec();

        let cache_len = geo.cache_len(c.b_rollout);
        let mut kc = HostArray::f32(
            geo.kv_shape(c.b_rollout),
            vec![0.0; cache_len],
        );
        let mut vc = kc.clone();
        for (p, &tok) in toks.iter().enumerate() {
            let mut feed = vec![0i32; c.b_rollout];
            feed[0] = tok;
            let mut pos = vec![0i32; c.b_rollout];
            pos[0] = p as i32;
            let mut inputs = ps.clone();
            inputs.push(kc.clone());
            inputs.push(vc.clone());
            inputs.push(HostArray::i32(vec![c.b_rollout, 1], feed));
            inputs.push(HostArray::i32(vec![c.b_rollout, 1], pos));
            inputs.push(HostArray::scalar_f32(1.0));
            inputs.push(HostArray::scalar_f32(1.0));
            let out = dec.run(&inputs).unwrap();
            let dec_logits = out[0].as_f32().unwrap();
            let want =
                &wave_logits[p * geo.vocab..(p + 1) * geo.vocab];
            assert_eq!(
                &dec_logits[..geo.vocab],
                want,
                "position {p} diverged"
            );
            kc = out[1].clone();
            vc = out[2].clone();
        }
    }

    #[test]
    fn fp8_variants_perturb_logits() {
        let m = Manifest::synthetic();
        let ps = params(&m, "dense");
        let c = m.constants.clone();
        let mk_inputs = || {
            let mut inputs = ps.clone();
            inputs.push(HostArray::i32(
                vec![c.b_rollout, c.prompt_len],
                vec![3; c.b_rollout * c.prompt_len],
            ));
            inputs.push(HostArray::scalar_f32(0.01));
            inputs.push(HostArray::scalar_f32(0.01));
            inputs
        };
        let bf16 = exec("dense_prefill_bf16").run(&mk_inputs()).unwrap();
        let fp8 =
            exec("dense_prefill_fullfp8").run(&mk_inputs()).unwrap();
        assert_ne!(
            bf16[0].as_f32().unwrap(),
            fp8[0].as_f32().unwrap(),
            "fp8 path must not be bit-identical to bf16"
        );
    }

    #[test]
    fn train_step_threads_adam_state() {
        let m = Manifest::synthetic();
        let ps = params(&m, "dense");
        let c = m.constants.clone();
        let n = ps.len();
        let tr = exec("dense_train_bf16");
        let zeros: Vec<HostArray> = ps
            .iter()
            .map(|p| {
                HostArray::f32(
                    p.shape().to_vec(),
                    vec![0.0; p.numel()],
                )
            })
            .collect();
        let steps = c.t_train - 1;
        let mut inputs = ps.clone();
        inputs.extend(zeros.clone());
        inputs.extend(zeros);
        inputs.push(HostArray::f32(vec![1, 1], vec![0.0]));
        let mut tokens = vec![14i32; c.b_train * c.t_train];
        for (i, t) in tokens.iter_mut().enumerate().take(8) {
            *t = (i % 10) as i32;
        }
        inputs.push(HostArray::i32(
            vec![c.b_train, c.t_train],
            tokens,
        ));
        let mut mask = vec![0.0f32; c.b_train * steps];
        mask[2] = 1.0;
        mask[3] = 1.0;
        inputs.push(HostArray::f32(
            vec![c.b_train, steps],
            mask.clone(),
        ));
        let mut adv = vec![0.0f32; c.b_train * steps];
        adv[2] = 1.0;
        adv[3] = 1.0;
        inputs.push(HostArray::f32(vec![c.b_train, steps], adv));
        inputs.push(HostArray::f32(
            vec![c.b_train, steps],
            vec![-1.0; c.b_train * steps],
        ));
        inputs.push(HostArray::f32(
            vec![1, 4],
            vec![1e-2, 2.0, 0.0, 0.0],
        ));
        let out = tr.run(&inputs).unwrap();
        assert_eq!(out.len(), 3 * n + 2);
        // step advanced, grad norm positive, moments moved on lm_head
        assert_eq!(out[3 * n].as_f32().unwrap()[0], 1.0);
        let names = &m.constants.metric_names;
        let gi = names.iter().position(|s| s == "grad_norm").unwrap();
        let metrics = out[3 * n + 1].as_f32().unwrap();
        assert!(metrics[gi] > 0.0, "expected gradient signal");
        let li = m
            .model("dense")
            .unwrap()
            .params
            .iter()
            .position(|p| p.name == "lm_head")
            .unwrap();
        // (the grad rows sum to zero across the vocab by construction,
        // so check per-element movement, not the sum)
        assert!(
            out[n + li].as_f32().unwrap().iter().any(|&x| x != 0.0),
            "lm_head Adam moment must move"
        );
    }
}
