//! Artifact manifest — the Rust<->Python ABI emitted by
//! `python/compile/aot.py` (`artifacts/manifest.json`).
//!
//! Describes the model architectures (param name/shape lists in flat
//! order), every AOT entrypoint's input signature, and the experiment
//! scale constants (batch sizes, sequence lengths) both sides must agree
//! on.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "s32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub arch: String,
    /// architecture hyperparameters (vocab, d_model, n_layers, ...)
    pub config: BTreeMap<String, f64>,
    pub params: Vec<ParamSpec>,
}

impl ModelSpec {
    pub fn cfg(&self, key: &str) -> usize {
        *self
            .config
            .get(key)
            .unwrap_or_else(|| panic!("model config missing '{key}'"))
            as usize
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn total_weights(&self) -> usize {
        self.params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }
}

#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub kind: String,    // prefill | decode | train | logprobs | calibrate
    pub arch: String,    // dense | moe
    pub variant: String, // bf16 | fp8lin | ...
    pub inputs: Vec<TensorSig>,
}

#[derive(Clone, Debug)]
pub struct Constants {
    pub b_rollout: usize,
    pub prompt_len: usize,
    pub b_train: usize,
    pub t_train: usize,
    pub metric_names: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub constants: Constants,
    pub models: BTreeMap<String, ModelSpec>,
    pub entrypoints: BTreeMap<String, EntrySpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let c = j.get("constants")?;
        let constants = Constants {
            b_rollout: c.get("b_rollout")?.as_usize()?,
            prompt_len: c.get("prompt_len")?.as_usize()?,
            b_train: c.get("b_train")?.as_usize()?,
            t_train: c.get("t_train")?.as_usize()?,
            metric_names: c
                .get("metric_names")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
        };

        let mut models = BTreeMap::new();
        for (arch, m) in j.get("models")?.as_obj()? {
            let mut config = BTreeMap::new();
            for (k, v) in m.get("config")?.as_obj()? {
                let num = match v {
                    Json::Num(n) => *n,
                    Json::Bool(b) => {
                        if *b {
                            1.0
                        } else {
                            0.0
                        }
                    }
                    _ => continue,
                };
                config.insert(k.clone(), num);
            }
            let params = m
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name")?.as_str()?.to_string(),
                        shape: p
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<std::result::Result<Vec<_>, _>>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                arch.clone(),
                ModelSpec {
                    arch: arch.clone(),
                    config,
                    params,
                },
            );
        }

        let mut entrypoints = BTreeMap::new();
        for e in j.get("entrypoints")?.as_arr()? {
            let inputs = e
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|s| {
                    Ok(TensorSig {
                        shape: s
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<std::result::Result<Vec<_>, _>>()?,
                        dtype: DType::parse(s.get("dtype")?.as_str()?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let spec = EntrySpec {
                name: e.get("name")?.as_str()?.to_string(),
                file: e.get("file")?.as_str()?.to_string(),
                kind: e.get("kind")?.as_str()?.to_string(),
                arch: e.get("arch")?.as_str()?.to_string(),
                variant: e.get("variant")?.as_str()?.to_string(),
                inputs,
            };
            entrypoints.insert(spec.name.clone(), spec);
        }

        Ok(Manifest {
            dir,
            constants,
            models,
            entrypoints,
        })
    }

    pub fn model(&self, arch: &str) -> Result<&ModelSpec> {
        self.models
            .get(arch)
            .with_context(|| format!("unknown arch {arch:?}"))
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entrypoints
            .get(name)
            .with_context(|| format!("unknown entrypoint {name:?}"))
    }

    /// Load the deterministic initial weights dumped by aot.py.
    pub fn load_initial_params(&self, arch: &str) -> Result<Vec<Vec<f32>>> {
        let spec = self.model(arch)?;
        let path = self.dir.join(format!("params_{arch}.bin"));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let total: usize = spec.total_weights();
        if bytes.len() != total * 4 {
            bail!(
                "params_{arch}.bin: expected {} bytes, got {}",
                total * 4,
                bytes.len()
            );
        }
        let mut out = Vec::with_capacity(spec.params.len());
        let mut off = 0usize;
        for p in &spec.params {
            let n: usize = p.shape.iter().product();
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[(off + i) * 4..(off + i) * 4 + 4];
                v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n;
            out.push(v);
        }
        Ok(out)
    }
}
